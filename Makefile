.PHONY: check test smoke analyze chaos

# one offline regression command: static analysis + tier-1 tests +
# smoke benchmarks
check:
	sh scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

smoke:
	PYTHONPATH=src python -m benchmarks.run --smoke

# repo-specific static analysis (fails on non-baselined findings;
# prints a per-rule finding summary); see src/repro/analysis/README.md
analyze:
	PYTHONPATH=src python -m repro.analysis src/

# full fault-injection chaos matrix (step transactions, degradation
# ladder, engine-vs-sim parity under faults), `slow` sweeps included
chaos:
	PYTHONPATH=src python -m pytest -x -q tests/test_chaos.py
