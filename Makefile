.PHONY: check test smoke

# one offline regression command: tier-1 tests + smoke benchmarks
check:
	sh scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

smoke:
	python -m benchmarks.run --smoke
