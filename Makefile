.PHONY: check test smoke analyze

# one offline regression command: static analysis + tier-1 tests +
# smoke benchmarks
check:
	sh scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

smoke:
	PYTHONPATH=src python -m benchmarks.run --smoke

# repo-specific static analysis (fails on non-baselined findings);
# see src/repro/analysis/README.md
analyze:
	PYTHONPATH=src python -m repro.analysis src/
