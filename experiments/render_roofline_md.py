"""Render EXPERIMENTS.md's roofline table from experiments/roofline JSONs."""
import glob, json, sys
sys.path.insert(0, ".")
from benchmarks.roofline_table import _advice

rows = []
for path in sorted(glob.glob("experiments/roofline/*_sp.json")):
    r = json.load(open(path))
    rf = r["roofline"]
    raw = rf.get("memory_s_cpu_raw", rf["memory_s"])
    rows.append((r["arch"], r["shape"], rf["compute_s"], rf["memory_s"],
                 raw, rf["collective_s"], rf["dominant"],
                 rf["useful_flops_fraction"], r.get("microbatches", 1),
                 r.get("fits_hbm"), _advice(r)))
print("| arch | shape | C (ms) | M (ms) | X (ms) | dominant | useful | mb | what moves the dominant term |")
print("|---|---|---|---|---|---|---|---|---|")
for a, s, c, m, raw, x, d, u, mb, fit, adv in rows:
    print(f"| {a} | {s} | {c*1e3:.2f} | {m*1e3:.2f} | "
          f"{x*1e3:.2f} | {d.replace('_s','')} | {u:.1%} | {mb} | {adv} |")
