"""Training launcher: ``python -m repro.launch.train --arch smollm-360m``.

CPU-scale by default (reduced config, tiny mesh); pass ``--full`` on a
real pod.  Features exercised end-to-end: sharded params (FSDP + TP),
microbatched grad accumulation, remat, deterministic data pipeline,
periodic async checkpointing with auto-resume, straggler logging, and
retry-on-transient-failure.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.cost_model import (TheoreticalCostModel, BatchSpec,
                                   get_hardware)
from repro.data import DataConfig, batch_with_frontend
from repro.distributed import StragglerMonitor, run_with_retries
from repro.models import model as M
from repro.training import AdamWConfig, init_adamw, make_train_step

log = logging.getLogger("repro.train")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — real-hardware scale")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
        if args.resume and mgr.has_checkpoint():
            state, start_step = mgr.restore_latest(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            log.info("resumed from step %d", start_step)

    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    straggler = StragglerMonitor(deadline_factor=10.0, min_floor_s=1.0)
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = batch_with_frontend(cfg, dcfg, step)
        t0 = time.time()
        params, opt_state, metrics = run_with_retries(
            step_fn, params, opt_state, batch)
        if straggler.observe(
                cm.batch_time(BatchSpec(prefills=[(args.seq, 0)] * args.batch)),
                time.time() - t0):
            log.warning("straggler batch at step %d", step)
        if step % args.log_every == 0 or step == args.steps - 1:
            log.info("step %d loss %.4f grad_norm %.3f lr %.2e",
                     step, float(metrics["loss"]),
                     float(metrics["grad_norm"]), float(metrics["lr"]))
        if mgr is not None:
            mgr.maybe_save({"params": params, "opt": opt_state}, step + 1)
    if mgr is not None:
        mgr.save({"params": params, "opt": opt_state}, args.steps,
                 block=True)
    log.info("done: %d steps in %.1fs", args.steps - start_step,
             time.time() - t_start)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
