"""Serving launcher: ``python -m repro.launch.serve --arch tinyllama-1.1b``.

Runs the continuous-batching engine on a workload with the selected
scheduling / cache-replacement policy (the paper's deployment path) and
prints the §5.1 metrics.  CPU-scale reduced configs by default.
"""
from __future__ import annotations

import argparse
import logging

import jax
import numpy as np

from repro.configs import get_config
from repro.core import TheoreticalCostModel, get_hardware, make_scheduler
from repro.data import azureconv_like, fixed_grid, hetero_mix, longform_like
from repro.models import model as M
from repro.serving import Engine, EngineConfig

log = logging.getLogger("repro.serve")

WORKLOADS = {
    "fixed": lambda vocab: fixed_grid(12, 24, 8, vocab=vocab),
    "hetero": lambda vocab: hetero_mix(["SISO", "SILO"], 12, vocab=vocab),
    "azureconv": lambda vocab: azureconv_like(12, vocab=vocab),
    "longform": lambda vocab: longform_like(12, vocab=vocab),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--scheduler", default="vllm",
                    choices=["vllm", "vllm_hy", "sarathi", "sarathi_cs",
                             "orca", "vllm_pf", "sarathi_pf"])
    ap.add_argument("--replacement", default="srf",
                    choices=["nrf", "srf", "lrf", "pf"])
    ap.add_argument("--histogram", action="store_true",
                    help="SRF+Hist admission gating")
    ap.add_argument("--workload", default="fixed", choices=sorted(WORKLOADS))
    ap.add_argument("--M", type=int, default=128,
                    help="KV cache size in tokens")
    ap.add_argument("--nslots", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = WORKLOADS[args.workload](cfg.vocab_size)
    # crop requests to the engine's context budget
    for r in reqs:
        r.input_len = min(r.input_len, args.cache_len // 2)
        r.output_len = min(r.output_len, args.cache_len // 2)
        r.prompt = r.prompt[:r.input_len]

    sched = make_scheduler(args.scheduler, args.M, S=args.cache_len,
                           replacement=args.replacement,
                           use_histogram=args.histogram)
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=args.nslots, cache_len=args.cache_len,
                              chunk=args.chunk), cost_model=cm)
    res = eng.run(reqs)
    s = res.metrics.summary()
    log.info("scheduler=%s replacement=%s workload=%s",
             args.scheduler, args.replacement, args.workload)
    for k, v in s.items():
        log.info("  %-16s %.6g", k, v)
    log.info("wall time %.2fs; sample output rid=0: %s",
             res.wall_time, res.outputs.get(0, [])[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
