"""Production mesh builders (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  Target: TPU v5e pods — 16x16 = 256 chips per
pod, 2 pods = 512 chips multi-pod.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older releases default
    # every axis to Auto, which is exactly what we want.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return _make_mesh((data, model), ("data", "model"))
