"""Production mesh builders (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  Target: TPU v5e pods — 16x16 = 256 chips per
pod, 2 pods = 512 chips multi-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
