import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST precede every other import (jax locks the
# device count on first init); `from __future__` is therefore omitted.
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, WITHOUT allocating any real arrays
(ShapeDtypeStruct stand-ins).

For every cell it records:
  * memory_analysis()  — per-device argument/temp bytes (proves it fits)
  * cost_analysis()    — per-device HLO FLOPs / bytes (roofline §g)
  * collective bytes   — parsed from the compiled HLO text, with
    while-loop (lax.scan over layers) trip-count multiplication
  * the three roofline terms vs TPU v5e peaks, and the dominant one

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, applicable_shapes, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cost_model import get_hardware
from repro.distributed.sharding import (batch_pspecs, named,
                                        out_pspecs_decode, param_pspecs)
from repro.launch.hlo_analysis import (collective_bytes,
                                       convert_traffic_bytes,
                                       duplicate_op_fraction)
from repro.launch.mesh import make_production_mesh
from repro.serving.serve_step import (build_decode_fn, build_prefill_fn,
                                      cache_specs, param_specs,
                                      serve_input_specs)
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step

HW = get_hardware("tpu_v5e")


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D = batch
    tokens; train counts fwd+bwd (6ND), inference counts fwd (2ND)."""
    n = cfg.active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * 1 * shape.global_batch  # decode: one token per request


def analytic_memory(cfg: ModelConfig, shape: ShapeConfig, chips: int, *,
                    fsdp: bool, microbatches: int = 1) -> Dict[str, float]:
    """TPU-side HBM estimate per chip (the CPU backend's temp analysis
    reflects host scheduling, not TPU buffer assignment).

    train: bf16 params + fp32 (master, mu, nu) + fp32 grads, all sharded
    over the whole mesh when fsdp else over model only; activations with
    remat ~= residual stream for all layers + one layer's working set.
    serve: bf16 params over model axis + the KV cache over the mesh.
    """
    n = cfg.num_params()
    model_par = 16
    mesh_par = chips if fsdp else model_par
    if shape.kind == "train":
        weights = 2 * n / mesh_par                # bf16
        opt = 3 * 4 * n / mesh_par                # fp32 master+mu+nu
        grads = 4 * n / mesh_par
        B_loc = shape.global_batch / (chips / model_par) / microbatches
        d_wide = max(cfg.d_ff, cfg.q_dim + 2 * cfg.kv_dim,
                     2 * cfg.d_inner if cfg.ssm_state else 0)
        acts = B_loc * shape.seq_len * (
            cfg.num_layers * cfg.d_model * 2            # bf16 stream
            + 6 * d_wide / model_par * 2)               # one layer, TP
        logits = B_loc * shape.seq_len * cfg.padded_vocab / model_par * 4
        total = weights + opt + grads + acts + logits
        return {"weights": weights, "opt": opt + grads, "acts": acts,
                "logits": logits, "total": total}
    weights = 2 * n / model_par
    B, S = shape.global_batch, shape.seq_len
    eff = min(S, cfg.window) if cfg.window else S
    kv = (cfg.num_layers * B * eff * 2 * cfg.kv_dim * 2) / chips
    if cfg.family == "ssm":
        kv = cfg.num_layers * B * (cfg.ssm_heads * cfg.ssm_state ** 2 * 4
                                   + 2 * cfg.d_model * 2) / chips
    acts = (B * S * cfg.d_model * 2 / (chips / model_par)
            if shape.kind == "prefill" else B * cfg.d_model * 2)
    total = weights + kv + acts
    return {"weights": weights, "kv": kv, "acts": acts, "total": total}


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               impl: str = "reference", moe_impl: str = "sparse",
               remat: bool = True, seq_shard: bool = True,
               fsdp: bool = True, microbatches: int = 1,
               unroll: bool = False, append: str = "inline"):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    pshape = param_specs(cfg)
    if shape.kind == "train":
        ps = param_pspecs(cfg, pshape, fsdp=fsdp)
        from jax.sharding import PartitionSpec as P
        oshape = jax.eval_shape(init_adamw, pshape)
        # opt-state specs: step replicated; master/mu/nu follow params
        ospec = type(oshape)(step=P(), master=ps, mu=ps, nu=ps)
        bspec = batch_pspecs(cfg, shape, mesh)
        ins = serve_input_specs(cfg, shape)
        opt_cfg = AdamWConfig(total_steps=1000)
        step = make_train_step(cfg, opt_cfg, microbatches=microbatches,
                               impl=impl, moe_impl=moe_impl, remat=remat,
                               unroll=unroll)
        fn = jax.jit(step,
                     in_shardings=(named(mesh, ps), named(mesh, ospec),
                                   named(mesh, bspec)),
                     out_shardings=(named(mesh, ps), named(mesh, ospec),
                                    None),
                     donate_argnums=(0, 1))
        return fn, (pshape, oshape, ins)
    if shape.kind == "prefill":
        ps = param_pspecs(cfg, pshape, fsdp=False)
        bspec = batch_pspecs(cfg, shape, mesh)
        ins = serve_input_specs(cfg, shape)
        prefill = build_prefill_fn(cfg, cache_len=shape.seq_len, impl=impl,
                                   moe_impl=moe_impl, unroll=unroll)
        from jax.sharding import PartitionSpec as P
        dshape = dataclasses.replace(shape, kind="decode")
        cache_spec = batch_pspecs(cfg, dshape, mesh,
                                  seq_shard=seq_shard)["cache"]
        dp = [a for a in mesh.axis_names if a in ("pod", "data")]
        out_spec = (P(tuple(dp), "model"), cache_spec)
        fn = jax.jit(prefill,
                     in_shardings=(named(mesh, ps), named(mesh, bspec)),
                     out_shardings=named(mesh, out_spec))
        return fn, (pshape, ins)
    # decode
    ps = param_pspecs(cfg, pshape, fsdp=False)
    bspec = batch_pspecs(cfg, shape, mesh, seq_shard=seq_shard)
    ins = serve_input_specs(cfg, shape)
    decode = build_decode_fn(cfg, impl=impl, moe_impl=moe_impl,
                             unroll=unroll, append=append)
    out_spec = out_pspecs_decode(cfg, shape, mesh, seq_shard=seq_shard)
    fn = jax.jit(decode,
                 in_shardings=(named(mesh, ps), named(mesh, bspec["tokens"]),
                               named(mesh, bspec["cache"])),
                 out_shardings=named(mesh, out_spec),
                 donate_argnums=(2,))
    return fn, (pshape, ins["tokens"], ins["cache"])


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                impl: str = "reference", moe_impl: str = "sparse",
                remat: bool = True, seq_shard: bool = True,
                fsdp: bool = True, microbatches: int = 1,
                unroll: bool = False, append: str = "inline",
                verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    if shape.kind == "train" and microbatches == 0:  # auto: fit HBM
        microbatches = 1
        while (analytic_memory(cfg, shape, chips, fsdp=fsdp,
                               microbatches=microbatches)["total"]
               > 0.9 * HW.hbm_cap and microbatches < 32):
            microbatches *= 2
    microbatches = max(1, microbatches)
    from repro.distributed.context import set_mesh
    set_mesh(mesh)
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape, mesh, impl=impl, moe_impl=moe_impl,
                              remat=remat, seq_shard=seq_shard, fsdp=fsdp,
                              microbatches=microbatches, unroll=unroll,
                              append=append)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax<=0.4.x: one dict per device
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = collective_bytes(hlo, num_devices=chips)
    cvt_bytes = convert_traffic_bytes(hlo)

    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    if shape.kind == "train" and microbatches > 1:
        # the microbatch grad-accumulation lax.scan is a while loop whose
        # body cost_analysis counts ONCE (the layer stack inside is
        # unrolled under --unroll, but the mb loop is not): correct by
        # the trip count.  (The collective parser already multiplies.)
        flops_dev *= microbatches
        bytes_dev *= microbatches
    compute_s = flops_dev / HW.flops
    memory_s = bytes_dev / HW.hbm_bw
    # TPU-target correction: the CPU backend materializes f32 copies of
    # every bf16 dot operand (convert ops); the TPU MXU reads bf16
    # natively, so those bytes do not exist on the target hardware.
    bytes_tpu = max(bytes_dev - cvt_bytes, 0.2 * bytes_dev)
    memory_s_tpu = bytes_tpu / HW.hbm_bw
    collective_s = colls.link_bytes / HW.link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s_tpu,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["memory_s_cpu_raw"] = memory_s
    mf = model_flops(cfg, shape)
    useful = mf / (flops_dev * chips) if flops_dev else 0.0

    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "impl": impl, "moe_impl": moe_impl, "remat": remat,
        "seq_shard": seq_shard, "fsdp": fsdp, "microbatches": microbatches,
        "unroll": unroll, "append": append,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": flops_dev, "bytes": bytes_dev,
            "bytes_tpu": bytes_tpu, "convert_bytes": cvt_bytes,
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
        },
        "collectives": {
            "bytes_by_kind": colls.bytes_by_kind,
            "count_by_kind": colls.count_by_kind,
            "link_bytes_per_device": colls.link_bytes,
        },
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "model_flops": mf,
            "useful_flops_fraction": useful,
            "dup_dot_fraction": duplicate_op_fraction(hlo),
        },
    }
    amem = analytic_memory(cfg, shape, chips, fsdp=fsdp,
                           microbatches=microbatches)
    report["analytic_memory_per_chip"] = amem
    # args from the real compile; working set from the analytic model
    # (CPU-backend temp analysis reflects host scheduling, not TPU HBM)
    report["fits_hbm"] = bool(amem["total"] <= HW.hbm_cap)
    if verbose:
        arg_gb = (report["per_device"]["argument_bytes"] or 0) / 1e9
        tmp_gb = amem["total"] / 1e9
        print(f"[dryrun] {arch:20s} {shape_name:12s} {report['mesh']:8s} "
              f"args={arg_gb:6.2f}GB hbm~{tmp_gb:6.2f}GB "
              f"C={compute_s*1e3:9.3f}ms M={memory_s_tpu*1e3:9.3f}ms "
              f"X={collective_s*1e3:9.3f}ms dom={dominant:12s} "
              f"useful={useful:5.1%} (lower {t_lower:.0f}s "
              f"compile {t_compile:.0f}s)", flush=True)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--impl", default="reference")
    ap.add_argument("--moe-impl", default="sparse")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="grad-accumulation microbatches (0 = auto-fit HBM)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan for exact cost analysis")
    ap.add_argument("--decode-append", default="inline",
                    choices=["inline", "deferred"])
    ap.add_argument("--out", default=None, help="JSON output directory")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, sh in cells:
        for mp in meshes:
            try:
                rep = dryrun_cell(
                    arch, sh, multi_pod=mp, impl=args.impl,
                    moe_impl=args.moe_impl, remat=not args.no_remat,
                    seq_shard=not args.no_seq_shard, fsdp=not args.no_fsdp,
                    microbatches=args.microbatches, unroll=args.unroll,
                    append=args.decode_append)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    tag = "mp" if mp else "sp"
                    path = os.path.join(args.out, f"{arch}_{sh}_{tag}.json")
                    with open(path, "w") as f:
                        json.dump(rep, f, indent=1)
            except Exception as e:  # noqa: BLE001 - report-all mode
                failures.append((arch, sh, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} {sh} mp={mp}: {e!r}",
                      flush=True)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES")
        return 1
    print("[dryrun] all cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
