"""HLO-text analysis: collective-byte accounting with while-loop
trip-count multiplication.

``compiled.cost_analysis()`` does not report collective bytes, so we
parse the (post-SPMD, per-device) HLO: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op contributes its
result bytes, multiplied by the trip counts of every while loop it sits
inside (lax.scan over layers emits a while; nested scans multiply).

Trip counts are recovered from each while's CONDITION computation (the
scan counter is compared against a literal constant).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)", re.S)
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"\bs32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in an HLO result type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def split_computations(text: str) -> Dict[str, List[str]]:
    """name -> list of body lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        m = _COMP_START_RE.match(line.rstrip())
        if m and line and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            if cur is not None and line.startswith("}"):
                cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Largest s32 literal in the while condition (scan bound)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    # bytes actually moved over links per device (ring algorithm factors)
    link_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def _multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    """Execution-count multiplier per computation (while trip counts)."""
    mult: Dict[str, float] = {}

    def visit(name: str, m: float) -> None:
        if name not in comps:
            return
        if name in mult and mult[name] >= m:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        body = "\n".join(comps[name])
        # while ops: body runs trip-count times
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, []))
            visit(cond, m * trips)
            visit(wbody, m * trips)
        # plain calls / fusions inherit the multiplier
        for line in comps[name]:
            if "while(" in line:
                continue
            for cm in _CALL_RE.finditer(line):
                visit(cm.group(1), m)

    visit(entry, 1.0)
    return mult


def collective_bytes(hlo_text: str, *, num_devices: int) -> CollectiveStats:
    comps = split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k]), default="")
    mult = _multipliers(comps, entry)

    stats = CollectiveStats()
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in lines:
            for kind in COLLECTIVES:
                token = f" {kind}("
                if token not in line and not line.startswith(kind + "("):
                    continue
                lhs = line.split("=", 1)[0] if "=" in line else ""
                rhs_type = line.split("=", 1)[1] if "=" in line else line
                b = shape_bytes(rhs_type.split(kind + "(")[0])
                g = _group_size(line, num_devices)
                stats.bytes_by_kind[kind] = (
                    stats.bytes_by_kind.get(kind, 0.0) + m * b)
                stats.count_by_kind[kind] = (
                    stats.count_by_kind.get(kind, 0) + int(m))
                # per-device link traffic (ring algorithms)
                if kind == "all-reduce":
                    factor = 2.0 * (g - 1) / max(g, 1)
                elif kind in ("all-gather", "reduce-scatter"):
                    factor = (g - 1) / max(g, 1)
                elif kind == "all-to-all":
                    factor = (g - 1) / max(g, 1)
                else:  # collective-permute: point-to-point
                    factor = 1.0
                stats.link_bytes += m * b * factor
                break
    return stats


def convert_traffic_bytes(hlo_text: str) -> float:
    """Bytes moved by dtype ``convert`` ops (in + out), with while
    multipliers.  The CPU backend cannot consume bf16 in dots and
    materializes f32 copies of every bf16 operand — on the TPU target
    (native bf16 MXU) these ops do not exist, so the §Roofline memory
    term subtracts them (reported as memory_s_tpu)."""
    comps = split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k]), default="")
    mult = _multipliers(comps, entry)
    total = 0.0
    cv = re.compile(r"=\s*(\S+)\s+convert\(%[\w\.\-]+\)")
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in lines:
            mm = cv.search(line)
            if mm:
                out_b = shape_bytes(mm.group(1))
                # input is the other precision: f32<->bf16 => in = out/2
                # or 2*out; approximate in+out as 1.5x the larger
                total += m * out_b * 1.5
    return total


def duplicate_op_fraction(hlo_text: str) -> float:
    """Fraction of dot ops appearing with rematerialization suffixes —
    a cheap remat/redundancy indicator for §Roofline."""
    dots = re.findall(r"%([\w\.\-]*dot[\w\.\-]*)\s*=", hlo_text)
    if not dots:
        return 0.0
    base = set()
    dup = 0
    for d in dots:
        root = re.sub(r"\.\d+$", "", d)
        if root in base:
            dup += 1
        base.add(root)
    return dup / len(dots)


def custom_calls(hlo_text: str) -> Dict[str, int]:
    """custom_call target -> occurrence count.

    Custom calls are where XLA escapes its own fusion/scheduling —
    Pallas kernels show up here (expected, by target name), but so do
    host callbacks and debugging hooks that silently serialize the
    engine's jitted entry points.  The artifact audit diffs this
    against an expected-target allowlist."""
    out: Dict[str, int] = {}
    for m in re.finditer(r'custom_call_target="([^"]+)"', hlo_text):
        out[m.group(1)] = out.get(m.group(1), 0) + 1
    return out


#: op mnemonics that move data across the device/host boundary or pin
#: the schedule to host progress
HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "send-done", "recv",
                     "recv-done")


def host_transfer_ops(hlo_text: str) -> Dict[str, int]:
    """Host-boundary ops in the lowered module, name -> count.

    A compiled serving entry point should contain NONE of these: the
    engine stages all tokens/tables device-side before the call and
    fetches results after it.  Any hit means a host round-trip got
    baked INTO the artifact — invisible to the Python-level host-sync
    checker, caught here."""
    out: Dict[str, int] = {}
    for op in HOST_TRANSFER_OPS:
        # whitespace-preceded mnemonic directly applied to operands —
        # matches the op position (`... = <type> send(...)`) but not
        # value references (`%send.1`) or longer mnemonics (send-done)
        n = len(re.findall(r"(?<=\s)%s\(" % re.escape(op), hlo_text))
        if n:
            out[op] = n
    return out
