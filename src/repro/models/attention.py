"""GQA attention with KV cache: reference impl + kernel/distributed dispatch.

Modes
-----
* full-sequence (train / prefill): causal (+optional sliding window /
  prefix-LM) attention over the whole batch.
* decode: one query token against a dense cache ``(B, S, Hkv, D)`` with a
  per-request length mask.

``impl`` selects the backend:
* ``"reference"`` — pure jnp (used by the dry-run and CPU tests),
* ``"flash"`` / ``"paged"`` — Pallas kernels (TPU target; interpret=True on
  CPU), see ``repro.kernels``.
* decode under a sequence-sharded cache goes through
  ``repro.distributed.collectives.flash_decode_seqsharded`` (shard_map).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (Params, apply_rope, causal_mask, dense_init,
                                 rms_norm)


def merge_softmax_groups(out1, m1, l1, s2, v2):
    """Numerically-stable merge of a softmax group (out1 with running
    max m1 / sum l1) with one extra logit s2 / value v2.
    out1 (B,H,D); m1,l1 (B,H); s2 (B,H); v2 (B,H,D)."""
    M = jnp.maximum(m1, s2)
    w1 = l1 * jnp.exp(m1 - M)
    w2 = jnp.exp(s2 - M)
    denom = jnp.maximum(w1 + w2, 1e-30)
    return (out1 * w1[..., None] + v2 * w2[..., None]) / denom[..., None]


def init_attention(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.q_dim, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(k4, cfg.q_dim, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim_,), dtype)
        p["k_norm"] = jnp.zeros((cfg.head_dim_,), dtype)
    return p


def _project_qkv(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = (x @ params["wq"]).reshape(B, S, H, D)
    k = (x @ params["wk"]).reshape(B, S, Hkv, D)
    v = (x @ params["wv"]).reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,Hkv,D), GQA via head grouping; fp32 softmax."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(D, jnp.float32))
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def _sdpa_flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       lengths: jnp.ndarray, *, chunk: int = 1024
                       ) -> jnp.ndarray:
    """One-token decode attention WITHOUT materializing (B, H, S) scores.

    lax.scan over KV chunks carrying fp32 (m, l, acc) — the HLO-level
    mirror of the Pallas flash-decoding kernel: per-chunk scores live in
    registers/VMEM-sized tiles, so HBM traffic collapses to the KV reads
    (§Perf cell A).  q (B,H,D); k/v (B,S,Hkv,D); lengths (B,) valid KVs.
    """
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    n = S // Q
    qg = (q.reshape(B, Hkv, G, D).astype(jnp.float32)
          / jnp.sqrt(jnp.asarray(D, jnp.float32)))
    kc = k.reshape(B, n, Q, Hkv, D).swapaxes(0, 1)
    vc = v.reshape(B, n, Q, Hkv, D).swapaxes(0, 1)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        i, kq, vq = inp
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kq.astype(jnp.float32))
        pos = i * Q + jnp.arange(Q)
        s = jnp.where(pos[None, None, None, :] < lengths[:, None, None, None],
                      s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = (alpha[..., None] * acc
               + jnp.einsum("bhgk,bkhd->bhgd", p, vq.astype(jnp.float32)))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n), kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, H, D).astype(q.dtype)


def attention_full(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                   positions: jnp.ndarray, *, prefix_len: int = 0,
                   impl: str = "reference",
                   cache_len: int = 0) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Train / prefill attention.  Returns (out, cache_or_None).

    cache_len > 0 => also emit a KV cache padded/cropped to that length.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    if impl == "flash":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, window=cfg.window,
                                     prefix_len=prefix_len)
    else:
        mask = causal_mask(positions, positions, window=cfg.window,
                           prefix_len=prefix_len)
        out = _sdpa(q, k, v, mask)
    out = out.reshape(B, S, cfg.q_dim) @ params["wo"]

    cache = None
    if cache_len:
        if cache_len >= S:
            pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
            cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        else:
            # sliding-window cache keeps the last `cache_len` KVs, stored in
            # ring order (row = position % cache_len) so that decode's
            # ring-buffer writes stay aligned.
            s0 = (S - cache_len) % cache_len
            cache = {"k": jnp.roll(k[:, -cache_len:], s0, axis=1),
                     "v": jnp.roll(v[:, -cache_len:], s0, axis=1)}
    return out, cache


def attention_chunk(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                    cache: Dict, start: jnp.ndarray, *,
                    impl: str = "reference",
                    length: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, Dict]:
    """Chunked prefill against an existing cache (engine path).

    x: (B, c, d) — the next c prompt tokens of each request, whose first
    absolute position is ``start[b]``; cache[k|v]: (B, Smax, Hkv, D) holds
    the first ``start[b]`` KVs (ring order when cfg.window, in which case
    c <= window is required so no in-chunk slot collision can occur).

    ``length`` (B,) marks only the first ``length[b]`` tokens of row b as
    real: the trailing tokens are shape padding (bucketed chunks, one
    compiled signature per bucket) whose KVs are routed to an
    out-of-bounds slot and dropped, so the cache after the call is
    bit-equal to an unpadded call of ``length[b]`` tokens.  Padded
    *queries* produce garbage rows the caller must ignore; padded *keys*
    never influence valid queries (their positions exceed every valid
    query position, and the causal mask excludes them).
    """
    B, c, _ = x.shape
    Smax = cache["k"].shape[1]
    positions = start[:, None] + jnp.arange(c)[None, :]        # (B, c)
    valid = (None if length is None
             else jnp.arange(c)[None, :] < length[:, None])    # (B, c)
    q, k, v = _project_qkv(params, cfg, x, positions)

    qpos = positions[:, :, None]                               # (B, c, 1)
    sidx = jnp.arange(Smax)[None, None, :]                     # (1, 1, Smax)
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, c))
    if cfg.window:
        if cfg.window != Smax:
            raise ValueError("window cache must be exactly window-sized")
        assert c <= cfg.window, (c, cfg.window)
        # Attend BEFORE writing: chunk tokens would overwrite ring slots
        # still visible to earlier in-chunk queries.  Keys = old ring
        # content (positions reconstructed per slot) ++ the chunk itself.
        prev_newest = (start - 1)[:, None, None]
        key_pos_old = prev_newest - jnp.mod(prev_newest - sidx, Smax)
        mask_old = ((key_pos_old >= 0) & (key_pos_old <= qpos)
                    & (qpos - key_pos_old < cfg.window))       # (B, c, Smax)
        kpos_new = positions[:, None, :]                       # (B, 1, c)
        mask_new = ((kpos_new <= qpos)
                    & (qpos - kpos_new < cfg.window))          # (B, c, c)
        keys = jnp.concatenate([cache["k"], k], axis=1)
        vals = jnp.concatenate([cache["v"], v], axis=1)
        mask = jnp.concatenate(
            [mask_old, jnp.broadcast_to(mask_new, (B, c, c))], axis=2)
        out = _sdpa(q, keys, vals, mask)
        slots = jnp.mod(positions, Smax)
    else:
        slots = jnp.minimum(positions, Smax - 1)
    if valid is not None:
        # padded-token writes go out of bounds and are dropped
        slots = jnp.where(valid, slots, Smax)
    new_k = cache["k"].at[rows, slots].set(k, mode="drop")
    new_v = cache["v"].at[rows, slots].set(v, mode="drop")
    if not cfg.window:
        mask = sidx <= qpos                                    # causal
        out = _sdpa(q, new_k, new_v, mask)
    out = out.reshape(B, c, cfg.q_dim) @ params["wo"]
    return out, {"k": new_k, "v": new_v}


def attention_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                     cache: Dict, cache_index: jnp.ndarray, *,
                     impl: str = "reference",
                     seq_shards: int = 1) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: (B, 1, d); cache[k|v]: (B, Smax, Hkv, D);
    cache_index: (B,) number of valid cache entries (also the position)."""
    B = x.shape[0]
    Smax = cache["k"].shape[1]
    positions = cache_index[:, None]  # (B, 1)
    q, k, v = _project_qkv(params, cfg, x, positions)

    if cfg.window and cfg.window < Smax:
        raise ValueError("window cache must be exactly window-sized")

    if cfg.window:
        # ring-buffer write for sliding-window cache
        slot = jnp.mod(cache_index, Smax)
    else:
        slot = jnp.minimum(cache_index, Smax - 1)
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])

    valid = jnp.arange(Smax)[None, :] < jnp.minimum(cache_index + 1, Smax)[:, None]
    if impl == "seqsharded":
        # shard_map region: each 'model' shard flash-decodes its slice of
        # the sequence, then one small psum combines (beyond-paper §Perf:
        # k-way seq-sharding multiplies aggregate HBM bandwidth for the
        # KV reads AND keeps score tensors shard-local)
        from repro.distributed.collectives import make_seqsharded_decode_attn
        from repro.distributed.context import get_mesh
        mesh = get_mesh()
        if mesh is None:
            raise ValueError("impl='seqsharded' needs distributed.context"
                             ".set_mesh(mesh)")
        fn = make_seqsharded_decode_attn(mesh)
        out = fn(q[:, 0], new_k, new_v, jnp.minimum(cache_index + 1, Smax))
    elif impl == "paged":
        from repro.kernels.paged_attention import ops as pa_ops
        out = pa_ops.decode_attention_dense(q[:, 0], new_k, new_v,
                                            jnp.minimum(cache_index + 1, Smax))
    elif impl == "flash_jnp":
        out = _sdpa_flash_decode(q[:, 0], new_k, new_v,
                                 jnp.minimum(cache_index + 1, Smax))
    else:
        mask = valid[:, None, :]  # (B, 1, Smax)
        out = _sdpa(q, new_k, new_v, mask)[:, 0]
    out = out.reshape(B, cfg.q_dim) @ params["wo"]
    return out[:, None, :], {"k": new_k, "v": new_v}


def attention_decode_deferred(params: Params, cfg: ModelConfig,
                              x: jnp.ndarray, cache: Dict,
                              cache_index: jnp.ndarray, *,
                              impl: str = "reference"
                              ) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode with a READ-ONLY cache (§Perf cell A).

    The new token's (k, v) are NOT written here — they are returned as a
    delta and scattered into the stacked cache ONCE per step by the
    caller (``decode_step(append='deferred')``), instead of once per
    layer: the per-layer dynamic-update-slice of the full (L, B, S, ...)
    buffer is what dominated the baseline's HBM-byte count.  Attention
    over the old cache is merged with the new token's contribution by a
    stable two-group softmax combine.
    """
    B = x.shape[0]
    Smax = cache["k"].shape[1]
    positions = cache_index[:, None]
    q, k, v = _project_qkv(params, cfg, x, positions)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]         # (B, H*, D)
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    G = H // Hkv

    # valid OLD entries: index, capped by the ring size; when the ring is
    # full the slot the new token will overwrite has EXPIRED (its
    # position is index - W, outside the window) -> mask it out.
    n_valid = jnp.minimum(cache_index, Smax)
    valid = jnp.arange(Smax)[None, :] < n_valid[:, None]
    if cfg.window:
        expired = (jnp.arange(Smax)[None, :] == jnp.mod(cache_index, Smax)[:, None]) \
            & (cache_index[:, None] >= Smax)
        valid &= ~expired

    if impl == "seqsharded":
        from repro.distributed.collectives import (
            make_seqsharded_decode_attn_partials)
        from repro.distributed.context import get_mesh
        mesh = get_mesh()
        if mesh is None:
            raise ValueError("impl='seqsharded' needs set_mesh(mesh)")
        out1, m1, l1 = make_seqsharded_decode_attn_partials(mesh)(
            q1, cache["k"], cache["v"], n_valid)
    else:
        from repro.distributed.collectives import decode_attn_partials
        out1, m1, l1 = decode_attn_partials(q1, cache["k"], cache["v"],
                                            valid)

    # new token's own logit/value per q head
    qg = q1.reshape(B, Hkv, G, D).astype(jnp.float32)
    s2 = jnp.einsum("bhgd,bhd->bhg", qg, k1.astype(jnp.float32))
    s2 = s2 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    v2 = jnp.broadcast_to(v1.astype(jnp.float32)[:, :, None, :],
                          (B, Hkv, G, D))
    out = merge_softmax_groups(
        out1.reshape(B, Hkv, G, D).astype(jnp.float32),
        m1.reshape(B, Hkv, G), l1.reshape(B, Hkv, G), s2, v2)
    out = out.reshape(B, cfg.q_dim).astype(x.dtype) @ params["wo"]
    return out[:, None, :], {"k_new": k1, "v_new": v1}
