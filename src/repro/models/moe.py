"""Mixture-of-Experts layer (GShard-style dense dispatch, EP-shardable).

Expert weights are stacked ``(E, d_model, moe_ff)`` so the expert axis can
be sharded over the ``model`` mesh axis (expert parallelism).  Routing uses
top-k with softmax-after-topk (Qwen style) and a capacity-free dense
dispatch: every token's expert contributions are computed with one-hot
combine einsums.  Padding experts (qwen2-moe 60->64) receive -inf router
logits and therefore exactly zero weight.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import shard_map_compat
from repro.models.common import Params, dense_init, gated_mlp, gated_mlp_init


def init_moe(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    E = cfg.padded_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], cfg.d_model, E, dtype),
        "wi_gate": (jax.random.normal(ks[1], (E, cfg.d_model, cfg.moe_d_ff), jnp.float32)
                    * (cfg.d_model ** -0.5)).astype(dtype),
        "wi_up": (jax.random.normal(ks[2], (E, cfg.d_model, cfg.moe_d_ff), jnp.float32)
                  * (cfg.d_model ** -0.5)).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, cfg.moe_d_ff, cfg.d_model), jnp.float32)
               * (cfg.moe_d_ff ** -0.5)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = gated_mlp_init(
            ks[4], cfg.d_model, cfg.num_shared_experts * cfg.moe_d_ff, dtype)
        p["shared_gate"] = dense_init(ks[4], cfg.d_model, 1, dtype)
    return p


def apply_moe(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, K = cfg.padded_experts, cfg.experts_per_token
    xt = x.reshape(B * S, d)

    logits = (xt @ params["router"]).astype(jnp.float32)  # (T, E)
    if E > cfg.num_experts:  # mask padding experts out of routing
        pad_mask = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    topv, topi = jax.lax.top_k(logits, K)                  # (T, K)
    weights = jax.nn.softmax(topv, axis=-1)                # softmax over top-k
    # combine weights as a dense (T, E) matrix
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)    # (T, K, E)
    combine = jnp.einsum("tk,tke->te", weights, onehot)    # (T, E)

    # dense dispatch: every expert sees every token, weighted combine.
    gate = jnp.einsum("td,edf->tef", xt, params["wi_gate"])
    up = jnp.einsum("td,edf->tef", xt, params["wi_up"])
    h = jax.nn.silu(gate) * up                              # (T, E, f)
    out = jnp.einsum("tef,efd->ted", h, params["wo"])       # (T, E, d)
    y = jnp.einsum("te,ted->td", combine.astype(out.dtype), out)

    if cfg.num_shared_experts:
        sg = jax.nn.sigmoid((xt @ params["shared_gate"]).astype(jnp.float32))
        y = y + (sg.astype(xt.dtype) * gated_mlp(params["shared"], xt))
    return y.reshape(B, S, d)


def apply_moe_ep(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                 capacity_factor: float = 1.25,
                 ep_axis: str = "model") -> jnp.ndarray:
    """Expert-parallel MoE via shard_map + all_to_all (§Perf cell B).

    Under plain GSPMD the scatter-add token buffers of
    ``apply_moe_sparse`` force replication of the expert einsums
    (measured: ~1000x the active FLOPs at 256 chips).  This is the
    production dispatch: experts live sharded over ``model``; each
    device routes its local tokens, packs per-destination-shard
    capacity buffers, exchanges them with ONE all_to_all, computes its
    local experts, and returns results with a second all_to_all.
    Per-device expert FLOPs ~= capacity_factor^2 * T_local * K / E_shards
    rows — i.e. the active compute, not E copies of it.

    Tokens overflowing a (src, dst) pair's capacity are dropped (GShard
    semantics); parity with ``apply_moe`` holds when nothing overflows.
    """
    from repro.distributed.context import get_mesh
    mesh = get_mesh()
    if mesh is None:
        raise ValueError("apply_moe_ep requires distributed.context"
                         ".set_mesh(mesh)")
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n = mesh.shape[ep_axis]
    B, S, d = x.shape
    E, K, ff = cfg.padded_experts, cfg.experts_per_token, cfg.moe_d_ff
    e_loc = E // n

    def local(xb, router, wg, wu, wo):
        T = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(T, d)
        # ---- routing: full logits from the (replicated, tiny) router -- #
        # (router must NOT be expert-sharded here: with tokens row-
        # sharded over the ep axis, gathering column blocks would mix
        # different ranks' tokens)
        logits = (xt @ router).astype(jnp.float32)          # (T, E)
        if E > cfg.num_experts:
            pad = jnp.arange(E) >= cfg.num_experts
            logits = jnp.where(pad[None, :], -1e30, logits)
        topv, topi = jax.lax.top_k(logits, K)               # (T, K)
        weights = jax.nn.softmax(topv, axis=-1)
        dest = topi // e_loc                                 # target shard
        local_eid = topi % e_loc

        # ---- pack per destination shard ------------------------------- #
        cap = max(1, int(capacity_factor * T * K / n))
        flat_dest = dest.reshape(-1)                         # (T*K,)
        oh = jax.nn.one_hot(flat_dest, n, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=-1)
        keep = pos < cap
        slot = flat_dest * cap + jnp.where(keep, pos, 0)
        tok_idx = jnp.repeat(jnp.arange(T), K)
        send_x = jnp.zeros((n * cap, d), xt.dtype).at[slot].add(
            jnp.where(keep[:, None], xt[tok_idx], 0))
        send_e = jnp.zeros((n * cap,), jnp.int32).at[slot].add(
            jnp.where(keep, local_eid.reshape(-1) + 1, 0))

        recv_x = jax.lax.all_to_all(send_x.reshape(n, cap, d), ep_axis,
                                    0, 0).reshape(n * cap, d)
        recv_e = jax.lax.all_to_all(send_e.reshape(n, cap), ep_axis,
                                    0, 0).reshape(n * cap)

        # ---- local expert compute (capacity buffers) ------------------ #
        R = n * cap
        valid = recv_e > 0
        eid = jnp.maximum(recv_e - 1, 0)
        oh2 = jax.nn.one_hot(eid, e_loc, dtype=jnp.int32) * valid[:, None]
        pos2 = jnp.sum((jnp.cumsum(oh2, axis=0) - 1) * oh2, axis=-1)
        cap2 = max(1, int(capacity_factor * R / e_loc))
        keep2 = (pos2 < cap2) & valid
        slot2 = eid * cap2 + jnp.where(keep2, pos2, 0)
        buf = jnp.zeros((e_loc * cap2, d), xt.dtype).at[slot2].add(
            jnp.where(keep2[:, None], recv_x, 0)).reshape(e_loc, cap2, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg,
                                   preferred_element_type=jnp.float32))
        h = (h * jnp.einsum("ecd,edf->ecf", buf, wu,
                            preferred_element_type=jnp.float32)).astype(xt.dtype)
        out = jnp.einsum("ecf,efd->ecd", h, wo,
                         preferred_element_type=jnp.float32
                         ).reshape(e_loc * cap2, d).astype(xt.dtype)
        y_rows = out[slot2] * keep2[:, None].astype(out.dtype)

        # ---- return + combine at source ------------------------------- #
        ret = jax.lax.all_to_all(y_rows.reshape(n, cap, d), ep_axis,
                                 0, 0).reshape(n * cap, d)
        y_tk = ret[slot] * keep[:, None].astype(ret.dtype)
        y_tk = y_tk * weights.reshape(-1)[:, None].astype(ret.dtype)
        y = jnp.zeros((T, d), ret.dtype).at[tok_idx].add(y_tk)
        return y.reshape(xb.shape)

    # Shard the SEQUENCE over the expert axis for dispatch whenever it
    # divides: otherwise every model-rank routes (and all_to_alls) the
    # same replicated tokens — n x duplicate traffic (measured 16x on
    # cell B).  Decode steps (S=1) fall back to replicated dispatch.
    seq_spec = ep_axis if S % n == 0 else None
    y = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(dp, seq_spec, None), P(None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=P(dp, seq_spec, None),
        check_vma=False,
    )(x, params["router"], params["wi_gate"], params["wi_up"],
      params["wo"])

    if cfg.num_shared_experts:
        xt = x.reshape(B * S, d)
        sg = jax.nn.sigmoid((xt @ params["shared_gate"]).astype(jnp.float32))
        y = y + (sg.astype(xt.dtype)
                 * gated_mlp(params["shared"], xt)).reshape(B, S, d)
    return y


def apply_moe_sparse(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                     capacity_factor: float = 1.25) -> jnp.ndarray:
    """Capacity-based sparse dispatch (per-expert token buffers).

    FLOPs ~= K/E of the dense dispatch; used for the optimized serving path
    and the perf hillclimb.  Tokens overflowing an expert's capacity are
    dropped (standard GShard semantics) — parity with ``apply_moe`` holds
    whenever no overflow occurs.
    """
    B, S, d = x.shape
    E, K = cfg.padded_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)
    C = max(1, int(capacity_factor * T * K / E))  # repro: allow-recompile-hazard(capacity_factor is a static Python float kwarg; C is host arithmetic fixing the dispatch shape, one trace per factor)

    logits = (xt @ params["router"]).astype(jnp.float32)
    if E > cfg.num_experts:
        logits = jnp.where((jnp.arange(E) >= cfg.num_experts)[None, :], -1e30, logits)
    topv, topi = jax.lax.top_k(logits, K)
    weights = jax.nn.softmax(topv, axis=-1)  # (T, K)

    # position of each (token, k) inside its expert's buffer
    flat_e = topi.reshape(-1)                                  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (T*K, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot       # (T*K, E)
    pos = jnp.sum(pos_in_e, axis=-1)                           # (T*K,)
    keep = pos < C
    buf_idx = flat_e * C + jnp.where(keep, pos, 0)             # (T*K,)

    tok_idx = jnp.repeat(jnp.arange(T), K)
    gathered = xt[tok_idx]                                     # (T*K, d)
    buffers = jnp.zeros((E * C, d), xt.dtype)
    buffers = buffers.at[buf_idx].add(jnp.where(keep[:, None], gathered, 0))
    buffers = buffers.reshape(E, C, d)

    gate = jnp.einsum("ecd,edf->ecf", buffers, params["wi_gate"])
    up = jnp.einsum("ecd,edf->ecf", buffers, params["wi_up"])
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, params["wo"]).reshape(E * C, d)

    y_tk = out[buf_idx] * jnp.where(keep[:, None], 1.0, 0.0).astype(out.dtype)
    y_tk = y_tk * weights.reshape(-1)[:, None].astype(out.dtype)
    y = jnp.zeros((T, d), out.dtype).at[tok_idx].add(y_tk)

    if cfg.num_shared_experts:
        sg = jax.nn.sigmoid((xt @ params["shared_gate"]).astype(jnp.float32))
        y = y + (sg.astype(xt.dtype) * gated_mlp(params["shared"], xt))
    return y.reshape(B, S, d)
