"""Shared layers/utilities for the model zoo (raw-JAX pytree params)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple:
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S)."""
    d = x.shape[-1]
    cos, sin = rope_angles(positions, d, theta)  # (B, S, d/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """positions (B, S) -> (B, S, dim) float32 sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          vocab_size: int) -> jnp.ndarray:
    """Mean token loss; labels < 0 are masked.  logits (..., Vpad)."""
    logits = logits.astype(jnp.float32)
    # padded vocab entries must not receive probability mass
    if logits.shape[-1] > vocab_size:
        neg = jnp.full((logits.shape[-1] - vocab_size,), -1e9, dtype=jnp.float32)
        logits = logits.at[..., vocab_size:].set(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def gated_mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def gated_mlp(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    gate = x @ params["wi_gate"]
    up = x @ params["wi_up"]
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    return (fn(gate) * up) @ params["wo"]


def causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                window: int = 0, prefix_len: int = 0) -> jnp.ndarray:
    """Boolean (…, Sq, Sk) mask. prefix-LM: keys/queries with pos <
    prefix_len are bidirectional (PaliGemma image prefix)."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    if prefix_len:
        # prefix-LM: prefix keys are visible to every query (bidirectional
        # within the prefix, and always-visible context for the suffix)
        m |= k_pos[..., None, :] < prefix_len
    return m
