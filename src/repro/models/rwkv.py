"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

Recurrence per head (state S in R^{D x D}):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t S_{t-1} + (r_t . u . k_t) v_t        (u = per-channel bonus)

Full-sequence mode uses the *chunked* linear-attention form (GLA-style):
within a chunk of Q steps, cumulative decays W_t = prod_{s<=t} w_s give
    y_t = (r_t . W_{t-1}) S_0
          + sum_{s<t} <r_t . W_{t-1}/W_s, k_s> v_s + <r_t . u, k_t> v_t
    S_Q = diag(W_Q) S_0 + sum_s diag(W_Q/W_s) k_s^T v_s
so the state is materialized once per chunk, not per token.  Decode is the
O(1) recurrence.  Data-dependent decay w_t and token-shift mixes follow the
Finch low-rank parameterization (simplified: single LoRA per projection).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init, rms_norm

LORA_R = 32


def init_rwkv_layer(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    p = {
        # time-mix
        "mu": (jax.random.uniform(ks[0], (4, d)) * 0.5 + 0.25).astype(jnp.float32),
        "wr": dense_init(ks[1], d, d, dtype),
        "wk": dense_init(ks[2], d, d, dtype),
        "wv": dense_init(ks[3], d, d, dtype),
        "wg": dense_init(ks[4], d, d, dtype),
        "wo": dense_init(ks[5], d, d, dtype),
        "decay_lora_a": dense_init(ks[6], d, LORA_R, dtype),
        "decay_lora_b": dense_init(ks[7], LORA_R, d, dtype),
        "decay_base": (jnp.linspace(-6.0, -1.0, d)).astype(jnp.float32),
        "bonus": (jnp.zeros((d,))).astype(jnp.float32),
        "ln_x": jnp.zeros((d,), dtype),  # per-head groupnorm scale
        # channel-mix
        "cm_mu": (jax.random.uniform(ks[8], (2, d)) * 0.5 + 0.25).astype(jnp.float32),
        "cm_r": dense_init(ks[9], d, d, dtype),
        "cm_k": dense_init(ks[10], d, cfg.d_ff, dtype),
        "cm_v": dense_init(ks[11], cfg.d_ff, d, dtype),
    }
    return p


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """x (B,S,d), prev (B,1,d) = last token of previous segment."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def init_rwkv_state(cfg: ModelConfig, batch: int) -> Dict:
    H, D = cfg.ssm_heads, cfg.ssm_state
    return {
        "S": jnp.zeros((batch, H, D, D), jnp.float32),
        "x_tm": jnp.zeros((batch, 1, cfg.d_model), jnp.dtype(cfg.dtype)),
        "x_cm": jnp.zeros((batch, 1, cfg.d_model), jnp.dtype(cfg.dtype)),
    }


def _rkvwg(params, cfg, x, shifted):
    """Shared projection block. Returns r,k,v (B,S,H,D), w (decay), g."""
    B, S, d = x.shape
    H, D = cfg.ssm_heads, cfg.ssm_state
    mu = params["mu"]
    xr = _mix(x, shifted, mu[0].astype(x.dtype))
    xk = _mix(x, shifted, mu[1].astype(x.dtype))
    xv = _mix(x, shifted, mu[2].astype(x.dtype))
    xw = _mix(x, shifted, mu[3].astype(x.dtype))
    r = (xr @ params["wr"]).reshape(B, S, H, D)
    k = (xk @ params["wk"]).reshape(B, S, H, D)
    v = (xv @ params["wv"]).reshape(B, S, H, D)
    g = jax.nn.silu(xv @ params["wg"])
    dd = (xw @ params["decay_lora_a"]) @ params["decay_lora_b"]
    logw = params["decay_base"].astype(jnp.float32) + jnp.tanh(dd.astype(jnp.float32))
    w = jnp.exp(-jnp.exp(logw)).reshape(B, S, H, D)  # in (0,1)
    return r, k, v, w, g


def _out_norm(params, cfg, y, g, dtype):
    """Per-head RMS norm + gate + out projection. y (B,S,H,D) fp32."""
    B, S, H, D = y.shape
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(B, S, H * D) * (1.0 + params["ln_x"].astype(jnp.float32))
    return (y.astype(dtype) * g) @ params["wo"]


def rwkv_time_mix_full(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                       state: Dict, chunk: int = 64,
                       length=None) -> Tuple[jnp.ndarray, Dict]:
    """``length`` (B,) marks only the first ``length[b]`` steps of row b
    as real.  Padded steps are forced to the recurrence identity
    (w = 1, k = 0, so S_t = S_{t-1}) and the carried token-shift sample
    is the last *valid* token, making the final state bit-equal to an
    unpadded run (length 0 = untouched row)."""
    B, S, d = x.shape
    H, D = cfg.ssm_heads, cfg.ssm_state
    from repro.models.ssm import pick_chunk
    Q = pick_chunk(S, chunk)
    shifted = _token_shift(x, state["x_tm"])
    r, k, v, w, g = _rkvwg(params, cfg, x, shifted)
    if length is not None:
        valid = (jnp.arange(S)[None, :] < length[:, None])[..., None, None]
        w = jnp.where(valid, w, 1.0)
        k = jnp.where(valid, k, jnp.zeros_like(k))
    u = jnp.exp(params["bonus"]).reshape(H, D)

    nc = S // Q
    as_chunks = lambda t: t.reshape(B, nc, Q, H, D).transpose(1, 0, 3, 2, 4)
    r_c, k_c, v_c, w_c = map(as_chunks, (r.astype(jnp.float32), k.astype(jnp.float32),
                                         v.astype(jnp.float32), w))
    # (nc, B, H, Q, D) each

    def chunk_step(S0, inp):
        rq, kq, vq, wq = inp
        logW = jnp.cumsum(jnp.log(wq), axis=2)              # (B,H,Q,D)
        W = jnp.exp(logW)
        Wm1 = jnp.exp(logW - jnp.log(wq))                   # W_{t-1} = W_t / w_t
        # inter-chunk: y_inter[t] = (r_t . W_{t-1}) @ S0
        y_inter = jnp.einsum("bhqd,bhde->bhqe", rq * Wm1, S0)
        # intra-chunk (strictly lower triangular):
        att = jnp.einsum("bhqd,bhsd->bhqs", rq * Wm1, kq / W)
        tri = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhqs,bhse->bhqe", att, vq)
        # current-token bonus
        y_diag = jnp.einsum("bhqd,bhqd->bhq", rq * u[None, :, None, :], kq)[..., None] * vq
        # carry: S_Q = diag(W_Q) S0 + sum_s diag(W_Q/W_s) k_s^T v_s
        WQ = W[:, :, -1]                                    # (B,H,D)
        S_new = WQ[..., None] * S0 + jnp.einsum(
            "bhsd,bhse->bhde", kq * (WQ[:, :, None, :] / W), vq)
        return S_new, y_inter + y_intra + y_diag

    S_last, y = jax.lax.scan(chunk_step, state["S"], (r_c, k_c, v_c, w_c))
    y = y.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)      # back to (B,S,H,D)
    out = _out_norm(params, cfg, y, g, x.dtype)
    x_tm = _last_valid(x, length, state["x_tm"])
    return out, {"S": S_last, "x_tm": x_tm, "x_cm": state["x_cm"]}


def _last_valid(x: jnp.ndarray, length, fallback: jnp.ndarray) -> jnp.ndarray:
    """Token-shift carry: last valid token of x (B,S,d), or ``fallback``
    (B,1,d) for rows with length 0.  length None = whole row valid."""
    if length is None:
        return x[:, -1:]
    last = jnp.maximum(length - 1, 0)
    picked = jnp.take_along_axis(x, last[:, None, None], axis=1)
    return jnp.where((length > 0)[:, None, None], picked, fallback)


def rwkv_time_mix_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                         state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, d)."""
    B = x.shape[0]
    H, D = cfg.ssm_heads, cfg.ssm_state
    shifted = state["x_tm"]
    r, k, v, w, g = _rkvwg(params, cfg, x, shifted)
    u = jnp.exp(params["bonus"]).reshape(H, D)
    r1, k1, v1, w1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    S0 = state["S"]                                          # (B,H,D,D)
    y = jnp.einsum("bhd,bhde->bhe", r1, S0)
    y = y + jnp.einsum("bhd,bhd->bh", r1 * u[None], k1)[..., None] * v1
    S_new = w1[..., None] * S0 + jnp.einsum("bhd,bhe->bhde", k1, v1)
    out = _out_norm(params, cfg, y[:, None].reshape(B, 1, H, D), g, x.dtype)
    return out, {"S": S_new, "x_tm": x, "x_cm": state["x_cm"]}


def rwkv_channel_mix(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                     prev: jnp.ndarray,
                     length=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Channel-mix FFN with token shift; returns (out, new_prev).
    ``length`` (B,) makes new_prev the last *valid* token per row."""
    shifted = _token_shift(x, prev)
    mu = params["cm_mu"]
    xr = _mix(x, shifted, mu[0].astype(x.dtype))
    xk = _mix(x, shifted, mu[1].astype(x.dtype))
    rgate = jax.nn.sigmoid((xr @ params["cm_r"]).astype(jnp.float32)).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ params["cm_k"]))
    return rgate * (kk @ params["cm_v"]), _last_valid(x, length, prev)
