"""Decoder-LM assembly for all families, with lax.scan over stacked layers.

Families:
  dense / moe / vlm / audio : transformer blocks (attention + MLP/MoE)
  hybrid (hymba)            : parallel attention || SSM heads, then MLP
  ssm (rwkv6)               : time-mix + channel-mix

Public API (all functional):
  init_params(cfg, rng)                       -> params pytree
  train_loss(cfg, params, batch, impl=...)    -> scalar loss
  prefill(cfg, params, batch, cache_len, ...) -> (last_logits, cache)
  decode_step(cfg, params, tokens, cache, ...)-> (logits, cache)
  init_cache(cfg, batch, cache_len)           -> cache pytree

The cache pytree always carries "index" (B,) = number of tokens already in
context (== next absolute position).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (Params, embed_init, gated_mlp,
                                 gated_mlp_init, rms_norm,
                                 sinusoidal_pos_emb, softmax_cross_entropy)

# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #


def _init_layer(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), dtype),
                 "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.family == "ssm":
        p.update(rwkv_mod.init_rwkv_layer(ks[0], cfg))
        return p
    p["attn"] = attn.init_attention(ks[0], cfg)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
        p["ln_attn"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln_ssm"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.num_experts:
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
    else:
        p["mlp"] = gated_mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_head, k_layers, k_patch = jax.random.split(rng, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys)
    params: Params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model, dtype)
    if cfg.frontend == "patch":
        # stub projection applied to precomputed patch embeddings
        params["patch_proj"] = embed_init(k_patch, cfg.d_model, cfg.d_model, dtype)
    return params


# --------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------- #


def _mlp_or_moe(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
                moe_impl: str) -> jnp.ndarray:
    if cfg.num_experts:
        fn = {"dense": moe_mod.apply_moe,
              "sparse": moe_mod.apply_moe_sparse,
              "ep": moe_mod.apply_moe_ep}[moe_impl]
        return fn(lp["moe"], cfg, x)
    act = "gelu" if cfg.family == "vlm" else "silu"
    return gated_mlp(lp["mlp"], x, act=act)


def _block_full(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
                positions: jnp.ndarray, prefix_len: int, impl: str,
                moe_impl: str, cache_len: int) -> Tuple[jnp.ndarray, Any]:
    """Full-sequence transformer/hybrid/ssm block. Returns (x, cache)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_cache: Dict[str, Any] = {}
    if cfg.family == "ssm":
        tm_state = rwkv_mod.init_rwkv_state(cfg, x.shape[0])
        y, st = rwkv_mod.rwkv_time_mix_full(lp, cfg, h, tm_state)
        x = x + y
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        cm, x_cm = rwkv_mod.rwkv_channel_mix(lp, cfg, h2, tm_state["x_cm"])
        x = x + cm
        st["x_cm"] = x_cm
        return x, st
    if cfg.family == "hybrid":
        a, kv = attn.attention_full(lp["attn"], cfg, h, positions,
                                    prefix_len=prefix_len, impl=impl,
                                    cache_len=min(cache_len, cfg.window) if cache_len else 0)
        s, ssm_state = ssm_mod.apply_ssm_full(lp["ssm"], cfg, h)
        y = 0.5 * (rms_norm(a, lp["ln_attn"], cfg.norm_eps)
                   + rms_norm(s, lp["ln_ssm"], cfg.norm_eps))
        x = x + y
        if cache_len:  # repro: allow-recompile-hazard(cache_len is a static Python int closed over per plane; one specialization per cache length by design)
            new_cache = {"k": kv["k"], "v": kv["v"],
                         "h": ssm_state["h"], "conv": ssm_state["conv"]}
    else:
        a, kv = attn.attention_full(lp["attn"], cfg, h, positions,
                                    prefix_len=prefix_len, impl=impl,
                                    cache_len=cache_len)
        x = x + a
        if cache_len:  # repro: allow-recompile-hazard(cache_len is a static Python int closed over per plane; one specialization per cache length by design)
            new_cache = {"k": kv["k"], "v": kv["v"]}
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + _mlp_or_moe(cfg, lp, h2, moe_impl)
    return x, new_cache


def _block_chunk(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
                 layer_cache: Dict, start: jnp.ndarray, impl: str,
                 moe_impl: str,
                 length: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, Dict]:
    """Chunked-prefill block: continue from an existing per-layer cache.
    x: (B, c, d); start: (B,) absolute position of the chunk's first token.
    ``length`` (B,) marks the real (non-padding) prefix of each row —
    padded steps must leave the cache/recurrent state untouched.
    """
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        st = {"S": layer_cache["S"], "x_tm": layer_cache["x_tm"],
              "x_cm": layer_cache["x_cm"]}
        y, st = rwkv_mod.rwkv_time_mix_full(lp, cfg, h, st, length=length)
        x = x + y
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        cm, x_cm = rwkv_mod.rwkv_channel_mix(lp, cfg, h2, st["x_cm"],
                                             length=length)
        x = x + cm
        st["x_cm"] = x_cm
        return x, st
    if cfg.family == "hybrid":
        kv = {"k": layer_cache["k"], "v": layer_cache["v"]}
        a, kv = attn.attention_chunk(lp["attn"], cfg, h, kv, start, impl=impl,
                                     length=length)
        sst = {"h": layer_cache["h"], "conv": layer_cache["conv"]}
        s, sst = ssm_mod.apply_ssm_full(lp["ssm"], cfg, h, state=sst,
                                        length=length)
        y = 0.5 * (rms_norm(a, lp["ln_attn"], cfg.norm_eps)
                   + rms_norm(s, lp["ln_ssm"], cfg.norm_eps))
        x = x + y
        new_cache = {"k": kv["k"], "v": kv["v"], "h": sst["h"],
                     "conv": sst["conv"]}
    else:
        kv = {"k": layer_cache["k"], "v": layer_cache["v"]}
        a, kv = attn.attention_chunk(lp["attn"], cfg, h, kv, start, impl=impl,
                                     length=length)
        x = x + a
        new_cache = {"k": kv["k"], "v": kv["v"]}
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + _mlp_or_moe(cfg, lp, h2, moe_impl)
    return x, new_cache


def _block_decode(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
                  layer_cache: Dict, cache_index: jnp.ndarray, impl: str,
                  moe_impl: str) -> Tuple[jnp.ndarray, Dict]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        st = {"S": layer_cache["S"], "x_tm": layer_cache["x_tm"],
              "x_cm": layer_cache["x_cm"]}
        y, st = rwkv_mod.rwkv_time_mix_decode(lp, cfg, h, st)
        x = x + y
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        cm, x_cm = rwkv_mod.rwkv_channel_mix(lp, cfg, h2, st["x_cm"])
        x = x + cm
        st["x_cm"] = x_cm
        return x, st
    if cfg.family == "hybrid":
        kv = {"k": layer_cache["k"], "v": layer_cache["v"]}
        a, kv = attn.attention_decode(lp["attn"], cfg, h, kv, cache_index,
                                      impl=impl)
        sst = {"h": layer_cache["h"], "conv": layer_cache["conv"]}
        s, sst = ssm_mod.apply_ssm_decode(lp["ssm"], cfg, h, sst)
        y = 0.5 * (rms_norm(a, lp["ln_attn"], cfg.norm_eps)
                   + rms_norm(s, lp["ln_ssm"], cfg.norm_eps))
        x = x + y
        new_cache = {"k": kv["k"], "v": kv["v"], "h": sst["h"],
                     "conv": sst["conv"]}
    else:
        kv = {"k": layer_cache["k"], "v": layer_cache["v"]}
        a, kv = attn.attention_decode(lp["attn"], cfg, h, kv, cache_index,
                                      impl=impl)
        x = x + a
        new_cache = {"k": kv["k"], "v": kv["v"]}
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + _mlp_or_moe(cfg, lp, h2, moe_impl)
    return x, new_cache


# --------------------------------------------------------------------- #
# embedding / head / frontends
# --------------------------------------------------------------------- #


def _embed(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
           positions: jnp.ndarray,
           patch_embeds: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, int]:
    """Returns (x (B,S,d), prefix_len)."""
    x = params["embed"][tokens]
    prefix_len = 0
    if cfg.family in ("vlm",):
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(x.dtype)
    if cfg.frontend == "patch" and patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
        prefix_len = patch_embeds.shape[1]
    if cfg.family == "audio":
        x = x + sinusoidal_pos_emb(positions if prefix_len == 0 else
                                   jnp.arange(x.shape[1])[None, :],
                                   cfg.d_model).astype(x.dtype)
    return x, prefix_len


def _logits(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return jnp.einsum("...d,vd->...v", x, head)


def _scan_layers(cfg: ModelConfig, params: Params, x, body,
                 unroll: bool = False):
    """lax.scan over stacked layer params (+ optional cache xs/ys).
    ``unroll=True`` linearizes the graph so compiled.cost_analysis()
    counts every layer (XLA under-counts while-loop bodies) — dry-run
    accuracy mode; runtime behaviour is identical."""
    return jax.lax.scan(body, x, params["layers"], unroll=unroll)


# --------------------------------------------------------------------- #
# public entry points
# --------------------------------------------------------------------- #


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
               *, impl: str = "reference", moe_impl: str = "sparse",
               remat: bool = True, unroll: bool = False) -> jnp.ndarray:
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, prefix_len = _embed(cfg, params, tokens, positions,
                           batch.get("patch_embeds"))
    Sx = x.shape[1]
    pos_x = jnp.broadcast_to(jnp.arange(Sx)[None, :], (B, Sx))

    def body(xc, lp):
        xc, _ = _block_full(cfg, lp, xc, pos_x, prefix_len, impl, moe_impl, 0)
        return xc, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = _scan_layers(cfg, params, x, body, unroll=unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if prefix_len:
        x = x[:, prefix_len:]
    logits = _logits(cfg, params, x)
    return softmax_cross_entropy(logits, labels, cfg.vocab_size)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> Dict[str, Any]:
    """Empty cache pytree (used by the serving engine)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    cache: Dict[str, Any] = {"index": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        st = rwkv_mod.init_rwkv_state(cfg, batch)
        cache.update({k: jnp.stack([v] * L) for k, v in st.items()})
        return cache
    eff_len = min(cache_len, cfg.window) if cfg.window else cache_len
    cache["k"] = jnp.zeros((L, batch, eff_len, cfg.num_kv_heads, cfg.head_dim_), dtype)
    cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.family == "hybrid":
        st = ssm_mod.init_ssm_state(cfg, batch)
        cache["h"] = jnp.stack([st["h"]] * L)
        cache["conv"] = jnp.stack([st["conv"]] * L)
    return cache


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            cache_len: int, *, impl: str = "reference",
            moe_impl: str = "sparse",
            unroll: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """Process the whole prompt; returns (last-token logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, prefix_len = _embed(cfg, params, tokens, positions,
                           batch.get("patch_embeds"))
    Sx = x.shape[1]
    pos_x = jnp.broadcast_to(jnp.arange(Sx)[None, :], (B, Sx))

    def body(xc, lp):
        xc, layer_cache = _block_full(cfg, lp, xc, pos_x, prefix_len, impl,
                                      moe_impl, cache_len)
        return xc, layer_cache

    x, caches = _scan_layers(cfg, params, x, body, unroll=unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _logits(cfg, params, x[:, -1])
    length = batch.get("length")
    index = (jnp.full((B,), Sx, jnp.int32) if length is None
             else length.astype(jnp.int32) + prefix_len)
    cache = dict(caches)
    cache["index"] = index
    return logits, cache


def _block_decode_deferred(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
                           layer_cache: Dict, cache_index: jnp.ndarray,
                           impl: str, moe_impl: str
                           ) -> Tuple[jnp.ndarray, Dict]:
    """Decode block with READ-ONLY KV cache; returns per-layer deltas
    (new k/v row, or full recurrent states) instead of updated caches."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        st = {"S": layer_cache["S"], "x_tm": layer_cache["x_tm"],
              "x_cm": layer_cache["x_cm"]}
        y, st = rwkv_mod.rwkv_time_mix_decode(lp, cfg, h, st)
        x = x + y
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        cm, x_cm = rwkv_mod.rwkv_channel_mix(lp, cfg, h2, st["x_cm"])
        x = x + cm
        st["x_cm"] = x_cm
        return x, st                       # states ARE the delta
    if cfg.family == "hybrid":
        kv_ro = {"k": layer_cache["k"], "v": layer_cache["v"]}
        a, delta = attn.attention_decode_deferred(lp["attn"], cfg, h, kv_ro,
                                                  cache_index, impl=impl)
        sst = {"h": layer_cache["h"], "conv": layer_cache["conv"]}
        s, sst = ssm_mod.apply_ssm_decode(lp["ssm"], cfg, h, sst)
        y = 0.5 * (rms_norm(a, lp["ln_attn"], cfg.norm_eps)
                   + rms_norm(s, lp["ln_ssm"], cfg.norm_eps))
        x = x + y
        delta = {"k_new": delta["k_new"], "v_new": delta["v_new"],
                 "h": sst["h"], "conv": sst["conv"]}
    else:
        kv_ro = {"k": layer_cache["k"], "v": layer_cache["v"]}
        a, delta = attn.attention_decode_deferred(lp["attn"], cfg, h, kv_ro,
                                                  cache_index, impl=impl)
        x = x + a
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + _mlp_or_moe(cfg, lp, h2, moe_impl)
    return x, delta


def decode_step_deferred(cfg: ModelConfig, params: Params,
                         tokens: jnp.ndarray, cache: Dict[str, Any], *,
                         impl: str = "reference", moe_impl: str = "sparse",
                         unroll: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """One decode step with DEFERRED cache append (§Perf cell A): the
    layer scan reads the cache, collects per-layer new-KV deltas, and a
    SINGLE scatter per step writes them — eliminating the per-layer
    full-buffer dynamic-update-slice that dominates the baseline's HBM
    bytes.  Numerically equivalent to ``decode_step`` (tested)."""
    if tokens.ndim == 1:
        tokens = tokens[:, None]
    B = tokens.shape[0]
    index = cache["index"]
    x, _ = _embed(cfg, params, tokens, index[:, None], None)
    layer_caches = {k: v for k, v in cache.items() if k != "index"}

    def body(xc, per_layer):
        lp, lc = per_layer
        xc, delta = _block_decode_deferred(cfg, lp, xc, lc, index, impl,
                                           moe_impl)
        return xc, delta

    x, deltas = jax.lax.scan(body, x, (params["layers"], layer_caches),
                             unroll=unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _logits(cfg, params, x[:, 0])

    out: Dict[str, Any] = {"index": index + 1}
    if cfg.family == "ssm":
        out.update(deltas)                  # full new states, no scatter
        return logits, out
    Smax = cache["k"].shape[2]
    slot = (jnp.mod(index, Smax) if cfg.window
            else jnp.minimum(index, Smax - 1))
    rows = jnp.arange(B)
    out["k"] = cache["k"].at[:, rows, slot].set(deltas["k_new"])
    out["v"] = cache["v"].at[:, rows, slot].set(deltas["v_new"])
    if cfg.family == "hybrid":
        out["h"] = deltas["h"]
        out["conv"] = deltas["conv"]
    return logits, out


def prefill_chunk(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                  cache: Dict[str, Any], *, impl: str = "reference",
                  moe_impl: str = "sparse", unroll: bool = False,
                  length: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Dict]:
    """Process the next c prompt tokens of each request against an
    existing cache (chunked prefill, paper §3 'chunked prefill').

    tokens: (B, c); cache["index"]: (B,) tokens already cached (= the
    absolute position of tokens[:, 0]).  Returns (last-token logits
    (B, V), updated cache with index += c).

    ``length`` (B,) enables SHAPE-STABLE bucketed chunks: only the first
    ``length[b]`` tokens of row b are real, the rest are padding.  The
    logits row is the *last valid* token's, ``index`` advances by
    ``length``, and every cache/state leaf is bit-equal to an unpadded
    call — one compiled signature serves all chunk sizes up to c.
    Rows with length 0 are inert (logits garbage, state untouched).
    """
    B, c = tokens.shape
    start = cache["index"]
    positions = start[:, None] + jnp.arange(c)[None, :]
    x, _ = _embed(cfg, params, tokens, positions, None)

    layer_caches = {k: v for k, v in cache.items() if k != "index"}

    def body(xc, per_layer):
        lp, lc = per_layer
        xc, new_lc = _block_chunk(cfg, lp, xc, lc, start, impl, moe_impl,
                                  length=length)
        return xc, new_lc

    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches),
                                 unroll=unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if length is None:
        x_last = x[:, -1]
        advance = c
    else:
        last = jnp.maximum(length - 1, 0)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        advance = length
    logits = _logits(cfg, params, x_last)
    out = dict(new_caches)
    out["index"] = start + advance
    return logits, out


def decode_step(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                cache: Dict[str, Any], *, impl: str = "reference",
                moe_impl: str = "sparse",
                unroll: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """tokens (B,) or (B,1); one decode step. Returns (logits (B,V), cache)."""
    if tokens.ndim == 1:
        tokens = tokens[:, None]
    B = tokens.shape[0]
    index = cache["index"]
    x, _ = _embed(cfg, params, tokens, index[:, None], None)

    layer_caches = {k: v for k, v in cache.items() if k != "index"}

    def body(xc, per_layer):
        lp, lc = per_layer
        xc, new_lc = _block_decode(cfg, lp, xc, lc, index, impl, moe_impl)
        return xc, new_lc

    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches),
                                 unroll=unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _logits(cfg, params, x[:, 0])
    out = dict(new_caches)
    out["index"] = index + 1
    return logits, out
