"""Selective-SSM (Mamba-style) branch used by the Hymba hybrid layer.

State: ``h (B, d_inner, N)`` with per-channel data-dependent decay
``a_t = exp(dt_t * A)`` and input injection ``b_t = dt_t * B_t * x_t``:
``h_t = a_t * h_{t-1} + b_t``, ``y_t = h_t @ C_t + D * x_t``.

Full-sequence mode uses an associative scan over the linear recurrence
(O(log S) depth — TPU-friendly); decode mode is the O(1) state update.
A short causal depthwise conv (kernel 4) precedes the SSM as in Mamba;
its 3-sample state is carried in the cache for decode.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init

CONV_K = 4


def init_ssm(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),        # x, z gate
        "conv_w": (jax.random.normal(ks[1], (CONV_K, di), jnp.float32) * 0.2).astype(dtype),
        "dt_proj": dense_init(ks[2], di, di, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "bc_proj": dense_init(ks[3], di, 2 * N, dtype),        # B_t, C_t
        "A_log": jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32) *
                         jnp.ones((di, 1), jnp.float32)).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _conv_full(xin: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv over (B, S, di)."""
    pad = jnp.pad(xin, [(0, 0), (CONV_K - 1, 0), (0, 0)])
    out = sum(pad[:, i:i + xin.shape[1]] * w[i] for i in range(CONV_K))
    return out


def _conv_window(stream: jnp.ndarray, w: jnp.ndarray, S: int) -> jnp.ndarray:
    """Causal depthwise conv over a stream that already carries the
    CONV_K-1 samples of left context; returns the last S outputs."""
    return sum(stream[:, i:i + S] * w[i] for i in range(CONV_K))


def _ssm_core_scan(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Associative scan of h_t = a_t * h_{t-1} + b_t along axis=1 (time)."""

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def pick_chunk(S: int, pref: int) -> int:
    """Largest power-of-two divisor of S that is <= pref (>= 1)."""
    if S <= pref:  # repro: allow-recompile-hazard(S and pref are static Python ints from .shape; chunk picking is trace-time shape arithmetic)
        return S
    q = pref
    while q > 1 and S % q != 0:  # repro: allow-recompile-hazard(same trace-time shape arithmetic as above)
        q //= 2
    return max(q, 1)


def apply_ssm_full(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                   chunk: int = 128,
                   state: Optional[Dict] = None,
                   length: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, d) -> (y (B,S,d), final_state dict).

    The per-step state h is (di, N) — 2·ssm_expand·N times wider than the
    activation — so we never materialize it for all S.  Time is processed
    in chunks of ``chunk`` steps: an associative scan *within* the chunk
    (O(log chunk) depth) and a ``lax.scan`` carrying h *across* chunks.
    ``state`` (from a previous chunk / ``init_ssm_state``) makes this a
    continuation — the engine's chunked prefill path.

    ``length`` (B,) marks only the first ``length[b]`` steps of row b as
    real; trailing steps are shape padding whose state update is forced
    to the identity (dt = 0 → a = 1, b = 0) and whose samples never
    enter the carried conv window, so a row's final state equals the
    unpadded run's (length 0 = untouched row).
    """
    B, S, _ = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    Q = pick_chunk(S, chunk)
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                      # (B, S, di)
    conv_prev = (state["conv"] if state is not None
                 else jnp.zeros((B, CONV_K - 1, di), xin.dtype))
    xin_stream = jnp.concatenate([conv_prev.astype(xin.dtype), xin], axis=1)
    if length is None:
        new_conv = xin_stream[:, -(CONV_K - 1):]
    else:
        # last CONV_K-1 *valid* stream samples: indices length..length+K-2
        idx = length[:, None] + jnp.arange(CONV_K - 1)[None, :]
        new_conv = jnp.take_along_axis(xin_stream, idx[:, :, None], axis=1)
    xin = jax.nn.silu(_conv_window(xin_stream, params["conv_w"], S))

    dt = jax.nn.softplus((xin @ params["dt_proj"]).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,di)
    if length is not None:
        valid = jnp.arange(S)[None, :] < length[:, None]    # (B, S)
        dt = jnp.where(valid[..., None], dt, 0.0)
    bc = (xin @ params["bc_proj"]).astype(jnp.float32)
    Bt, Ct = jnp.split(bc, 2, axis=-1)                      # (B, S, N)
    A = -jnp.exp(params["A_log"])                           # (di, N)

    nchunk = S // Q
    dt_c = dt.reshape(B, nchunk, Q, di).swapaxes(0, 1)
    xin_c = xin.astype(jnp.float32).reshape(B, nchunk, Q, di).swapaxes(0, 1)
    Bt_c = Bt.reshape(B, nchunk, Q, N).swapaxes(0, 1)
    Ct_c = Ct.reshape(B, nchunk, Q, N).swapaxes(0, 1)

    def chunk_step(h0, inputs):
        dt_q, xin_q, B_q, C_q = inputs                      # (B,Q,...)
        a = jnp.exp(dt_q[..., None] * A[None, None])        # (B,Q,di,N)
        b = (dt_q * xin_q)[..., None] * B_q[:, :, None, :]
        # inject carry into the first step, then associative-scan the chunk
        b = b.at[:, 0].add(a[:, 0] * h0)
        h = _ssm_core_scan(a, b)                            # (B,Q,di,N)
        y = jnp.einsum("bqdn,bqn->bqd", h, C_q)
        return h[:, -1], y

    h0 = (state["h"] if state is not None
          else jnp.zeros((B, di, N), jnp.float32))
    h_last, y = jax.lax.scan(chunk_step, h0, (dt_c, xin_c, Bt_c, Ct_c))
    y = y.swapaxes(0, 1).reshape(B, S, di)
    y = y + params["D"] * xin.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, {"h": h_last, "conv": new_conv}


def init_ssm_state(cfg: ModelConfig, batch: int) -> Dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, cfg.d_inner),
                          jnp.dtype(cfg.dtype)),
    }


def apply_ssm_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                     state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, d), state from init/prior step -> (y (B,1,d), state)."""
    B = x.shape[0]
    xz = x[:, 0] @ params["in_proj"]
    xin_new, z = jnp.split(xz, 2, axis=-1)                  # (B, di)
    window = jnp.concatenate([state["conv"], xin_new[:, None]], axis=1)
    xin = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, params["conv_w"]))

    dt = jax.nn.softplus((xin @ params["dt_proj"]).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    bc = (xin @ params["bc_proj"]).astype(jnp.float32)
    Bt, Ct = jnp.split(bc, 2, axis=-1)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[..., None] * A[None])                    # (B,di,N)
    b = (dt * xin.astype(jnp.float32))[..., None] * Bt[:, None, :]
    h = a * state["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Ct) + params["D"] * xin.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None]
    return out, {"h": h, "conv": window[:, 1:]}
