"""Data substrate: synthetic token pipeline + inference workloads."""
from repro.data.synthetic import (  # noqa: F401
    DataConfig,
    batch_for_step,
    batch_with_frontend,
    data_iterator,
)
from repro.data.workloads import (  # noqa: F401
    GROUPS,
    azureconv_like,
    fixed_grid,
    hetero_mix,
    longform_like,
)
