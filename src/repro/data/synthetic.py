"""Deterministic, sharded, infinite synthetic token pipeline.

Every (step, host) pair maps to the same tokens via counter-based
threefry — any host can recompute any shard, so the data path has no
single point of failure and straggling hosts can be skipped and
recomputed elsewhere (DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # language-model-ish skew: zipf-like marginal over the vocabulary
    zipf_a: float = 1.2


def batch_for_step(cfg: DataConfig, step: int, *,
                   shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
    """The (tokens, labels) shard for ``step`` — pure function of
    (seed, step, shard)."""
    assert cfg.global_batch % num_shards == 0
    b = cfg.global_batch // num_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))
    # zipf-ish skew, clipped into vocab
    raw = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len + 1))
    toks = (raw - 1) % cfg.vocab_size
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def data_iterator(cfg: DataConfig, *, start_step: int = 0, shard: int = 0,
                  num_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_for_step(cfg, step, shard=shard, num_shards=num_shards)
        step += 1


def batch_with_frontend(model_cfg: ModelConfig, data_cfg: DataConfig,
                        step: int) -> Dict[str, np.ndarray]:
    """Adds the stub modality inputs (precomputed patch embeddings)."""
    batch = batch_for_step(data_cfg, step)
    if model_cfg.frontend == "patch":
        rng = np.random.default_rng(
            np.random.SeedSequence([data_cfg.seed, step, 999]))
        batch["patch_embeds"] = rng.standard_normal(
            (data_cfg.global_batch, model_cfg.num_patches,
             model_cfg.d_model)).astype(np.float32)
    return batch
