"""Inference workload generators (paper §5.5, §8, App. C).

* fixed (I, O) grids — §5's controlled sweeps
* SISO/SILO/LISO/LILO heterogeneous mixes — App. C
* AzureConv-like online conversation trace — §8 (lognormal lengths,
  Poisson-ish arrivals over an hour; avg I≈1.2K max 14.1K, avg O≈0.2K
  max 1K)
* LongForm-like offline generation trace — §8 (avg I≈250 max 8.4K,
  avg O≈380 max 3.8K; uniform arrivals over 100 s)

All return ``List[Request]`` with real token ids optional (engine mode).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Request


def _mk(spec: Sequence[Tuple[int, int, float]],
        vocab: Optional[int] = None, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    out = []
    for i, (I, O, a) in enumerate(spec):
        prompt = (rng.integers(0, vocab, size=I).tolist()
                  if vocab is not None else None)
        out.append(Request(rid=i, input_len=int(I), output_len=int(O),
                           arrival=float(a), prompt=prompt))
    return out


def fixed_grid(W: int, I: int, O: int, *, vocab: Optional[int] = None,
               seed: int = 0) -> List[Request]:
    """W identical offline requests (paper §5.5 workloads)."""
    return _mk([(I, O, 0.0)] * W, vocab=vocab, seed=seed)


GROUPS = {
    "SISO": ((8, 16), (8, 16)),
    "SILO": ((8, 16), (512, 1024)),
    "LISO": ((512, 1024), (8, 16)),
    "LILO": ((512, 1024), (512, 1024)),
}


def hetero_mix(groups: Sequence[str], W: int, *, seed: int = 0,
               vocab: Optional[int] = None) -> List[Request]:
    """Shuffled mix of two (or more) App.-C groups, offline arrivals."""
    rng = np.random.default_rng(seed)
    spec = []
    for i in range(W):
        g = GROUPS[groups[i % len(groups)]]
        I = int(rng.choice(g[0]))
        O = int(rng.choice(g[1]))
        spec.append((I, O, 0.0))
    rng.shuffle(spec)
    return _mk(spec, vocab=vocab, seed=seed + 1)


def _lognormal(rng, mean: float, maximum: float, n: int) -> np.ndarray:
    """Lognormal with the given mean, clipped at maximum (>= 1)."""
    sigma = 1.0
    mu = math.log(mean) - sigma ** 2 / 2
    x = rng.lognormal(mu, sigma, size=n)
    return np.clip(x, 1, maximum).astype(int)


def azureconv_like(n: int = 512, *, duration_s: float = 3600.0,
                   o_scale: float = 1.0, seed: int = 0,
                   vocab: Optional[int] = None) -> List[Request]:
    """Online conversation trace with AzureConv's published statistics."""
    rng = np.random.default_rng(seed)
    I = _lognormal(rng, 1200, 14_100, n)
    O = np.maximum((_lognormal(rng, 200, 1000, n) * o_scale), 1).astype(int)
    arrivals = np.sort(rng.uniform(0.0, duration_s, size=n))
    return _mk(list(zip(I, O, arrivals)), vocab=vocab, seed=seed + 1)


def longform_like(n: int = 256, *, duration_s: float = 100.0,
                  o_scale: float = 1.0, seed: int = 0,
                  vocab: Optional[int] = None) -> List[Request]:
    """Long-form generation trace (uniform arrivals in [0, 100 s])."""
    rng = np.random.default_rng(seed)
    I = _lognormal(rng, 250, 8_400, n)
    O = np.maximum((_lognormal(rng, 380, 3_800, n) * o_scale), 1).astype(int)
    arrivals = rng.uniform(0.0, duration_s, size=n)
    return _mk(list(zip(I, O, arrivals)), vocab=vocab, seed=seed + 1)


def shared_prefix(n: int = 8, *, input_len: int = 32,
                  prefix_frac: float = 0.75, num_groups: int = 1,
                  output_len: int = 8, vocab: int = 1000,
                  stagger: float = 0.0, seed: int = 0) -> List[Request]:
    """Relational-LLM-style workload: ``num_groups`` groups of requests
    whose prompts share a common leading prefix of
    ``round(prefix_frac * input_len)`` tokens (think one system prompt /
    table schema fanned out over rows), with per-request random
    suffixes.  This is the workload shared-prefix page reuse exists for:
    with ``prefix_frac=0.75`` and 8 requests, ~75% of prompt pages
    dedupe to one physical copy.  Always generates real token ids
    (engine mode).

    ``stagger`` delays every request after each group's first by that
    many seconds: the template request prefills (and publishes its
    prefix pages) one batch ahead of the fan-out, which is the shape
    real deployments have — the system prompt is in the page registry
    before the per-row queries arrive.  Prefix reuse is cross-batch:
    requests co-scheduled into the same prefill batch all miss."""
    assert 0.0 <= prefix_frac < 1.0
    rng = np.random.default_rng(seed)
    plen = int(round(prefix_frac * input_len))
    prefixes = [rng.integers(0, vocab, size=plen).tolist()
                for _ in range(num_groups)]
    out = []
    for i in range(n):
        suffix = rng.integers(0, vocab, size=input_len - plen).tolist()
        prompt = prefixes[i % num_groups] + suffix
        out.append(Request(rid=i, input_len=input_len,
                           output_len=output_len,
                           arrival=0.0 if i < num_groups else stagger,
                           prompt=prompt))
    return out


def zipf_shared_prefix(n: int = 48, *, num_groups: int = 6,
                       alpha: float = 1.2, page_size: int = 8,
                       prefix_pages: Tuple[int, int] = (2, 4),
                       input_len: int = 48, output_len: int = 4,
                       vocab: int = 1000, arrival_gap: float = 5e-4,
                       seed: int = 0) -> List[Request]:
    """Zipf-skewed hot-prefix workload — the analytics shape of
    *Optimizing LLM Queries in Relational Workloads* (arXiv 2403.05821),
    where hit-rate-blind LRU loses and cost-based replacement wins.

    ``num_groups`` prefix templates with popularity ``p(g) ∝
    (g+1)^-alpha``: a few HOT templates are re-referenced constantly, a
    long tail of COLD templates appears once or twice.  Template prefix
    LENGTH grows with coldness (``prefix_pages`` = (hot, cold) in full
    ``page_size`` pages): the cold tail is exactly the long-prefix scan
    traffic that flushes an LRU registry, while the §6 break-even policy
    evicts those first (long prefixes have SHORTER break-even residency
    — Eq. 5) and keeps the hot short templates resident.

    Prompts = group prefix + per-request random suffix padded to a
    common ``input_len``; arrivals are staggered ``arrival_gap`` apart so
    reuse is cross-batch (co-scheduled duplicates all miss).  Always
    generates real token ids (engine mode)."""
    assert num_groups >= 2 and prefix_pages[0] <= prefix_pages[1]
    assert n >= num_groups, \
        f"need n >= num_groups (every template appears once), " \
        f"got n={n} < {num_groups}"
    assert prefix_pages[1] * page_size < input_len, \
        "prefix must leave room for a suffix"
    rng = np.random.default_rng(seed)
    probs = (1.0 / np.arange(1, num_groups + 1) ** alpha)
    probs /= probs.sum()
    lo, hi = prefix_pages
    plens = [int(round(lo + (hi - lo) * g / max(num_groups - 1, 1)))
             * page_size for g in range(num_groups)]
    prefixes = [rng.integers(0, vocab, size=p).tolist() for p in plens]
    # every group appears at least once (the cold tail must exist to
    # pollute the cache); remaining draws follow the Zipf popularity
    groups = list(range(num_groups)) \
        + rng.choice(num_groups, size=n - num_groups, p=probs).tolist()
    rng.shuffle(groups)
    out = []
    for i, g in enumerate(groups):
        suffix = rng.integers(0, vocab,
                              size=input_len - plens[g]).tolist()
        out.append(Request(rid=i, input_len=input_len,
                           output_len=output_len,
                           arrival=i * arrival_gap,
                           prompt=prefixes[g] + suffix))
    return out


def conversation_tree(n: int = 24, *, page_size: int = 8,
                      system_pages: int = 3, turn_pages: int = 1,
                      branching: int = 2, depth: int = 2,
                      output_len: int = 4, vocab: int = 1000,
                      arrival_gap: float = 5e-4,
                      seed: int = 0) -> List[Request]:
    """Branching multi-turn conversations — the radix-trie workload.

    One shared system prompt (``system_pages`` full pages) roots a
    ``branching``-ary tree of conversation turns, each turn a run of
    ``turn_pages`` full pages; every request walks root -> leaf and
    appends one UNIQUE final page (its own last user message), so no
    two prompts are identical but every pair sharing a tree path shares
    that path's token prefix.  This is exactly where an all-or-nothing
    exact-match registry scores ZERO (the unique tail breaks every
    full-prompt probe) while a radix trie converts each shared path
    into a partial hit — the PR 9 exit-criterion workload.

    Requests are dealt round-robin over the ``branching**depth`` leaves
    (every leaf path occurs, hot paths first) and staggered
    ``arrival_gap`` apart so reuse is cross-batch.  Prompt length is
    uniform: ``(system_pages + depth*turn_pages + 1) * page_size``
    tokens.  Always generates real token ids (engine mode)."""
    assert page_size > 1 and system_pages >= 1 and turn_pages >= 1
    assert branching >= 2 and depth >= 1
    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, size=system_pages * page_size).tolist()
    # turns[path] caches the token run for each tree node so siblings
    # share ancestors verbatim (trie nodes must be byte-identical)
    turns: dict = {}

    def turn(path: Tuple[int, ...]) -> List[int]:
        run = turns.get(path)
        if run is None:
            run = rng.integers(0, vocab,
                               size=turn_pages * page_size).tolist()
            turns[path] = run
        return run

    leaves = [()]
    for _ in range(depth):
        leaves = [p + (b,) for p in leaves for b in range(branching)]
    order = list(range(len(leaves)))
    rng.shuffle(order)
    input_len = (system_pages + depth * turn_pages + 1) * page_size
    out = []
    for i in range(n):
        path = leaves[order[i % len(leaves)]]
        prompt = list(system)
        for d in range(1, depth + 1):
            prompt += turn(path[:d])
        prompt += rng.integers(0, vocab, size=page_size).tolist()
        out.append(Request(rid=i, input_len=input_len,
                           output_len=output_len,
                           arrival=i * arrival_gap, prompt=prompt))
    return out
