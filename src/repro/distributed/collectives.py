"""Distributed flash-decode combine for sequence-sharded KV caches.

The paper's central hardware observation — decode attention is
memory-bandwidth-bound (§5.2) and "bandwidth matters more than capacity"
(§8) — maps onto a TPU pod as: shard the KV cache's SEQUENCE dimension
over the ``model`` axis so k chips stream k× the aggregate HBM bandwidth,
then combine the per-shard partial softmax with one small ``psum``
(numerator, sum-of-exp, running max).  This is flash-decoding re-expressed
as a jax collective instead of CUDA split-k blocks.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.distributed.context import shard_map_compat

NEG_INF = -1e30


def flash_decode_seqsharded(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            valid: jnp.ndarray, *, axis_name: str
                            ) -> jnp.ndarray:
    """One-token decode attention over a sequence-sharded KV cache.

    Inside shard_map: q (B, H, D) replicated over ``axis_name``; k/v
    (B, S_local, Hkv, D) hold this shard's slice of the sequence;
    valid (B, S_local) marks real entries.  Returns (B, H, D) (full).
    """
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(k.dtype)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m_local = jnp.max(s, axis=-1)                      # (B, Hkv, G)
    m_global = jax.lax.pmax(m_local, axis_name)
    p = jnp.exp(s - m_global[..., None])
    l_local = jnp.sum(p, axis=-1)                      # (B, Hkv, G)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    l = jax.lax.psum(l_local, axis_name)
    out = jax.lax.psum(acc, axis_name) / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, H, D).astype(q.dtype)


def make_seqsharded_decode_attn(mesh: Mesh, *, seq_axis: str = "model"):
    """shard_map wrapper: full arrays in, sequence sharded internally.

    q (B, H, D); k/v (B, S, Hkv, D) sharded P(dp, seq, None, None);
    lengths (B,) = valid context per request.  Returns (B, H, D).
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def fn(q, k, v, lengths):
        S = k.shape[1]
        n = mesh.shape[seq_axis]
        S_local = S // n

        def local(qs, ks, vs, ln):
            idx = jax.lax.axis_index(seq_axis)
            pos = idx * S_local + jnp.arange(S_local)[None, :]
            valid = pos < ln[:, None]
            return flash_decode_seqsharded(qs, ks, vs, valid,
                                           axis_name=seq_axis)

        return shard_map_compat(
            local, mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, seq_axis, None, None),
                      P(dp, seq_axis, None, None), P(dp)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )(q, k, v, lengths)

    return fn


def decode_attn_partials(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Decode attention returning (out, running max m, sum-exp l) so a
    caller can merge additional softmax groups (deferred-append decode).
    q (B,H,D); k/v (B,S,Hkv,D); valid (B,S) -> out (B,H,D), m/l (B,H)."""
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(k.dtype)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return (out.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def flash_decode_seqsharded_partials(q, k, v, valid, *, axis_name: str):
    """Sequence-sharded flash decode returning global (out, m, l)."""
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(k.dtype)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m_local = jnp.max(s, axis=-1)
    m = jax.lax.pmax(m_local, axis_name)
    p = jnp.exp(s - m[..., None])
    l = jax.lax.psum(jnp.sum(p, axis=-1), axis_name)
    acc = jax.lax.psum(
        jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32), axis_name)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return (out.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def make_seqsharded_decode_attn_partials(mesh: Mesh, *,
                                         seq_axis: str = "model"):
    """shard_map wrapper of the partials variant (full arrays in/out)."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def fn(q, k, v, lengths):
        S = k.shape[1]
        n = mesh.shape[seq_axis]
        S_local = S // n

        def local(qs, ks, vs, ln):
            idx = jax.lax.axis_index(seq_axis)
            pos = idx * S_local + jnp.arange(S_local)[None, :]
            valid = pos < ln[:, None]
            return flash_decode_seqsharded_partials(qs, ks, vs, valid,
                                                    axis_name=seq_axis)

        return shard_map_compat(
            local, mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, seq_axis, None, None),
                      P(dp, seq_axis, None, None), P(dp)),
            out_specs=(P(dp, None, None), P(dp, None), P(dp, None)),
            check_vma=False,
        )(q, k, v, lengths)

    return fn


# --------------------------------------------------------------------- #
# reference (single-device oracle)
# --------------------------------------------------------------------- #

def decode_attn_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          lengths: jnp.ndarray) -> jnp.ndarray:
    """q (B,H,D); k/v (B,S,Hkv,D); lengths (B,) -> (B,H,D), fp32 softmax."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
