"""Distribution: sharding rules, collectives, compression, fault tolerance."""
from repro.distributed.collectives import (  # noqa: F401
    decode_attn_reference,
    flash_decode_seqsharded,
    make_seqsharded_decode_attn,
)
from repro.distributed.compression import (  # noqa: F401
    compress_with_feedback,
    compressed_psum,
    init_error_state,
)
from repro.distributed.fault_tolerance import (  # noqa: F401
    StragglerMonitor,
    elastic_remesh,
    reshard,
    run_with_retries,
)
from repro.distributed.sharding import (  # noqa: F401
    batch_pspecs,
    dp_axes,
    named,
    out_pspecs_decode,
    param_pspecs,
)
