"""Int8 error-feedback gradient compression for cross-pod (DCN) reduction.

At 1000+-node scale the pod-to-pod links are the slowest hop; gradients
crossing them are quantized to int8 with per-tensor scales.  The
quantization error is fed back into the next step's gradient (error
feedback), which keeps SGD-style convergence guarantees: the residual
state satisfies  err_{t} = (g_t + err_{t-1}) - Q(g_t + err_{t-1})
and the long-run bias of the compressed sum is bounded by one step's
quantization error (unit-tested invariant).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_with_feedback(grads: Any, err: Any) -> Tuple[Any, Any, Any]:
    """Returns (quantized pytree of (q, scale), new_err, decompressed)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        deq = _dequantize(q, scale)
        return (q, scale), x - deq, deq

    flat, treedef = jax.tree.flatten(grads)
    eflat = treedef.flatten_up_to(err)
    qs, errs, deqs = zip(*(one(g, e) for g, e in zip(flat, eflat)))
    return (treedef.unflatten(list(qs)), treedef.unflatten(list(errs)),
            treedef.unflatten(list(deqs)))


def compressed_psum(grads: Any, err: Any, axis_name: str) -> Tuple[Any, Any]:
    """All-reduce int8-compressed gradients over ``axis_name`` (the pod
    axis): quantize -> psum(int32) -> dequantize by the mean scale.
    Returns (reduced grads fp32, new error state)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        new_e = x - _dequantize(q, scale)
        total = jax.lax.psum(q.astype(jnp.int32) * scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total / n, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = treedef.flatten_up_to(err)
    outs, errs = zip(*(one(g, e) for g, e in zip(flat, eflat)))
    return treedef.unflatten(list(outs)), treedef.unflatten(list(errs))
