"""Fault tolerance & elasticity primitives (1000+-node design).

* ``run_with_retries`` — transient-error shield around a step function
  (preemptible TPU slices surface as RuntimeError/XlaRuntimeError).
* ``elastic_remesh`` — rebuild a production mesh on a SHRUNKEN device set
  after node loss (e.g. 512 -> 256 chips keeping the model axis intact),
  and ``reshard`` any pytree onto the new mesh.
* ``StragglerMonitor`` — per-batch deadline relative to the cost model's
  prediction; serving batches exceeding it are logged and their requests
  requeued (scheduler-level mitigation, matching the paper's framing of
  GPU time as the critical path).
"""
from __future__ import annotations

import logging
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger("repro.ft")


def run_with_retries(fn: Callable, *args, retries: int = 3,
                     backoff_s: float = 0.1,
                     retry_on: Tuple = (RuntimeError,),
                     sleep: Optional[Callable[[float], None]] = None, **kw):
    """Re-execute ``fn`` on transient runtime errors (jittable steps are
    deterministic, so re-execution is safe).

    ``sleep`` is the backoff clock — defaults to ``time.sleep``; the
    serving engine injects a virtual clock that *records* the schedule
    (exponential: ``backoff_s * 2**attempt``) instead of stalling the
    step, which also makes the retry path unit-testable.
    """
    if sleep is None:
        sleep = time.sleep
    for attempt in range(retries + 1):
        try:
            return fn(*args, **kw)
        except retry_on as e:
            if attempt == retries:
                raise
            log.warning("step failed (%s); retry %d/%d", e, attempt + 1,
                        retries)
            sleep(backoff_s * (2 ** attempt))


def largest_pow2_leq(n: int) -> int:
    return 1 << (n.bit_length() - 1)


def elastic_remesh(devices: Sequence, *, model_parallel: int,
                   multi_pod: bool = False) -> Mesh:
    """Build the biggest valid mesh from the surviving devices.

    The ``model`` axis is preserved (TP degree is baked into the weight
    layout); data (and pod) shrink to the largest power of two that fits.
    """
    n = len(devices)
    if n < model_parallel:
        raise ValueError(
            f"cannot keep model={model_parallel} with {n} devices")
    usable_dp = largest_pow2_leq(n // model_parallel)
    if multi_pod and usable_dp >= 2:
        pods = 2
        dp = usable_dp // 2
        shape, axes = (pods, dp, model_parallel), ("pod", "data", "model")
    else:
        shape, axes = (usable_dp, model_parallel), ("data", "model")
    total = math.prod(shape)
    dev = list(devices)[:total]
    import numpy as np
    return Mesh(np.asarray(dev).reshape(shape), axes)


def reshard(tree: Any, mesh: Mesh, pspecs: Any) -> Any:
    """Move a pytree onto ``mesh`` under ``pspecs`` (post-remesh)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, pspecs, is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


# --------------------------------------------------------------------- #
# straggler mitigation
# --------------------------------------------------------------------- #


@dataclass
class StragglerEvent:
    batch_index: int
    predicted_s: float
    actual_s: float


@dataclass
class StragglerMonitor:
    """Flags batches slower than deadline_factor x the cost-model
    prediction.  The serving engine requeues the flagged batch's
    requests; the training loop logs and continues (deterministic data
    pipeline lets any host recompute any shard)."""

    deadline_factor: float = 3.0
    min_floor_s: float = 1e-4
    events: List[StragglerEvent] = field(default_factory=list)
    _index: int = 0

    def observe(self, predicted_s: float, actual_s: float) -> bool:
        self._index += 1
        deadline = max(predicted_s * self.deadline_factor, self.min_floor_s)
        if actual_s > deadline:
            self.events.append(StragglerEvent(self._index, predicted_s,
                                              actual_s))
            return True
        return False
