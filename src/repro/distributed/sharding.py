"""Logical sharding rules for every architecture family.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Megatron-style tensor parallelism on ``model`` (all feature
dims of the assigned archs are divisible by 16 — verified in tests),
FSDP over ``data`` for training, replication over ``data`` for serving
(the classic train/serve tradeoff; see DESIGN.md §4).

MoE expert tensors are expert-parallel over ``model`` (E padded to a
multiple of 16).  KV caches are batch-sharded over (pod, data) and
sequence-sharded over ``model`` — the beyond-paper bandwidth
multiplication for decode attention (§Perf).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# weights whose LAST dim is TP-sharded (column parallel)
_COL = ("wq", "wk", "wv", "wi_gate", "wi_up", "cm_k", "cm_r", "in_proj",
        "router")
# weights whose MIDDLE (input) dim is TP-sharded (row parallel)
_ROW = ("wo", "out_proj", "cm_v")
# SSM per-channel tensors sharded on the channel (d_inner) dim
_SSM_CHANNEL = ("dt_proj", "bc_proj", "A_log", "dt_bias", "D", "conv_w")
# expert-parallel stacked tensors (L, E, ...)
_EXPERT = ("wi_gate", "wi_up", "wo")


def _param_rule(path: Tuple[str, ...], ndim: int, *, fsdp: bool) -> P:
    name = path[-1]
    data = "data" if fsdp else None
    in_layers = "layers" in path
    in_moe = "moe" in path and "shared" not in path

    if name in ("embed", "head"):
        return P("model", None)
    if name == "patch_proj":
        return P(None, "model")
    if not in_layers:
        return P()  # ln_f etc.

    # ---- stacked per-layer tensors: leading L axis is never sharded ----
    if in_moe:
        if name in _EXPERT and ndim == 4:          # (L, E, d, ff)/(L, E, ff, d)
            return P(None, "model", data, None)
        if name == "router":                        # (L, d, E)
            return P(None, data, "model")
        return P()                                   # shared_gate etc.
    if name in _ROW and ndim == 3:
        return P(None, "model", data)
    if name in _COL and ndim == 3:
        return P(None, data, "model")
    if name == "wg" and ndim == 3:                   # rwkv gate proj
        return P(None, data, "model")
    if name in ("wr", "wk", "wv") and ndim == 3:     # rwkv projections
        return P(None, data, "model")
    if name in _SSM_CHANNEL:
        if name == "dt_proj":                        # (L, di, di)
            return P(None, "model", None)
        if name == "bc_proj":                        # (L, di, 2N)
            return P(None, "model", None)
        if name == "A_log":                          # (L, di, N)
            return P(None, "model", None)
        if name == "conv_w":                         # (L, K, di)
            return P(None, None, "model")
        return P(None, "model")                      # (L, di)
    if name == "decay_lora_a" and ndim == 3:         # (L, d, R)
        return P(None, data, None)
    if name == "decay_lora_b" and ndim == 3:         # (L, R, d)
        return P(None, None, data)
    return P()                                        # norms, mus, biases


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def param_pspecs(cfg: ModelConfig, params_shape: Any, *,
                 fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching ``init_params``' structure.

    params_shape: ShapeDtypeStruct pytree (``serve_step.param_specs``) or
    real params.
    """
    def rule(path, leaf):
        return _param_rule(_path_names(path), len(leaf.shape), fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# --------------------------------------------------------------------- #
# batch / cache shardings
# --------------------------------------------------------------------- #


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _dp(mesh: Mesh, size: int) -> Optional[Tuple[str, ...]]:
    """Data-parallel axes usable for a batch of ``size`` (None if < mesh)."""
    axes = dp_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if size % total == 0:
        return axes
    if "data" in axes and size % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 *, seq_shard: bool = True) -> Dict[str, Any]:
    """PartitionSpecs for the cell's inputs (mirrors serve_input_specs)."""
    B = shape.global_batch
    dp = _dp(mesh, B)
    bspec = P(dp) if dp else P(None)
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {"tokens": P(dp, None)}
        if shape.kind == "train":
            specs["labels"] = P(dp, None)
        if cfg.frontend == "patch":
            specs["patch_embeds"] = P(dp, None, None)
        return specs
    # decode: {tokens (B,), cache}
    seq = "model" if seq_shard else None

    def cache_rule(path, leaf):
        name = _path_names(path)[-1]
        nd = len(leaf.shape)
        if name == "index":
            return bspec
        if name in ("k", "v"):              # (L, B, S, Hkv, D)
            S = leaf.shape[2]
            s_ok = seq and S % mesh.shape["model"] == 0
            return P(None, dp, seq if s_ok else None, None, None)
        if name == "h":                      # (L, B, di, N)
            return P(None, dp, "model", None)
        if name == "conv":                   # (L, B, K-1, di)
            return P(None, dp, None, "model")
        if name == "S":                      # (L, B, H, D, D)
            H = leaf.shape[2]
            hs = "model" if H % mesh.shape["model"] == 0 else None
            return P(None, dp, hs, None, None)
        if name in ("x_tm", "x_cm"):         # (L, B, 1, d)
            return P(None, dp, None, "model")
        return P(None, dp) if nd >= 2 else bspec

    from repro.serving.serve_step import cache_specs
    cache_shape = cache_specs(cfg, B, shape.seq_len)
    cache_spec = jax.tree_util.tree_map_with_path(cache_rule, cache_shape)
    return {"tokens": bspec, "cache": cache_spec}


def out_pspecs_decode(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, seq_shard: bool = True) -> Any:
    """(logits, cache) output specs for the decode serve_step."""
    cs = batch_pspecs(cfg, shape, mesh, seq_shard=seq_shard)
    B = shape.global_batch
    dp = _dp(mesh, B)
    logits = P(dp, "model")
    return (logits, cs["cache"])


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
