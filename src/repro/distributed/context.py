"""Ambient mesh for model-internal shard_map regions.

Model code (attention, MoE) sometimes needs the mesh to build a
shard_map region (seq-sharded flash decode, expert-parallel dispatch).
Launchers set it; single-device tests leave it unset and the model falls
back to mesh-free implementations.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def dp_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
