"""Ambient mesh for model-internal shard_map regions.

Model code (attention, MoE) sometimes needs the mesh to build a
shard_map region (seq-sharded flash decode, expert-parallel dispatch).
Launchers set it; single-device tests leave it unset and the model falls
back to mesh-free implementations.
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def dp_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
