"""Checkpointing: sharded store + async manager with auto-resume."""
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.store import (  # noqa: F401
    list_steps,
    restore,
    retain,
    save,
    verify,
)
