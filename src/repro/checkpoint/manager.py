"""Checkpoint manager: periodic async saves, retention, auto-resume,
SIGTERM drain (preemptible-slice survival).

The async writer snapshots the state to host memory synchronously (cheap)
and writes to disk on a worker thread, so the training loop never blocks
on I/O.  ``install_sigterm_drain`` arranges a final synchronous save when
the scheduler/cluster preempts the job.
"""
from __future__ import annotations

import logging
import signal
import threading
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import store

log = logging.getLogger("repro.ckpt")


class CheckpointManager:
    def __init__(self, directory: str, *, interval: int = 100,
                 keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._last_saved_step = -1
        self._lock = threading.Lock()
        # test hook: raise inside the writer to exercise failure paths
        self.failure_injection: Optional[Callable[[int], None]] = None

    # ------------------------------------------------------------------ #
    def should_save(self, step: int) -> bool:
        return step % self.interval == 0 and step != self._last_saved_step

    def _write(self, host_tree: Any, step: int) -> None:
        if self.failure_injection is not None:
            self.failure_injection(step)
        store.save(host_tree, self.directory, step)
        store.retain(self.directory, self.keep)
        log.info("checkpoint step %d committed", step)

    def save(self, state: Any, step: int, *, block: bool = False) -> None:
        """Snapshot to host memory now; write async (or sync)."""
        host_tree = jax.tree.map(np.asarray, state)  # device->host snapshot
        with self._lock:
            self.wait()
            self._last_saved_step = step
            if self.async_write and not block:
                self._thread = threading.Thread(
                    target=self._safe_write, args=(host_tree, step),
                    daemon=True)
                self._thread.start()
            else:
                self._write(host_tree, step)

    def _safe_write(self, host_tree: Any, step: int) -> None:
        try:
            self._write(host_tree, step)
        except Exception:  # pragma: no cover
            log.exception("async checkpoint write failed at step %d", step)

    def maybe_save(self, state: Any, step: int) -> bool:
        if self.should_save(step):
            self.save(state, step)
            return True
        return False

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    # ------------------------------------------------------------------ #
    def restore_latest(self, template: Any, *, shardings: Any = None
                       ) -> Tuple[Any, int]:
        """Latest VALID checkpoint (hash-verified; torn writes skipped)."""
        steps = store.list_steps(self.directory)
        for step in reversed(steps):
            path = f"{self.directory}/step_{step:09d}"
            if store.verify(path):
                return store.restore(template, self.directory, step,
                                     shardings=shardings)
            log.warning("checkpoint %s failed verification; skipping", path)
        raise FileNotFoundError(f"no valid checkpoint in {self.directory}")

    def has_checkpoint(self) -> bool:
        return bool(store.list_steps(self.directory))

    # ------------------------------------------------------------------ #
    def install_sigterm_drain(self, get_state: Callable[[], Tuple[Any, int]]
                              ) -> None:
        def handler(signum, frame):  # pragma: no cover - signal path
            log.warning("SIGTERM: draining with a final checkpoint")
            state, step = get_state()
            self.save(state, step, block=True)
            raise SystemExit(0)

        signal.signal(signal.SIGTERM, handler)
