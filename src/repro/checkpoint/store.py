"""Sharding-aware checkpoint store (npz per host-shard + json manifest).

Layout::

    <dir>/step_000123/
        manifest.json       # tree structure, per-leaf shape/dtype, hash
        leaves.npz          # flat leaf arrays (host-local full arrays)
        COMMITTED           # written LAST (atomic-rename commit marker)

Restore maps leaves back into the saved treedef and (optionally)
device_puts them under a target mesh/sharding — which is how elastic
restarts reshard a 512-chip checkpoint onto 256 chips.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"
LEAVES = "leaves.npz"
COMMITTED = "COMMITTED"


def _flatten_with_names(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        named.append((name, leaf))
    return named, treedef


def _to_storable(a: np.ndarray) -> Tuple[np.ndarray, str]:
    """npz can't hold ml_dtypes (bfloat16 etc.) — store as a uint view and
    record the logical dtype in the manifest."""
    dt = str(a.dtype)
    if a.dtype.kind not in "biufc":  # ml_dtypes register as kind 'V'/other
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8), dt
    if dt == "bfloat16":
        return a.view(np.uint16), dt
    return a, dt


def _from_storable(a: np.ndarray, logical_dtype: str) -> np.ndarray:
    if str(a.dtype) == logical_dtype:
        return a
    import ml_dtypes
    return a.view(np.dtype(getattr(ml_dtypes, logical_dtype)))


def save(tree: Any, directory: str, step: int) -> str:
    """Write a committed checkpoint; returns its path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    named, _ = _flatten_with_names(tree)
    arrays = {name: np.asarray(leaf) for name, leaf in named}
    stored: Dict[str, np.ndarray] = {}
    logical: Dict[str, str] = {}
    for name, a in arrays.items():
        stored[name], logical[name] = _to_storable(a)
    np.savez(os.path.join(tmp, LEAVES), **stored)
    digest = hashlib.sha256()
    for name in sorted(stored):
        digest.update(name.encode())
        digest.update(stored[name].tobytes())
    manifest = {
        "step": step,
        "leaves": {name: {"shape": list(a.shape), "dtype": logical[name]}
                   for name, a in arrays.items()},
        "hash": digest.hexdigest(),
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(tmp, COMMITTED), "w") as f:
        f.write("ok\n")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def is_committed(path: str) -> bool:
    return os.path.exists(os.path.join(path, COMMITTED))


def verify(path: str) -> bool:
    """Recompute the manifest hash (detects torn/corrupt checkpoints)."""
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, LEAVES)) as z:
            digest = hashlib.sha256()
            for name in sorted(z.files):
                digest.update(name.encode())
                digest.update(z[name].tobytes())
        return digest.hexdigest() == manifest["hash"]
    except Exception:  # torn zip, bad CRC, missing files, bad json, ...
        return False


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and is_committed(
                os.path.join(directory, name)):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(template: Any, directory: str, step: Optional[int] = None, *,
            shardings: Any = None) -> Tuple[Any, int]:
    """Load the latest (or given) committed step into ``template``'s
    structure.  ``shardings``: optional matching pytree of NamedSharding
    to place leaves onto a (possibly different) mesh."""
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:09d}")
    named, treedef = _flatten_with_names(template)
    flat_shardings = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(named))
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, LEAVES)) as z:
        leaves = []
        for (name, tmpl), sh in zip(named, flat_shardings):
            arr = _from_storable(z[name], manifest["leaves"][name]["dtype"])
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def retain(directory: str, keep: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
