"""Host-side KV swap store — the §5.4 suspend/resume data plane.

When the scheduler preempts a victim in ``swap`` mode, the engine
snapshots the victim's per-slot cache slice (every cache leaf, including
the position index and any recurrent SSM state) to HOST memory as NumPy
arrays, together with the request's sampled token ids.  On re-admission
the snapshot is written back into a (possibly different) free slot and
generation continues — no refill prefill, bit-identical state.

The store is pure bookkeeping: one entry per suspended rid, explicit
byte accounting, and fail-fast invariants (double-put and missing-pop
raise).  An optional ``capacity_bytes`` bound models finite host memory;
exceeding it raises ``SwapStoreFullError`` so callers can fall back to
discard-and-recompute.

Two entry granularities share the byte budget:

* ``SwapEntry`` — a whole contiguous slot slice (the batched/legacy
  planes' full suspend).
* ``PageRunEntry`` — a contiguous run of pool PAGES (the paged plane's
  §8 page-level partial preemption; also how the paged plane stores a
  full suspend: one run covering every device page).  Runs for one rid
  stack as the tail is shed repeatedly and always tile a contiguous
  span, restored together in ascending-start order.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


class SwapStoreFullError(RuntimeError):
    pass


def _tree_nbytes(tree: Any) -> int:
    if isinstance(tree, dict):
        return sum(_tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(_tree_nbytes(v) for v in tree)
    return int(np.asarray(tree).nbytes)


@dataclass
class SwapEntry:
    rid: int
    cache: Any                   # pytree of host (NumPy) arrays, one slot
    tokens: List[int]            # prompt + sampled tokens at suspend time
    num_kv: int                  # KV tokens held (Request.suspended_m)
    nbytes: int = field(default=0)

    def __post_init__(self) -> None:
        if not self.nbytes:
            self.nbytes = _tree_nbytes(self.cache)


@dataclass
class PageRunEntry:
    """Page-granular snapshot: a contiguous run of a request's KV pages
    (the §8 partial-preemption unit).  ``kv`` holds the gathered pool
    pages per layer — ``{"k": (L, n_pages, page, Hkv, D), "v": ...}`` —
    and ``start`` is the absolute token position of the run's first
    token (always page-aligned).  Runs for one rid tile [0, suspended
    tokens) contiguously; only the topmost run may end mid-page."""
    rid: int
    start: int
    num_tokens: int
    kv: Any
    nbytes: int = field(default=0)

    def __post_init__(self) -> None:
        if not self.nbytes:
            self.nbytes = _tree_nbytes(self.kv)


class KVSwapStore:
    """rid -> suspended slot snapshot, with byte accounting."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        assert capacity_bytes is None or capacity_bytes > 0
        self.capacity_bytes = capacity_bytes
        self._entries: Dict[int, SwapEntry] = {}
        self._runs: Dict[int, List[PageRunEntry]] = {}
        self._nbytes = 0

    # ------------------------------------------------------------------ #
    def put(self, rid: int, cache: Any, tokens: List[int],
            num_kv: int, nbytes: int = 0) -> SwapEntry:
        """Suspend rid's slot snapshot.  One live entry per rid.

        ``nbytes`` lets callers charge capacity from array metadata
        without forcing a host transfer — the async swap-out path hands
        over device arrays whose D2H copy is still in flight and
        finalizes the entry at drain time."""
        if rid in self._entries:
            raise ValueError(f"rid {rid} already suspended")
        assert num_kv > 0, (rid, num_kv)
        entry = SwapEntry(rid=rid, cache=cache, tokens=list(tokens),
                          num_kv=num_kv, nbytes=nbytes)
        if (self.capacity_bytes is not None
                and self._nbytes + entry.nbytes > self.capacity_bytes):
            raise SwapStoreFullError(
                f"rid {rid}: {entry.nbytes}B over capacity "
                f"({self._nbytes}/{self.capacity_bytes}B held)")
        self._entries[rid] = entry
        self._nbytes += entry.nbytes
        return entry

    def pop(self, rid: int) -> SwapEntry:
        """Restore rid: removes and returns its snapshot."""
        entry = self._entries.pop(rid, None)
        if entry is None:
            raise KeyError(f"rid {rid} not suspended")
        self._nbytes -= entry.nbytes
        return entry

    def peek(self, rid: int) -> SwapEntry:
        return self._entries[rid]

    def discard(self, rid: int) -> bool:
        """Drop a snapshot without restoring (request aborted)."""
        entry = self._entries.pop(rid, None)
        if entry is None:
            return False
        self._nbytes -= entry.nbytes
        return True

    # --- page-granular runs (partial preemption, §8) ------------------- #
    def put_run(self, rid: int, start: int, num_tokens: int,
                kv: Any) -> PageRunEntry:
        """Suspend one contiguous run of rid's KV pages.  Runs stack:
        later runs sit BELOW earlier ones (the tail is shed top-down), so
        entries for a rid always tile a suffix of its context."""
        assert num_tokens > 0, (rid, num_tokens)
        entry = PageRunEntry(rid=rid, start=start, num_tokens=num_tokens,
                             kv=kv)
        if (self.capacity_bytes is not None
                and self._nbytes + entry.nbytes > self.capacity_bytes):
            raise SwapStoreFullError(
                f"rid {rid} run: {entry.nbytes}B over capacity "
                f"({self._nbytes}/{self.capacity_bytes}B held)")
        runs = self._runs.setdefault(rid, [])
        assert all(r.start != start for r in runs), (rid, start)
        runs.append(entry)
        self._nbytes += entry.nbytes
        return entry

    def pop_runs(self, rid: int) -> List[PageRunEntry]:
        """Restore ALL of rid's page runs, sorted by ascending start (the
        order they must be scattered back in)."""
        runs = self._runs.pop(rid, None)
        if not runs:
            raise KeyError(f"rid {rid} has no page runs")
        self._nbytes -= sum(r.nbytes for r in runs)
        return sorted(runs, key=lambda r: r.start)

    def discard_runs(self, rid: int) -> int:
        """Drop rid's page runs without restoring (fallback to
        recompute).  Returns the number of runs dropped."""
        runs = self._runs.pop(rid, None)
        if not runs:
            return 0
        self._nbytes -= sum(r.nbytes for r in runs)
        return len(runs)

    def has_runs(self, rid: int) -> bool:
        return bool(self._runs.get(rid))

    def run_tokens(self, rid: int) -> int:
        return sum(r.num_tokens for r in self._runs.get(rid, []))

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._entries) + len(self._runs)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries or rid in self._runs

    @property
    def nbytes(self) -> int:
        return self._nbytes

    @property
    def suspended_rids(self) -> List[int]:
        return sorted(set(self._entries) | set(self._runs))

    def check_invariants(self) -> None:
        recount = sum(e.nbytes for e in self._entries.values()) \
            + sum(r.nbytes for runs in self._runs.values() for r in runs)
        assert recount == self._nbytes, (recount, self._nbytes)
        if self.capacity_bytes is not None:
            assert self._nbytes <= self.capacity_bytes
        for rid, e in self._entries.items():
            assert rid == e.rid and e.num_kv > 0, (rid, e.rid, e.num_kv)
        for rid, runs in self._runs.items():
            assert runs, rid
            # runs tile a contiguous [min_start, end) span, no overlap
            spans = sorted((r.start, r.num_tokens) for r in runs)
            for (s0, n0), (s1, _) in zip(spans, spans[1:]):
                assert s0 + n0 == s1, (rid, spans)
            for r in runs:
                assert r.rid == rid and r.num_tokens > 0, (rid, r)
