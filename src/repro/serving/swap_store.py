"""Host-side KV swap store — the §5.4 suspend/resume data plane.

When the scheduler preempts a victim in ``swap`` mode, the engine
snapshots the victim's per-slot cache slice (every cache leaf, including
the position index and any recurrent SSM state) to HOST memory as NumPy
arrays, together with the request's sampled token ids.  On re-admission
the snapshot is written back into a (possibly different) free slot and
generation continues — no refill prefill, bit-identical state.

The store is pure bookkeeping: one entry per suspended rid, explicit
byte accounting, and fail-fast invariants (double-put and missing-pop
raise).  An optional ``capacity_bytes`` bound models finite host memory;
exceeding it raises ``SwapStoreFullError`` so callers can fall back to
discard-and-recompute.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


class SwapStoreFullError(RuntimeError):
    pass


def _tree_nbytes(tree: Any) -> int:
    if isinstance(tree, dict):
        return sum(_tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(_tree_nbytes(v) for v in tree)
    return int(np.asarray(tree).nbytes)


@dataclass
class SwapEntry:
    rid: int
    cache: Any                   # pytree of host (NumPy) arrays, one slot
    tokens: List[int]            # prompt + sampled tokens at suspend time
    num_kv: int                  # KV tokens held (Request.suspended_m)
    nbytes: int = field(default=0)

    def __post_init__(self) -> None:
        if not self.nbytes:
            self.nbytes = _tree_nbytes(self.cache)


class KVSwapStore:
    """rid -> suspended slot snapshot, with byte accounting."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        assert capacity_bytes is None or capacity_bytes > 0
        self.capacity_bytes = capacity_bytes
        self._entries: Dict[int, SwapEntry] = {}
        self._nbytes = 0

    # ------------------------------------------------------------------ #
    def put(self, rid: int, cache: Any, tokens: List[int],
            num_kv: int, nbytes: int = 0) -> SwapEntry:
        """Suspend rid's slot snapshot.  One live entry per rid.

        ``nbytes`` lets callers charge capacity from array metadata
        without forcing a host transfer — the async swap-out path hands
        over device arrays whose D2H copy is still in flight and
        finalizes the entry at drain time."""
        if rid in self._entries:
            raise ValueError(f"rid {rid} already suspended")
        assert num_kv > 0, (rid, num_kv)
        entry = SwapEntry(rid=rid, cache=cache, tokens=list(tokens),
                          num_kv=num_kv, nbytes=nbytes)
        if (self.capacity_bytes is not None
                and self._nbytes + entry.nbytes > self.capacity_bytes):
            raise SwapStoreFullError(
                f"rid {rid}: {entry.nbytes}B over capacity "
                f"({self._nbytes}/{self.capacity_bytes}B held)")
        self._entries[rid] = entry
        self._nbytes += entry.nbytes
        return entry

    def pop(self, rid: int) -> SwapEntry:
        """Restore rid: removes and returns its snapshot."""
        entry = self._entries.pop(rid, None)
        if entry is None:
            raise KeyError(f"rid {rid} not suspended")
        self._nbytes -= entry.nbytes
        return entry

    def peek(self, rid: int) -> SwapEntry:
        return self._entries[rid]

    def discard(self, rid: int) -> bool:
        """Drop a snapshot without restoring (request aborted)."""
        entry = self._entries.pop(rid, None)
        if entry is None:
            return False
        self._nbytes -= entry.nbytes
        return True

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    @property
    def nbytes(self) -> int:
        return self._nbytes

    @property
    def suspended_rids(self) -> List[int]:
        return sorted(self._entries)

    def check_invariants(self) -> None:
        recount = sum(e.nbytes for e in self._entries.values())
        assert recount == self._nbytes, (recount, self._nbytes)
        if self.capacity_bytes is not None:
            assert self._nbytes <= self.capacity_bytes
        for rid, e in self._entries.items():
            assert rid == e.rid and e.num_kv > 0, (rid, e.rid, e.num_kv)
