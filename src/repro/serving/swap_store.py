"""Host-side KV swap store — the §5.4 suspend/resume data plane.

When the scheduler preempts a victim in ``swap`` mode, the engine
snapshots the victim's per-slot cache slice (every cache leaf, including
the position index and any recurrent SSM state) to HOST memory as NumPy
arrays, together with the request's sampled token ids.  On re-admission
the snapshot is written back into a (possibly different) free slot and
generation continues — no refill prefill, bit-identical state.

The store is pure bookkeeping: one entry per suspended rid, explicit
byte accounting, and fail-fast invariants (double-put and missing-pop
raise).  An optional ``capacity_bytes`` bound models finite host memory;
exceeding it raises ``SwapStoreFullError`` so callers can fall back to
discard-and-recompute.

Three entry granularities share the byte budget:

* ``SwapEntry`` — a whole contiguous slot slice (the batched/legacy
  planes' full suspend).
* ``PageRunEntry`` — a contiguous run of pool PAGES (the paged plane's
  §8 page-level partial preemption; also how the paged plane stores a
  full suspend: one run covering every device page).  Runs for one rid
  stack as the tail is shed repeatedly and always tile a contiguous
  span, restored together in ascending-start order.
* ``PrefixPageEntry`` — the HOST DEMOTION TIER of the prefix cache: a
  refcount-free snapshot of ONE registry page evicted by the page-pool
  replacement policy, keyed by its chain hash (not a rid — no request
  owns it).  A later registry miss that matches the key (token-verified,
  like the device registry) promotes it back through the swap path.
  Unlike suspend entries, demoted prefixes may legitimately outlive the
  run — ``__len__`` counts only suspend bookkeeping, so end-of-run
  leak checks stay meaningful.

Integrity: every entry kind carries an optional CRC32 *seal*
(``seal_entry``, computed once over the host bytes when they
materialize — at put for sync paths, at drain for async ones) that
``verify_entry`` re-checks at swap-in / promotion.  A mismatch means
the host snapshot rotted (or the fault plan flipped a bit in it); the
caller drops the entry and degrades the request to recompute rather
than ever restoring wrong KV.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.invariants import invariant


class SwapStoreFullError(RuntimeError):
    pass


def _tree_nbytes(tree: Any) -> int:
    if isinstance(tree, dict):
        return sum(_tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(_tree_nbytes(v) for v in tree)
    return int(np.asarray(tree).nbytes)


def _tree_crc(tree: Any, crc: int = 0) -> int:
    """CRC32 over every array leaf, traversed in a deterministic order
    (sorted dict keys) so the seal is content-addressed."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            crc = _tree_crc(tree[k], crc)
        return crc
    if isinstance(tree, (list, tuple)):
        for v in tree:
            crc = _tree_crc(v, crc)
        return crc
    arr = np.ascontiguousarray(np.asarray(tree))
    return zlib.crc32(arr.tobytes(), crc)


def seal_entry(entry: Any) -> None:
    """Stamp ``entry.crc`` from its host bytes.  Idempotent: a second
    seal is a no-op, which matters for crash recovery — after a step
    rollback the engine may re-drain an entry whose bytes were already
    sealed (and possibly corrupted by the fault plan); re-sealing would
    bless the corruption."""
    if entry.crc is not None:
        return
    data = entry.cache if isinstance(entry, SwapEntry) else entry.kv
    if data is None:
        return                       # metadata-only shadow (simulator)
    entry.crc = _tree_crc(data)


def verify_entry(entry: Any) -> bool:
    """True iff the entry's bytes still match its seal (unsealed or
    metadata-only entries verify trivially)."""
    if entry.crc is None:
        return True
    data = entry.cache if isinstance(entry, SwapEntry) else entry.kv
    if data is None:
        return True
    return _tree_crc(data) == entry.crc


def _leaf_sites(tree: Any):
    """Yield ``(parent, key, nbytes)`` for every array leaf reachable
    through a mutable container (dict/list)."""
    if isinstance(tree, dict):
        items = [(tree, k, tree[k]) for k in sorted(tree)]
    elif isinstance(tree, (list, tuple)):
        items = [(tree, i, v) for i, v in enumerate(tree)]
    else:
        return
    for parent, key, val in items:
        if isinstance(val, (dict, list, tuple)):
            yield from _leaf_sites(val)
        else:
            yield parent, key, int(np.asarray(val).nbytes)


def flip_bit(tree: Any) -> bool:
    """Corrupt the *largest* array leaf (one bit of byte 0) — the fault
    plan's model of host-memory rot.  Targeting the biggest buffer
    models where rot lands in practice (the KV bytes, not the few-byte
    bookkeeping arrays riding in the same pytree) and keeps metadata
    like the slot ``index`` array intact for the engine's drain-time
    sanity asserts.  ``jax.device_get`` may hand back read-only views,
    so the leaf is *replaced* in its parent container by a flipped host
    copy rather than mutated in place.  Returns False if no leaf is
    reachable through a mutable container."""
    best = None
    for parent, key, nbytes in _leaf_sites(tree):
        if nbytes and (best is None or nbytes > best[2]):
            best = (parent, key, nbytes)
    if best is None or isinstance(best[0], tuple):
        return False
    parent, key, _ = best
    arr = np.array(np.asarray(parent[key]), copy=True)
    arr.view(np.uint8).reshape(-1)[0] ^= 1
    parent[key] = arr
    return True


@dataclass
class SwapEntry:
    rid: int
    cache: Any                   # pytree of host (NumPy) arrays, one slot
    tokens: List[int]            # prompt + sampled tokens at suspend time
    num_kv: int                  # KV tokens held (Request.suspended_m)
    nbytes: int = field(default=0)
    crc: Optional[int] = None    # integrity seal (seal_entry)
    corrupt: bool = False        # fault-plan marker: bytes were flipped

    def __post_init__(self) -> None:
        if not self.nbytes:
            self.nbytes = _tree_nbytes(self.cache)


@dataclass
class PageRunEntry:
    """Page-granular snapshot: a contiguous run of a request's KV pages
    (the §8 partial-preemption unit).  ``kv`` holds the gathered pool
    pages per layer — ``{"k": (L, n_pages, page, Hkv, D), "v": ...}`` —
    and ``start`` is the absolute token position of the run's first
    token (always page-aligned).  Runs for one rid tile [0, suspended
    tokens) contiguously; only the topmost run may end mid-page."""
    rid: int
    start: int
    num_tokens: int
    kv: Any
    nbytes: int = field(default=0)
    crc: Optional[int] = None
    corrupt: bool = False

    def __post_init__(self) -> None:
        if not self.nbytes:
            self.nbytes = _tree_nbytes(self.kv)


@dataclass
class PrefixPageEntry:
    """Host-demoted prefix-cache page (refcount-free: keyed by chain
    hash, owned by no request).  ``tokens`` are the page's token ids
    (collision verification at promotion, exactly like the device
    registry); ``n_kvs`` the chain depth the replacement policy scores
    with; ``kv`` the per-layer page snapshot ``{"k": (L, 1, page, Hkv,
    D), "v": ...}`` — or None for metadata-only shadows (the simulator
    charges virtual time without moving bytes; pass ``nbytes``)."""
    key: int
    tokens: tuple
    n_kvs: int
    kv: Any
    nbytes: int = field(default=0)
    crc: Optional[int] = None
    corrupt: bool = False

    def __post_init__(self) -> None:
        if not self.nbytes and self.kv is not None:
            self.nbytes = _tree_nbytes(self.kv)


class KVSwapStore:
    """rid -> suspended slot snapshot, with byte accounting."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        if not (capacity_bytes is None or capacity_bytes > 0):
            raise ValueError(f"capacity_bytes={capacity_bytes}")
        self.capacity_bytes = capacity_bytes
        self._entries: Dict[int, SwapEntry] = {}
        self._runs: Dict[int, List[PageRunEntry]] = {}
        self._prefixes: Dict[int, PrefixPageEntry] = {}
        self._nbytes = 0

    # ------------------------------------------------------------------ #
    def put(self, rid: int, cache: Any, tokens: List[int],
            num_kv: int, nbytes: int = 0) -> SwapEntry:
        """Suspend rid's slot snapshot.  One live entry per rid.

        ``nbytes`` lets callers charge capacity from array metadata
        without forcing a host transfer — the async swap-out path hands
        over device arrays whose D2H copy is still in flight and
        finalizes the entry at drain time."""
        if rid in self._entries:
            raise ValueError(f"rid {rid} already suspended")
        if num_kv <= 0:
            raise ValueError(f"rid {rid}: num_kv={num_kv}")
        entry = SwapEntry(rid=rid, cache=cache, tokens=list(tokens),
                          num_kv=num_kv, nbytes=nbytes)
        if (self.capacity_bytes is not None
                and self._nbytes + entry.nbytes > self.capacity_bytes):
            raise SwapStoreFullError(
                f"rid {rid}: {entry.nbytes}B over capacity "
                f"({self._nbytes}/{self.capacity_bytes}B held)")
        self._entries[rid] = entry
        self._nbytes += entry.nbytes
        return entry

    def pop(self, rid: int) -> SwapEntry:
        """Restore rid: removes and returns its snapshot."""
        entry = self._entries.pop(rid, None)
        if entry is None:
            raise KeyError(f"rid {rid} not suspended")
        self._nbytes -= entry.nbytes
        return entry

    def peek(self, rid: int) -> SwapEntry:
        return self._entries[rid]

    def discard(self, rid: int) -> bool:
        """Drop a snapshot without restoring (request aborted)."""
        entry = self._entries.pop(rid, None)
        if entry is None:
            return False
        self._nbytes -= entry.nbytes
        return True

    # --- page-granular runs (partial preemption, §8) ------------------- #
    def put_run(self, rid: int, start: int, num_tokens: int,
                kv: Any, nbytes: int = 0) -> PageRunEntry:
        """Suspend one contiguous run of rid's KV pages.  Runs stack:
        later runs sit BELOW earlier ones (the tail is shed top-down), so
        entries for a rid always tile a suffix of its context.

        ``nbytes`` mirrors ``put``: the async page-run path hands over a
        device-side gather whose D2H copy is still in flight and charges
        capacity from array metadata; the entry is finalized at drain."""
        if num_tokens <= 0:
            raise ValueError(f"rid {rid}: num_tokens={num_tokens}")
        entry = PageRunEntry(rid=rid, start=start, num_tokens=num_tokens,
                             kv=kv, nbytes=nbytes)
        if (self.capacity_bytes is not None
                and self._nbytes + entry.nbytes > self.capacity_bytes):
            raise SwapStoreFullError(
                f"rid {rid} run: {entry.nbytes}B over capacity "
                f"({self._nbytes}/{self.capacity_bytes}B held)")
        runs = self._runs.setdefault(rid, [])
        if any(r.start == start for r in runs):
            raise ValueError(f"rid {rid}: run at start {start} exists")
        runs.append(entry)
        self._nbytes += entry.nbytes
        return entry

    def pop_runs(self, rid: int) -> List[PageRunEntry]:
        """Restore ALL of rid's page runs, sorted by ascending start (the
        order they must be scattered back in)."""
        runs = self._runs.pop(rid, None)
        if not runs:
            raise KeyError(f"rid {rid} has no page runs")
        self._nbytes -= sum(r.nbytes for r in runs)
        return sorted(runs, key=lambda r: r.start)

    def discard_runs(self, rid: int) -> int:
        """Drop rid's page runs without restoring (fallback to
        recompute).  Returns the number of runs dropped."""
        runs = self._runs.pop(rid, None)
        if not runs:
            return 0
        self._nbytes -= sum(r.nbytes for r in runs)
        return len(runs)

    def has_runs(self, rid: int) -> bool:
        return bool(self._runs.get(rid))

    def peek_runs(self, rid: int) -> List[PageRunEntry]:
        """Read-only view of rid's stored runs (integrity checks)."""
        return list(self._runs.get(rid, []))

    def run_tokens(self, rid: int) -> int:
        return sum(r.num_tokens for r in self._runs.get(rid, []))

    # --- host demotion tier of the prefix cache ------------------------ #
    def put_prefix(self, key: int, tokens, n_kvs: int, kv: Any,
                   nbytes: int = 0) -> PrefixPageEntry:
        """Demote one evicted registry page to host memory."""
        if key in self._prefixes:
            raise ValueError(f"prefix key {key} already demoted")
        entry = PrefixPageEntry(key=key, tokens=tuple(tokens),
                                n_kvs=int(n_kvs), kv=kv, nbytes=nbytes)
        if (self.capacity_bytes is not None
                and self._nbytes + entry.nbytes > self.capacity_bytes):
            raise SwapStoreFullError(
                f"prefix key {key}: {entry.nbytes}B over capacity "
                f"({self._nbytes}/{self.capacity_bytes}B held)")
        self._prefixes[key] = entry
        self._nbytes += entry.nbytes
        return entry

    def peek_prefix(self, key: int,
                    tokens=None) -> Optional[PrefixPageEntry]:
        """Host-tier lookup; a token mismatch (hash collision) is a
        MISS, never another prompt's KV."""
        entry = self._prefixes.get(key)
        if entry is None:
            return None
        if tokens is not None and tuple(tokens) != entry.tokens:
            return None
        return entry

    def pop_prefix(self, key: int) -> PrefixPageEntry:
        """Promote: remove and return the demoted page snapshot."""
        entry = self._prefixes.pop(key, None)
        if entry is None:
            raise KeyError(f"prefix key {key} not demoted")
        self._nbytes -= entry.nbytes
        return entry

    def discard_prefix(self, key: int) -> bool:
        entry = self._prefixes.pop(key, None)
        if entry is None:
            return False
        self._nbytes -= entry.nbytes
        return True

    def has_prefix(self, key: int) -> bool:
        return key in self._prefixes

    @property
    def num_prefix_entries(self) -> int:
        return len(self._prefixes)

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        # suspend bookkeeping only: demoted prefixes (keyed by chain
        # hash, not rid) may outlive the run by design
        return len(self._entries) + len(self._runs)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries or rid in self._runs

    @property
    def nbytes(self) -> int:
        return self._nbytes

    @property
    def suspended_rids(self) -> List[int]:
        return sorted(set(self._entries) | set(self._runs))

    def check_invariants(self) -> None:
        recount = sum(e.nbytes for e in self._entries.values()) \
            + sum(r.nbytes for runs in self._runs.values() for r in runs) \
            + sum(p.nbytes for p in self._prefixes.values())
        invariant(recount == self._nbytes, (recount, self._nbytes))
        if self.capacity_bytes is not None:
            invariant(self._nbytes <= self.capacity_bytes,
                      (self._nbytes, self.capacity_bytes))
        for rid, e in self._entries.items():
            invariant(rid == e.rid and e.num_kv > 0, (rid, e.rid, e.num_kv))
        for key, p in self._prefixes.items():
            invariant(key == p.key and p.n_kvs > 0, (key, p.key, p.n_kvs))
        for rid, runs in self._runs.items():
            invariant(runs, rid)
            # runs tile a contiguous [min_start, end) span, no overlap
            spans = sorted((r.start, r.num_tokens) for r in runs)
            for (s0, n0), (s1, _) in zip(spans, spans[1:]):
                invariant(s0 + n0 == s1, (rid, spans))
            for r in runs:
                invariant(r.rid == rid and r.num_tokens > 0, (rid, r))
