"""Deterministic fault injection for the serving control plane.

A :class:`FaultPlan` is a *pure function* from (seed, fault kind,
content key) to "fail or not".  Both the engine and the simulator
build their own plan from the same :class:`FaultSpec` (threaded
through ``EngineConfig``/``SchedulerConfig`` like ``page_size``) and
consult it at the same decision points with the same keys, so the two
sides observe the *same* fault schedule without sharing any mutable
state — that is what keeps engine-vs-simulator parity byte-exact under
injected faults.

Content keying (rather than a draw counter) makes draws idempotent:
when the engine aborts a step attempt, rolls back, and retries, the
re-issued store puts see the same verdicts, so a fault schedule cannot
drift between an aborted attempt and its successful retry (or between
the engine, which aborts, and the simulator, which never does).  The
one exception is page-allocation faults, which model *transient device
errors*: those are keyed by (step, attempt, ordinal) so a retried
attempt clears them — they are trace-free aborts the simulator never
sees.

Hashing is ``zlib.crc32`` over ``repr`` of the key tuple — stable
across processes (unlike salted ``hash()``), cheap, and uniform enough
for fault rates.

Prefix demotions interact with the radix trie (PR 9) page-by-page: an
evicted node run lands in the host tier as consecutive
``PrefixPageEntry`` snapshots, each CRC-sealed and each drawing its
own ``corrupt_prefix`` / ``promote_fail`` verdict under its chain key.
A failed verdict mid-run therefore truncates the promotion exactly
where the rot is — the surviving front still attaches (the trie's
partial-hit path) and only the tail recomputes.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, List, Optional, Tuple

from repro.serving.swap_store import SwapStoreFullError


class FaultError(RuntimeError):
    """An injected transient device fault (e.g. page allocation).

    Aborts the current step attempt; the engine rolls back to batch
    start and retries the step.  Never escapes ``Engine.step``.
    """


class TransientStoreError(RuntimeError):
    """A swap-store write failed transiently; retried with backoff."""


class PermanentStoreError(SwapStoreFullError):
    """A swap-store write failed permanently.

    Subclasses ``SwapStoreFullError`` so every existing store-full
    fallback path — drop the snapshot, count a ``swap_fallbacks``,
    degrade the victim to recompute — handles it unchanged.
    """


class IntegrityError(RuntimeError):
    """A host-resident KV snapshot failed its CRC (or was marked
    corrupt by the fault plan) at swap-in / promote time.

    Carries ``repairs``: closures the engine applies *after* rolling
    the step back, which drop the corrupt entry and degrade the victim
    request to recompute.  The retried step then schedules without the
    poisoned snapshot.
    """

    def __init__(self, message: str,
                 repairs: Optional[List[Callable[[], None]]] = None):
        super().__init__(message)
        self.repairs: List[Callable[[], None]] = list(repairs or [])


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault rates, all in [0, 1] (0 = never)."""
    seed: int = 0
    p_alloc: float = 0.0            # transient page-allocation failure
    p_store_transient: float = 0.0  # store put fails, succeeds on retry
    p_store_permanent: float = 0.0  # store put fails for good
    p_corrupt: float = 0.0          # host snapshot corrupted after put
    p_demote_fail: float = 0.0      # async prefix demotion never lands
    p_promote_fail: float = 0.0     # prefix promotion read fails

    def __post_init__(self):
        for f in dataclasses.fields(self):
            if f.name == "seed":
                continue
            v = getattr(self, f.name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{f.name}={v} outside [0, 1]")


class FaultPlan:
    """Seeded, stateless oracle answering "does this operation fail?"."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec

    # ------------------------------------------------------------- #
    # core draw
    # ------------------------------------------------------------- #
    def _unit(self, kind: str, key: Tuple) -> float:
        h = zlib.crc32(repr((self.spec.seed, kind, key)).encode())
        return h / 2 ** 32

    def decide(self, kind: str, *key) -> bool:
        p = getattr(self.spec, "p_" + _RATE_OF[kind])
        return p > 0.0 and self._unit(kind, key) < p

    # ------------------------------------------------------------- #
    # named draws
    # ------------------------------------------------------------- #
    def alloc_fault(self, step_no: int, attempt: int, ordinal: int) -> bool:
        """Transient device fault on the ordinal-th page allocation of
        this (step, attempt).  Attempt-keyed: a retried step draws
        fresh, so allocation faults cannot livelock the step loop."""
        return self.decide("alloc", step_no, attempt, ordinal)

    def transient_failures(self, kind: str, *key) -> int:
        """How many times this store put fails transiently before
        succeeding: 0 (common) or a content-derived count in 1..3 —
        always within ``run_with_retries``'s budget, so a transient
        fault alone never escalates."""
        if not self.decide(kind, *key):
            return 0
        return 1 + zlib.crc32(
            repr((self.spec.seed, "k_fail", kind, key)).encode()) % 3


# Maps draw kind -> FaultSpec rate field.  Distinct kinds over the same
# key hash independently (the kind is inside the CRC).
_RATE_OF = {
    "alloc": "alloc",
    "store_put": "store_transient",      # full-suspend snapshot put
    "store_run": "store_transient",      # tail-shed page-run put
    "perm_put": "store_permanent",
    "perm_run": "store_permanent",
    "corrupt_put": "corrupt",
    "corrupt_run": "corrupt",
    "corrupt_prefix": "corrupt",
    "demote_fail": "demote_fail",
    "promote_fail": "promote_fail",
}
