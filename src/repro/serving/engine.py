"""Continuous-batching serving engine — REAL JAX execution of the paper's
schedules (the "deployment" path of Fig. 1; the simulator is the blue
path).

The engine drives the unified ``Scheduler`` (Algorithm 1) against an
actual model.  Token-level memory accounting (the scheduler's M) is
backed by a ``PagedAllocator``; the data plane stores each request in a
contiguous cache slot (on TPU, dynamic-slice slots are the idiomatic
layout — pointer-chasing page tables are a CUDA idiom; see DESIGN.md).

Execution plane (PR 2) — shape-stable and batched, selected by
``EngineConfig.plane``:

* ``"batched"`` (default) — all prefill work of a scheduler batch runs
  as rounds of ONE ``prefill_many`` call over the full (nslots, bucket)
  token grid.  Chunks are padded to a fixed bucket ladder (powers of
  two up to ``chunk``) and an explicit per-row ``length`` mask is
  threaded through ``models.model.prefill_chunk`` down to the attention
  / SSM / RWKV internals, so one compiled XLA signature per bucket
  serves every chunk size, request count, and prompt length: the number
  of distinct compiles is a small constant (see
  ``Engine.num_compiles`` and the compile-count regression test).
  Inactive rows carry length 0 and are provably inert.
* ``"legacy"`` — the PR-1 per-request chunk loop with exact (unpadded)
  shapes: every distinct tail length triggers a fresh XLA compile.
  Kept as the honest baseline for ``benchmarks/fig_engine_wall.py``.

Sampling is FUSED into the jitted steps: greedy argmax over the real
vocabulary happens on device and only (nslots,) int32 token ids ever
cross to the host — the full (nslots, vocab) logits array is never
materialized off-device.  ``EngineConfig.decode_append="deferred"``
routes decode through ``model.decode_step_deferred`` (one cache scatter
per step instead of one per layer).

Preemption supports BOTH §5.4 restoration paths, selected by
``SchedulerConfig.preempt_mode``:

* ``recompute`` — the victim's slot is freed and its KVs discarded; on
  re-admission it pays a full refill prefill (the §3 refill).
* ``swap`` — the victim's slot slice (every cache leaf, including the
  position index and recurrent SSM state) is snapshotted to a host-side
  ``KVSwapStore``; on re-admission the snapshot is written back into a
  free slot and generation continues where it stopped —
  ``Request.remaining_prefill`` sees the restored KVs, so no refill runs.
  If the store's ``EngineConfig.swap_bytes`` capacity is exhausted the
  victim falls back to discard-and-recompute for that preemption.
* ``auto`` — per-victim Fig. 8 decision via the cost model
  (``swap_time`` vs ``kv_projection_time``/``recompute_time``).

Swap-out transfers are ASYNC by default (``EngineConfig.async_swap``):
the victim's slot slice is computed on device (a fresh buffer — later
cache updates cannot alias it), ``copy_to_host_async`` starts the D2H
transfer off the critical path, and the snapshot is finalized
(double-buffered, at most two in flight) at the next step boundary or
on demand when the victim is re-admitted within the same drain window.
Store capacity is charged at enqueue time from array metadata — a full
store still falls back to recompute synchronously — and virtual-time
charges are identical to the sync path.

Virtual time charges ``cost_model.swap_time`` for each swap-out and
swap-in, mirroring the simulator, so simulated and engine schedules
agree.  Measured wall times of the host transfers are tracked in
``Engine.swap_stats`` (the fig08 validation column); per-batch measured
wall time lands in ``BatchLog.wall_s``.

Correctness contract (tested): scheduling, chunking, batching, padding
and preemption — under recompute, swap, AND auto — NEVER change the
generated tokens, exactly the paper's "standard inference optimization
techniques that do not affect inference outputs".  At the models layer
the padded cache state is bit-identical to the unpadded call for the
pure-attention family; for the recurrent families (SSM/RWKV) padding
changes the inner scans' chunk factorization, so states agree to float
reduction-order noise (~1e-7 relative) — the same order as the
chunked-vs-full divergence the parity oracle already tolerates, below
anything that flips a greedy argmax in practice.
"""
from __future__ import annotations

import functools
import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import BatchSpec, CostModel
from repro.core.kvcache import PagedAllocator
from repro.core.request import Request
from repro.core.scheduler import Scheduler
from repro.core.simulator import BatchLog, SimResult
from repro.models import model as M
from repro.serving.serve_step import build_prefill_chunk_fn
from repro.serving.swap_store import (KVSwapStore, SwapEntry,
                                      SwapStoreFullError)


@dataclass
class EngineConfig:
    nslots: int = 8
    cache_len: int = 256          # per-slot context capacity (tokens)
    chunk: int = 64               # chunked-prefill chunk size
    page_size: int = 1            # allocator granularity (1 = token-exact,
    #                               matching the scheduler's M accounting)
    impl: str = "reference"       # attention backend
    moe_impl: str = "dense"       # chunk-invariant dispatch for parity
    swap_bytes: Optional[int] = None   # host swap-store capacity (None =
    #                                    unbounded); a full store makes the
    #                                    victim fall back to recompute
    check_invariants: bool = True
    # --- execution plane (PR 2) --------------------------------------- #
    plane: str = "batched"        # "batched" (shape-stable bucketed
    #                               prefill_many) | "legacy" (PR-1
    #                               per-request exact-shape chunk loop)
    decode_append: str = "inline"   # "inline" | "deferred" (one cache
    #                                 scatter per step, §Perf cell A)
    async_swap: bool = True       # double-buffered async swap-out D2H
    min_bucket: int = 8           # smallest tail bucket of the ladder


def _bucket_ladder(chunk: int, min_bucket: int) -> List[int]:
    """Fixed padding targets: powers of two in [min_bucket, chunk), plus
    ``chunk`` itself.  Every prefill sub-chunk is padded UP to the
    smallest bucket that holds it, so at most ``len(ladder)`` distinct
    prefill signatures ever compile."""
    b = 1
    while b < min(min_bucket, chunk):
        b *= 2
    ladder = []
    while b < chunk:
        ladder.append(b)
        b *= 2
    ladder.append(chunk)
    return ladder


def _slot_axis(leaf: jnp.ndarray) -> int:
    """Cache leaves are (L, B, ...) except index (B,)."""
    return 0 if leaf.ndim == 1 else 1


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, scheduler: Scheduler,
                 ecfg: Optional[EngineConfig] = None,
                 cost_model: Optional[CostModel] = None):
        # copy the config: a shared default (or caller-reused) instance
        # must not be mutated by the per-model chunk clamp below
        ecfg = replace(ecfg) if ecfg is not None else EngineConfig()
        if cfg.window:
            ecfg.chunk = min(ecfg.chunk, cfg.window)
        assert ecfg.plane in ("batched", "legacy"), ecfg.plane
        assert ecfg.decode_append in ("inline", "deferred"), ecfg.decode_append
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.sched = scheduler
        self.cost_model = cost_model
        if scheduler.cost_model is None:
            scheduler.cost_model = cost_model   # auto preempt-mode pricing
        scheduler.cfg.max_running = ecfg.nslots
        # init_cache caps the per-slot KV length at cfg.window internally
        self.cache = M.init_cache(cfg, ecfg.nslots, ecfg.cache_len)
        self.allocator = PagedAllocator(
            num_pages=max(1, scheduler.cfg.M // ecfg.page_size),
            page_size=ecfg.page_size)
        self.free_slots: List[int] = list(range(ecfg.nslots - 1, -1, -1))
        self.slot_of: Dict[int, int] = {}
        self.token_ids: Dict[int, List[int]] = {}
        self.outputs: Dict[int, List[int]] = {}
        self.buckets = _bucket_ladder(ecfg.chunk, ecfg.min_bucket)
        self.swap_store = KVSwapStore(capacity_bytes=ecfg.swap_bytes)
        # in-flight async swap-out snapshots (rid -> (store entry whose
        # cache leaves are still device arrays mid-D2H, enqueue step)).
        # An entry enqueued during step N overlaps its D2H copy with
        # step N+1's compute and is finalized at the END of step N+1 —
        # or earlier, on same-window re-admission / double-buffer
        # pressure (more than two transfers in flight).
        self._pending_swaps: "OrderedDict[int, Tuple[SwapEntry, int]]" = \
            OrderedDict()
        self._step_no = 0
        # measured host-transfer wall times (fig08 validation column)
        self.swap_stats: Dict[str, float] = dict(
            swap_outs=0, swap_ins=0, kv_out=0, kv_in=0, swap_fallbacks=0,
            drains_on_swapin=0, wall_out_s=0.0, wall_in_s=0.0)
        # swap-out virtual-time charges from rounds that admitted no
        # items, owed to the next executed batch (mirrors the simulator)
        self._carry_swap_s = 0.0
        self._carry_out = 0
        self.now = 0.0
        self.wall = 0.0
        self.batch_logs: List[BatchLog] = []
        self._build_jits()

    # ------------------------------------------------------------------ #
    def _build_jits(self) -> None:
        cfg, ecfg = self.cfg, self.ecfg
        vocab = cfg.vocab_size

        def mask_merge(active, new_cache, old_cache):
            def merge(new, old):
                ax = _slot_axis(new)
                m = active.reshape(
                    (1,) * ax + (-1,) + (1,) * (new.ndim - ax - 1))
                return jnp.where(m, new, old)
            return jax.tree.map(merge, new_cache, old_cache)

        def slot_slice(cache, slot):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1,
                                                       _slot_axis(a)), cache)

        def slot_write(cache, upd, slot):
            return jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_slice_in_dim(
                    a, u, slot, _slot_axis(a)), cache, upd)

        def prefill_one(params, cache, slot, tokens):
            sl = slot_slice(cache, slot)
            logits, new_sl = M.prefill_chunk(cfg, params, tokens, sl,
                                             impl=ecfg.impl,
                                             moe_impl=ecfg.moe_impl)
            return logits[0], slot_write(cache, new_sl, slot)

        chunk_fn = build_prefill_chunk_fn(cfg, impl=ecfg.impl,
                                          moe_impl=ecfg.moe_impl)

        def prefill_many(params, cache, tokens, lengths):
            """One batched bucketed chunk round over ALL slots.
            tokens (nslots, bucket); lengths (nslots,), 0 = inert row.
            Returns (greedy token ids (nslots,), merged cache) — fused
            on-device sampling, full logits never leave the device."""
            logits, new_cache = chunk_fn(params, tokens, cache, lengths)
            toks = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
            return toks, mask_merge(lengths > 0, new_cache, cache)

        decode_step = (M.decode_step_deferred
                       if ecfg.decode_append == "deferred"
                       else M.decode_step)

        def decode_many(params, cache, tokens, mask):
            logits, new_cache = decode_step(cfg, params, tokens, cache,
                                            impl=ecfg.impl,
                                            moe_impl=ecfg.moe_impl)
            toks = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
            return toks, mask_merge(mask, new_cache, cache)

        def reset_slot(cache, slot):
            zeroed = jax.tree.map(
                lambda a: jnp.zeros_like(
                    jax.lax.dynamic_slice_in_dim(a, slot, 1, _slot_axis(a))),
                cache)
            return slot_write(cache, zeroed, slot)

        self._prefill_one = jax.jit(prefill_one)
        self._prefill_many = jax.jit(prefill_many)
        self._decode_many = jax.jit(decode_many)
        self._reset_slot = jax.jit(reset_slot)
        # swap data plane: slot snapshot (device->host) and slot restore
        self._slot_slice = jax.jit(slot_slice)
        self._slot_write = jax.jit(slot_write)
        self._jit_fns = [self._prefill_one, self._prefill_many,
                         self._decode_many, self._reset_slot,
                         self._slot_slice, self._slot_write]

    @property
    def num_compiles(self) -> int:
        """Distinct XLA compiles across every engine entry point.  The
        batched plane keeps this a small constant — independent of
        request count, prompt lengths, and preemptions (tested)."""
        return sum(f._cache_size() for f in self._jit_fns)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise AssertionError(f"chunk step {n} exceeds ladder {self.buckets}")

    # ------------------------------------------------------------------ #
    def submit(self, r: Request) -> None:
        assert r.prompt is not None, "engine requests need real token ids"
        assert len(r.prompt) == r.input_len
        # window/ssm archs hold bounded state; dense caches must fit
        assert self.cfg.window or self.cfg.family == "ssm" \
            or r.peak_kv <= self.ecfg.cache_len, \
            f"request {r.rid} peak KV {r.peak_kv} > cache_len"
        self.token_ids[r.rid] = list(r.prompt)
        self.outputs[r.rid] = []
        self.sched.add_request(r)

    # ------------------------------------------------------------------ #
    def _claim_slot(self, rid: int, reset: bool = True) -> int:
        slot = self.free_slots.pop()
        self.slot_of[rid] = slot
        if reset:
            self.cache = self._reset_slot(self.cache, slot)
        return slot

    def _release(self, rid: int) -> None:
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self.free_slots.append(slot)
        self.allocator.free(rid)
        # refill restarts from scratch: drop generated tokens beyond prompt?
        # NO — generated tokens are kept and re-prefilled (paper §3 refill).

    # --- §5.4 swap data plane ------------------------------------------ #
    def _swap_out(self, victim: Request) -> bool:
        """Snapshot the victim's slot to the host store, then free it.
        Returns False when the store is full: the snapshot is dropped and
        the victim falls back to discard-and-recompute (finite host
        memory is the five-minute-rule's operating constraint).

        With ``async_swap`` the snapshot is a device-side slice whose
        host copy is started here and finalized later (``_drain_swaps``);
        capacity is charged immediately from array metadata so the
        full-store fallback stays synchronous and deterministic."""
        t0 = time.perf_counter()
        slot = self.slot_of[victim.rid]
        snap = self._slot_slice(self.cache, jnp.int32(slot))
        try:
            if self.ecfg.async_swap:
                nbytes = sum(l.nbytes for l in jax.tree.leaves(snap))
                entry = self.swap_store.put(
                    victim.rid, snap, self.token_ids[victim.rid],
                    victim.suspended_m, nbytes=nbytes)
                for leaf in jax.tree.leaves(snap):
                    leaf.copy_to_host_async()
                self._pending_swaps[victim.rid] = (entry, self._step_no)
            else:
                snap = jax.device_get(snap)
                self.swap_store.put(victim.rid, snap,
                                    self.token_ids[victim.rid],
                                    victim.suspended_m)
                if self.ecfg.check_invariants:
                    assert int(np.asarray(snap["index"])[0]) \
                        == victim.suspended_m, \
                        (victim.rid, snap["index"], victim.suspended_m)
        except SwapStoreFullError:
            victim.drop_suspended()
            self.sched.num_swaps -= 1   # the suspend did not stick
            self.swap_stats["swap_fallbacks"] += 1
            self._release(victim.rid)
            return False
        self.swap_stats["swap_outs"] += 1
        self.swap_stats["kv_out"] += victim.suspended_m
        self.swap_stats["wall_out_s"] += time.perf_counter() - t0
        self._release(victim.rid)
        # double buffering: finalize the oldest transfer(s) OUTSIDE the
        # timed enqueue window above (the drain bills its own wait into
        # wall_out_s — overlapping windows would double-count it)
        while len(self._pending_swaps) > 2:
            self._drain_swaps(rid=next(iter(self._pending_swaps)))
        return True

    def _drain_swaps(self, rid: Optional[int] = None,
                     before_step: Optional[int] = None) -> None:
        """Finalize in-flight swap-out transfers: block on the async D2H
        copy and replace the store entry's device leaves with host
        arrays.  ``rid`` drains one entry (same-window re-admission,
        double-buffer pressure); ``before_step`` drains entries enqueued
        before that step (the end-of-step boundary); neither drains
        everything (end of run)."""
        if rid is not None:
            rids = [rid] if rid in self._pending_swaps else []
        elif before_step is not None:
            rids = [r for r, (_, s) in self._pending_swaps.items()
                    if s < before_step]
        else:
            rids = list(self._pending_swaps)
        for r in rids:
            entry, _ = self._pending_swaps.pop(r)
            t0 = time.perf_counter()
            entry.cache = jax.device_get(entry.cache)
            if self.ecfg.check_invariants:
                assert int(np.asarray(entry.cache["index"])[0]) \
                    == entry.num_kv, (r, entry.cache["index"], entry.num_kv)
            self.swap_stats["wall_out_s"] += time.perf_counter() - t0

    def _swap_in(self, r: Request) -> None:
        """Restore r's snapshot into a free slot; no refill is needed."""
        if r.rid in self._pending_swaps:
            # re-admitted within the drain window: finalize on demand
            self.swap_stats["drains_on_swapin"] += 1
            self._drain_swaps(rid=r.rid)
        t0 = time.perf_counter()
        entry = self.swap_store.pop(r.rid)
        slot = self._claim_slot(r.rid, reset=False)  # fully overwritten
        upd = jax.tree.map(jnp.asarray, entry.cache)
        self.cache = self._slot_write(self.cache, upd, jnp.int32(slot))
        jax.block_until_ready(self.cache["index"])
        self.allocator.allocate(r.rid, entry.num_kv)
        restored = r.resume()
        if self.ecfg.check_invariants:
            assert restored == entry.num_kv, (r.rid, restored, entry.num_kv)
            assert self.token_ids[r.rid] == entry.tokens, r.rid
        self.swap_stats["swap_ins"] += 1
        self.swap_stats["kv_in"] += entry.num_kv
        self.swap_stats["wall_in_s"] += time.perf_counter() - t0

    def _swap_time(self, n_kvs: int) -> float:
        return self.cost_model.swap_time(n_kvs) if self.cost_model else 0.0

    def _sample(self, logits: jnp.ndarray) -> int:
        """Greedy over the REAL vocabulary (padding logits excluded)."""
        return int(jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1))

    # ------------------------------------------------------------------ #
    def _run_prefills_legacy(self, prefill_items) -> Dict[int, int]:
        """PR-1 plane: per-request chunk loop with exact (unpadded)
        shapes — every distinct tail length compiles a new signature."""
        final_tok: Dict[int, int] = {}
        for r, c in prefill_items:
            slot = self.slot_of[r.rid]
            ids = self.token_ids[r.rid]
            start, remaining = r.m, c
            logits = None
            while remaining > 0:
                step_c = min(self.ecfg.chunk, remaining)
                toks = jnp.asarray([ids[start:start + step_c]], jnp.int32)
                logits, self.cache = self._prefill_one(
                    self.params, self.cache, jnp.int32(slot), toks)
                start += step_c
                remaining -= step_c
            if r.m + c == r.target_context:   # this batch emits a token
                final_tok[r.rid] = self._sample(logits)
        return final_tok

    def _run_prefills_batched(self, prefill_items) -> Dict[int, int]:
        """Shape-stable plane: rounds of one ``prefill_many`` over the
        full slot grid, sub-chunks padded to the bucket ladder.  Only
        (nslots,) sampled token ids are fetched, and only on rounds
        where some request finishes its batch allotment."""
        nslots = self.ecfg.nslots
        # [request, slot, next-token cursor, tokens left this batch]
        plans = [[r, self.slot_of[r.rid], r.m, c] for r, c in prefill_items]
        emits = {r.rid: r.m + c == r.target_context for r, c in prefill_items}
        final_tok: Dict[int, int] = {}
        while True:
            steps = {p[1]: min(self.ecfg.chunk, p[3])
                     for p in plans if p[3] > 0}
            if not steps:
                break
            bucket = self._bucket_for(max(steps.values()))
            toks = np.zeros((nslots, bucket), np.int32)
            lens = np.zeros((nslots,), np.int32)
            finishing: List[Tuple[Request, int]] = []
            for p in plans:
                r, slot, cursor, rem = p
                if rem <= 0:
                    continue
                sc = steps[slot]
                toks[slot, :sc] = self.token_ids[r.rid][cursor:cursor + sc]
                lens[slot] = sc
                p[2] += sc
                p[3] -= sc
                if p[3] == 0:
                    finishing.append((r, slot))
            tok_ids, self.cache = self._prefill_many(
                self.params, self.cache, jnp.asarray(toks), jnp.asarray(lens))
            if any(emits[r.rid] for r, _ in finishing):
                host = np.asarray(tok_ids)          # (nslots,) int32 only
                for r, slot in finishing:
                    if emits[r.rid]:
                        final_tok[r.rid] = int(host[slot])
        return final_tok

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """Run one scheduler batch. Returns the number of items executed."""
        if not self.sched.has_work():
            return 0
        t0 = time.perf_counter()
        self._step_no += 1
        batch = self.sched.get_next_batch()
        swap_s = 0.0
        num_swap_out = num_swap_in = 0
        for victim in batch.preempted:
            if victim.suspended:
                m = victim.suspended_m
                if self._swap_out(victim):   # False: store full, fell back
                    swap_s += self._swap_time(m)
                    num_swap_out += 1
            else:
                self._release(victim.rid)
        if not batch.items:
            # swap-outs still happened: owe their virtual-time charge to
            # the next executed batch (mirrors the simulator's carry)
            self._carry_swap_s += swap_s
            self._carry_out += num_swap_out
            self._drain_swaps(before_step=self._step_no)
            self.wall += time.perf_counter() - t0
            return 0
        swap_s += self._carry_swap_s
        num_swap_out += self._carry_out
        self._carry_swap_s, self._carry_out = 0.0, 0

        # swap-ins: restore suspended re-admissions before classification
        # so they re-enter as decodes/short prefills, not full refills
        for r, _ in batch.items:
            if r.suspended:
                swap_s += self._swap_time(r.suspended_m)
                num_swap_in += 1
                self._swap_in(r)

        # classify + virtual-time the batch up front
        spec = BatchSpec()
        prefill_items: List[Tuple[Request, int]] = []
        decode_items: List[Tuple[Request, int]] = []
        for r, c in batch.items:
            if r.generated > 0 and c == 1 and r.remaining_prefill == 1:
                decode_items.append((r, c))
                spec.decodes.append((c, r.m))
            else:
                prefill_items.append((r, c))
                spec.prefills.append((c, r.m))
        dt = (self.cost_model.batch_time(spec) if self.cost_model else 0.0) \
            + swap_s
        self.now += dt

        # ---- prefills (one batched bucketed call per round) ------------- #
        if prefill_items:
            for r, c in prefill_items:
                if r.rid not in self.slot_of:
                    self._claim_slot(r.rid)
                self.allocator.allocate(r.rid, c)
            runner = (self._run_prefills_batched
                      if self.ecfg.plane == "batched"
                      else self._run_prefills_legacy)
            final_tok = runner(prefill_items)
            for r, c in prefill_items:
                generated = r.advance(c, self.now)
                if generated:
                    tok = final_tok[r.rid]
                    self.outputs[r.rid].append(tok)
                    if r.finished:
                        self.sched.complete(r)
                        self._release(r.rid)
                    else:
                        self.token_ids[r.rid].append(tok)

        # ---- decodes (one batched fused step over all slots) ------------ #
        if decode_items:
            nslots = self.ecfg.nslots
            toks = np.zeros((nslots,), np.int32)
            mask = np.zeros((nslots,), bool)
            for r, _ in decode_items:
                slot = self.slot_of[r.rid]
                toks[slot] = self.token_ids[r.rid][-1]
                mask[slot] = True
                self.allocator.allocate(r.rid, 1)
            tok_ids, self.cache = self._decode_many(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(mask))
            host = np.asarray(tok_ids)              # (nslots,) int32 only
            for r, c in decode_items:
                slot = self.slot_of[r.rid]
                r.advance(c, self.now)
                tok = int(host[slot])
                self.outputs[r.rid].append(tok)
                if r.finished:
                    self.sched.complete(r)
                    self._release(r.rid)
                else:
                    self.token_ids[r.rid].append(tok)

        # end-of-step boundary: snapshots enqueued in EARLIER steps have
        # had a full step of compute to overlap their D2H copy; finalize
        # them now (this step's own snapshots stay in flight)
        self._drain_swaps(before_step=self._step_no)
        wall_s = time.perf_counter() - t0
        self.wall += wall_s
        if self.ecfg.check_invariants:
            self.allocator.check_invariants()
            self.swap_store.check_invariants()
            self._check_index_sync(batch)
        kv_used = sum(r.m for r in self.sched.running)
        self.batch_logs.append(BatchLog(
            t_start=self.now - dt, t_end=self.now,
            num_prefill=len(spec.prefills), num_decode=len(spec.decodes),
            tokens=spec.total_tokens, kv_used=kv_used,
            preempted=len(batch.preempted),
            swapped_out=num_swap_out, swapped_in=num_swap_in,
            swap_s=swap_s, wall_s=wall_s))
        return len(batch.items)

    def _check_index_sync(self, batch) -> None:
        idx = np.asarray(self.cache["index"])
        for r, _ in batch.items:
            if r.finished or r.rid not in self.slot_of:
                continue
            slot = self.slot_of[r.rid]
            assert idx[slot] == r.m, (r.rid, idx[slot], r.m)

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Request],
            max_batches: int = 100_000) -> "EngineResult":
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        for _ in range(max_batches):
            while i < len(pending) and pending[i].arrival <= self.now + 1e-12:
                self.submit(pending[i])
                i += 1
            if not self.sched.has_work():
                if i >= len(pending):
                    break
                self.now = pending[i].arrival
                continue
            executed = self.step()
            if executed == 0:
                if i < len(pending):     # blocked until the next arrival
                    self.now = max(self.now, pending[i].arrival)
                    continue
                raise RuntimeError(
                    "engine deadlock: work remains but nothing schedulable")
        else:
            raise RuntimeError("engine did not converge")
        self._drain_swaps()
        if self.ecfg.check_invariants:
            assert not self._pending_swaps
            assert len(self.swap_store) == 0, \
                f"swap store leaked rids {self.swap_store.suspended_rids}"
        sim = SimResult(requests=list(requests), batches=self.batch_logs,
                        num_preemptions=self.sched.num_preemptions,
                        num_swaps=self.sched.num_swaps)
        return EngineResult(outputs=dict(self.outputs), metrics=sim,
                            wall_time=self.wall,
                            swap_stats=dict(self.swap_stats),
                            num_compiles=self.num_compiles)


@dataclass
class EngineResult:
    outputs: Dict[int, List[int]]
    metrics: SimResult
    wall_time: float
    swap_stats: Dict[str, float] = field(default_factory=dict)
    num_compiles: int = 0


# --------------------------------------------------------------------- #
# reference generation (no scheduler) — the parity oracle
# --------------------------------------------------------------------- #


@functools.lru_cache(maxsize=64)
def _reference_decode_fn(cfg: ModelConfig, impl: str, moe_impl: str):
    """Jitted (params, cur (1,), cache) -> (next token (1,), cache) with
    fused greedy sampling; cached per (cfg, impl, moe_impl) so repeated
    parity-oracle calls stop paying an uncompiled decode per token."""

    def step(params, cur, cache):
        logits, cache = M.decode_step(cfg, params, cur, cache,
                                      impl=impl, moe_impl=moe_impl)
        nxt = jnp.argmax(logits[:, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
        return nxt, cache

    return jax.jit(step)


def generate_reference(cfg: ModelConfig, params: Any, prompt: Sequence[int],
                       num_tokens: int, *, cache_len: int,
                       impl: str = "reference",
                       moe_impl: str = "dense") -> List[int]:
    """Greedy generation of one request, full prefill + sequential decode.
    The decode loop is jitted (one compile per (cfg, cache shape), reused
    across calls) and samples on device — only token ids reach the host."""
    toks = jnp.asarray([list(prompt)], jnp.int32)
    logits, cache = M.prefill(cfg, params, {"tokens": toks},
                              cache_len=cache_len, impl=impl,
                              moe_impl=moe_impl)
    out: List[int] = []
    cur = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    out.append(int(cur[0]))
    decode = _reference_decode_fn(cfg, impl, moe_impl)
    for _ in range(num_tokens - 1):
        cur, cache = decode(params, cur, cache)
        out.append(int(cur[0]))
    return out
