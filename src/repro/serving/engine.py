"""Continuous-batching serving engine — REAL JAX execution of the paper's
schedules (the "deployment" path of Fig. 1; the simulator is the blue
path).

The engine drives the unified ``Scheduler`` (Algorithm 1) against an
actual model.  Memory accounting (the scheduler's M) is backed by a
``PagedAllocator`` at page granularity: the scheduler charges
page-rounded occupancy against the allocator's page-rounded capacity
(``ceil(M/page_size)`` pages), so a schedule the control plane admits is
allocator-feasible by construction — ``OutOfPagesError`` is unreachable,
internal fragmentation is charged up front, never discovered mid-batch.

Execution plane — THREE data planes, selected by ``EngineConfig.plane``:

* ``"batched"`` (default) — per-request contiguous cache slots; all
  prefill work of a scheduler batch runs as rounds of ONE
  ``prefill_many`` call over the full (nslots, bucket) token grid.
  Chunks are padded to a fixed bucket ladder (powers of two up to
  ``chunk``) and an explicit per-row ``length`` mask is threaded through
  ``models.model.prefill_chunk`` down to the attention / SSM / RWKV
  internals, so one compiled XLA signature per bucket serves every
  chunk size, request count, and prompt length: the number of distinct
  compiles is a small constant (see ``Engine.num_compiles`` and the
  compile-count regression test).  Inactive rows carry length 0 and are
  provably inert.
* ``"paged"`` — the allocator's block tables become the PHYSICAL memory
  layout (PR 4): attention KV lives in shared per-layer page pools
  ``(num_pages, page_size, Hkv, D)`` (``serving.paged_plane``), prefill
  writes K/V through the block table into owned pages, and decode runs
  the ``kernels.paged_attention`` flash-decoding Pallas kernel over
  scalar-prefetched block tables (jnp gather fallback on CPU).  Pooled
  pages unlock what contiguous slots cannot express:

  - *page-level partial preemption* — on memory pressure the scheduler
    sheds only a victim's TAIL pages (``SchedulerConfig.
    partial_preempt``; the §8 SRF idea at sub-request granularity),
    with the Fig. 8 crossover deciding swap-vs-recompute PER PAGE RUN;
    swapped runs live in the ``KVSwapStore`` as ``PageRunEntry``s and
    are restored before the victim's next compute step.
  - *shared-prefix reuse* — full prompt pages are published to a
    refcounted prefix registry keyed by chained content hashes; a new
    request whose prompt matches maps the SAME physical pages
    (copy-on-write guarded via ``PagedAllocator.ensure_private``) and
    skips their prefill compute.  When the pool runs short,
    registry-cached pages are reclaimed in the eviction order of a
    PLUGGABLE replacement policy (``SchedulerConfig.cache_policy`` /
    ``EngineConfig.cache_policy``: ``lru``, or ``break_even`` — the §6
    five-minute rule scored per entry), so they never shrink
    schedulable capacity; entries whose page a live table still maps
    are skipped (evicting them frees nothing).
  - *host demotion tier* (``cache_demotion``) — evicted prefix pages
    are demoted into the ``KVSwapStore`` as refcount-free
    ``PrefixPageEntry`` snapshots instead of discarded; a registry hit
    on a host-resident prefix PROMOTES the page back through the swap
    path, charged ``swap_time`` in virtual time (mirrored by the
    simulator's ``PrefixTierSim`` shadow) and measured on the wall —
    every KV access resolves along the Fig. 8 spectrum: GPU-resident <
    host swap-in < recompute.

  Sliding-window and SSM/RWKV state is O(1) per request and stays
  slot-resident: for those families ``plane="paged"`` keeps the batched
  data plane and retains the page-rounded control plane.
* ``"legacy"`` — the PR-1 per-request chunk loop with exact (unpadded)
  shapes: every distinct tail length triggers a fresh XLA compile.
  Kept as the honest baseline for ``benchmarks/fig_engine_wall.py``.

Sampling is FUSED into the jitted steps: greedy argmax over the real
vocabulary happens on device and only (nslots,) int32 token ids ever
cross to the host — the full (nslots, vocab) logits array is never
materialized off-device.  ``EngineConfig.decode_append="deferred"``
routes decode through ``model.decode_step_deferred`` (one cache scatter
per step instead of one per layer).

Preemption supports BOTH §5.4 restoration paths, selected by
``SchedulerConfig.preempt_mode``:

* ``recompute`` — the victim's slot is freed and its KVs discarded; on
  re-admission it pays a full refill prefill (the §3 refill).
* ``swap`` — the victim's slot slice (every cache leaf, including the
  position index and recurrent SSM state) is snapshotted to a host-side
  ``KVSwapStore``; on re-admission the snapshot is written back into a
  free slot and generation continues where it stopped —
  ``Request.remaining_prefill`` sees the restored KVs, so no refill runs.
  If the store's ``EngineConfig.swap_bytes`` capacity is exhausted the
  victim falls back to discard-and-recompute for that preemption.
* ``auto`` — per-victim Fig. 8 decision via the cost model
  (``swap_time`` vs ``kv_projection_time``/``recompute_time``).

Swap-out transfers are ASYNC by default (``EngineConfig.async_swap``):
the victim's snapshot is computed on device (a fresh buffer — later
cache/pool updates cannot alias it), ``copy_to_host_async`` starts the
D2H transfer off the critical path, and the snapshot is finalized
(double-buffered, at most two in flight) at the next step boundary or
on demand when the victim is re-admitted within the same drain window.
This covers ALL host-bound KV traffic: the slot planes' whole-slot
slices, the pooled plane's page-run suspend/shed gathers (whose fresh
buffers are what let the freed pages be reused in the very same step),
and the prefix tier's page demotions.  Store capacity is charged at
enqueue time from array metadata — a full store still falls back to
recompute synchronously — and virtual-time charges are identical to
the sync path.

Virtual time charges ``cost_model.swap_time`` for each swap-out and
swap-in, mirroring the simulator, so simulated and engine schedules
agree.  Measured wall times of the host transfers are tracked in
``Engine.swap_stats`` (the fig08 validation column); per-batch measured
wall time lands in ``BatchLog.wall_s``.

Correctness contract (tested): scheduling, chunking, batching, padding
and preemption — under recompute, swap, AND auto — NEVER change the
generated tokens, exactly the paper's "standard inference optimization
techniques that do not affect inference outputs".  At the models layer
the padded cache state is bit-identical to the unpadded call for the
pure-attention family; for the recurrent families (SSM/RWKV) padding
changes the inner scans' chunk factorization, so states agree to float
reduction-order noise (~1e-7 relative) — the same order as the
chunked-vs-full divergence the parity oracle already tolerates, below
anything that flips a greedy argmax in practice.

Failure model (DBMS-style step transactions) — every scheduler batch
runs as an atomic STEP TRANSACTION (``serving.txn``): allocator,
swap store, scheduler, request state machines, and the engine-local
slot/output maps are snapshotted at batch start and rolled back as one
unit on a mid-step failure.  Failures are injected deterministically by
a seeded ``serving.faults.FaultPlan`` (``EngineConfig.faults``, written
through to the SchedulerConfig so the simulator draws the identical
schedule) and handled along a three-rung degradation ladder:

1. **retry in place** — transient swap-store write failures are retried
   with bounded exponential backoff (``distributed.fault_tolerance.
   run_with_retries`` with an injectable virtual-sleep clock; the
   schedule lands in ``swap_stats["backoff_s"]``, never on the wall).
2. **rollback + retry the step** — a transient device fault at page
   allocation (``FaultError``) aborts the attempt; the step transaction
   restores batch-start state and the step re-runs (allocation faults
   are keyed by attempt, so the retry draws fresh).  A real
   ``OutOfPagesError`` rolls back too — invariants stay green — but
   re-raises: it signals an accounting bug, not a survivable fault.
3. **degrade to recompute** — host snapshots are CRC-sealed at drain
   time (``swap_store.seal_entry``) and verified at swap-in / promote;
   a corrupt entry (``IntegrityError``) triggers rollback, the entry is
   dropped, its request degrades to a §3-style recompute, and the step
   retries.  Wrong tokens are never served: chaos tests assert outputs
   under any fault schedule are byte-identical to the fault-free run.
   Permanent store failures (``PermanentStoreError``, a
   ``SwapStoreFullError`` subclass) ride the existing full-store
   fallback: drop the snapshot, recompute.

Abort history is recorded in ``Engine.recovery_stats`` (rollbacks,
alloc faults, integrity failures, degraded recomputes, straggler
requeues, aborted wall time) — deliberately OUTSIDE the transaction, so
rolling back never erases the record of the rollback itself.  In-step
fault counters (retries, backoff, permanent failures, prefix integrity)
live in ``swap_stats`` INSIDE the transaction, so an aborted attempt's
draws are not double-counted by its retry.  ``StragglerMonitor``
(``EngineConfig.straggler_factor``) optionally requeues all running
requests when a step's wall time blows past the cost-model prediction.

State-safety analysis — the three protocols above are AUDITED
STATICALLY by ``repro.analysis`` (``make analyze``, the check.sh static
stage): ``txn-coverage`` diffs every ``self.*`` attribute mutated on a
path reachable from ``step()`` against what ``_begin_txn`` snapshots
(plus the participant/``Request`` write-sets against ``serving.txn``'s
capture lists), so adding engine state without adding it to the
transaction is a blocking finding, not a latent rollback hole; the few
attributes that deliberately survive rollback (measured wall, recovery
accounting, attempt/step identity, straggler inputs) each carry an
inline ``allow-txn-coverage`` stating why.  ``stat-mirror`` diffs the
``swap_stats``/``recovery_stats``/``BatchLog`` key sets written here
against the simulator's ``PrefixTierSim``/``_FaultMirror`` shadows
(keys are ``core.stat_keys`` constants; sanctioned asymmetries live in
that module's allowlist sets), and ``async-drain`` enforces the swap
protocol: every ``copy_to_host_async`` registers in a ``_pending_*``
buffer, payload reads sit behind a ``_drain_*`` boundary,
``EngineResult`` is built on fully-drained state, and drains are never
jit-reachable.
"""
from __future__ import annotations

import functools
import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import BatchSpec, CostModel
from repro.core.invariants import invariant
from repro.core.kvcache import (OutOfPagesError, PagedAllocator,
                                attach_prefix_run, chain_keys)
from repro.core.policies import make_replacement_policy
from repro.core.request import Request
from repro.core.scheduler import Scheduler
from repro.core.simulator import BatchLog, SimResult
from repro.core import stat_keys as SK
from repro.distributed.fault_tolerance import (StragglerMonitor,
                                               run_with_retries)
from repro.models import model as M
from repro.serving.faults import (FaultError, FaultPlan, IntegrityError,
                                  PermanentStoreError, TransientStoreError)
from repro.serving.paged_plane import build_paged_fns, paged_supported
from repro.serving.serve_step import build_prefill_chunk_fn
from repro.serving.swap_store import (KVSwapStore, SwapEntry,
                                      SwapStoreFullError, flip_bit,
                                      seal_entry, verify_entry)
from repro.serving.txn import StepTxn, begin_step_txn

# hard ceiling on fault-recovery retries of one step: content-keyed
# draws are idempotent, so only attempt-keyed allocation faults can
# chain — at any sane p_alloc the chance of 50 in a row is nil, and a
# loop this long means the fault plan (or a repair) is broken
_MAX_STEP_ATTEMPTS = 50


@dataclass
class EngineConfig:
    nslots: int = 8
    cache_len: int = 256          # per-slot context capacity (tokens)
    chunk: int = 64               # chunked-prefill chunk size
    page_size: int = 1            # allocator granularity (1 = token-exact,
    #                               matching the scheduler's M accounting)
    impl: str = "reference"       # attention backend
    moe_impl: str = "dense"       # chunk-invariant dispatch for parity
    swap_bytes: Optional[int] = None   # host swap-store capacity (None =
    #                                    unbounded); a full store makes the
    #                                    victim fall back to recompute
    check_invariants: bool = True
    # --- execution plane (PR 2 / PR 4) --------------------------------- #
    plane: str = "batched"        # "batched" (shape-stable bucketed
    #                               prefill_many over contiguous slots)
    #                             | "paged" (pooled per-layer KV pages +
    #                               block tables; slot-resident fallback
    #                               for bounded-state families)
    #                             | "legacy" (PR-1 per-request
    #                               exact-shape chunk loop)
    prefix_sharing: bool = True   # paged plane: map identical full
    #                               prompt pages to the same physical
    #                               pages via the refcounted registry
    prefix_lookup: Optional[str] = None  # "trie" (radix-trie longest-
    #                               prefix match, partial hits) |
    #                               "exact" (all-or-nothing ablation:
    #                               attach only when EVERY queried page
    #                               resolves on device).  None keeps the
    #                               SchedulerConfig's choice; set, it is
    #                               written through (like page_size) so
    #                               the simulator shadow matches
    # --- page-pool cache replacement (§6 five-minute rule) ------------- #
    cache_policy: Optional[str] = None   # "lru" | "break_even" — None
    #                               keeps the SchedulerConfig's choice;
    #                               set, it is written through to the
    #                               scheduler (like page_size) so both
    #                               planes agree on one policy
    cache_demotion: Optional[bool] = None  # evicted prefix pages demote
    #                               to the host KVSwapStore instead of
    #                               being discarded; registry hits on
    #                               host-resident prefixes promote back
    #                               through the swap path (charged
    #                               swap_time).  None = scheduler's.
    decode_append: str = "inline"   # "inline" | "deferred" (one cache
    #                                 scatter per step, §Perf cell A)
    async_swap: bool = True       # double-buffered async swap-out D2H —
    #                               covers the slot planes' whole-slot
    #                               snapshots, the prefix tier's page
    #                               demotions, AND the pooled plane's
    #                               page-run suspend/shed snapshots
    share_jits: bool = False      # reuse process-global jitted plane
    #                               steps (keyed by model config) across
    #                               Engine instances, so a fresh engine
    #                               with a known config pays ZERO XLA
    #                               compiles.  Off by default: sharing
    #                               makes ``num_compiles`` a process-
    #                               cumulative count, which the per-
    #                               engine compile budgets / constancy
    #                               tests must not see.  Benchmarks turn
    #                               it on (with ``warmup()``) so timed
    #                               windows price compute, not
    #                               backend_compile
    min_bucket: int = 8           # smallest tail bucket of the ladder
    # --- failure model (step transactions + fault injection) ----------- #
    faults: Optional[Any] = None  # a serving.faults.FaultSpec; written
    #                               through to SchedulerConfig.faults
    #                               (like page_size) so engine and
    #                               simulator draw one fault schedule.
    #                               Typed Any: the core scheduler config
    #                               mirrors the field and must not
    #                               import the serving layer
    straggler_factor: Optional[float] = None  # arm StragglerMonitor: a
    #                               step whose measured wall time
    #                               exceeds factor x the cost model's
    #                               predicted dt requeues every running
    #                               request through the scheduler's
    #                               preemption path.  Wall-clock
    #                               dependent — leave None (off) in
    #                               parity/chaos tests


def _bucket_ladder(chunk: int, min_bucket: int) -> List[int]:
    """Fixed padding targets: powers of two in [min_bucket, chunk), plus
    ``chunk`` itself.  Every prefill sub-chunk is padded UP to the
    smallest bucket that holds it, so at most ``len(ladder)`` distinct
    prefill signatures ever compile."""
    b = 1
    while b < min(min_bucket, chunk):
        b *= 2
    ladder = []
    while b < chunk:
        ladder.append(b)
        b *= 2
    ladder.append(chunk)
    return ladder


def _slot_axis(leaf: jnp.ndarray) -> int:
    """Cache leaves are (L, B, ...) except index (B,)."""
    return 0 if leaf.ndim == 1 else 1


# --------------------------------------------------------------------- #
# plane step builders — module level so ``EngineConfig.share_jits`` can
# cache the JITTED closures per model config: every Engine with the same
# (cfg, impl, moe_impl) then shares one XLA compile cache, and a warmed
# signature is never paid for twice in a process (benchmarks construct
# several engines per figure; without sharing each re-compiles the same
# cells inside its first — often timed — steps)
# --------------------------------------------------------------------- #

def _mask_merge(active, new_cache, old_cache):
    def merge(new, old):
        ax = _slot_axis(new)
        m = active.reshape((1,) * ax + (-1,) + (1,) * (new.ndim - ax - 1))
        return jnp.where(m, new, old)
    return jax.tree.map(merge, new_cache, old_cache)


def _slot_slice_fn(cache, slot):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1,
                                               _slot_axis(a)), cache)


def _slot_write_fn(cache, upd, slot):
    return jax.tree.map(
        lambda a, u: jax.lax.dynamic_update_slice_in_dim(
            a, u, slot, _slot_axis(a)), cache, upd)


def _reset_slot_fn(cache, slot):
    zeroed = jax.tree.map(
        lambda a: jnp.zeros_like(
            jax.lax.dynamic_slice_in_dim(a, slot, 1, _slot_axis(a))),
        cache)
    return _slot_write_fn(cache, zeroed, slot)


def _make_slot_fns():
    """Fresh per-engine aliases of the slot helpers.  jax keys its
    compiled-executable cache on the wrapped callable, so jitting the
    module-level functions directly would leak compile counts (and
    ``num_compiles``) between engines even with ``share_jits=False``."""
    def slot_slice(cache, slot):
        return _slot_slice_fn(cache, slot)

    def slot_write(cache, upd, slot):
        return _slot_write_fn(cache, upd, slot)

    def reset_slot(cache, slot):
        return _reset_slot_fn(cache, slot)
    return slot_slice, slot_write, reset_slot


def _make_legacy_prefill(cfg: ModelConfig, impl: str, moe_impl: str):
    def prefill_one(params, cache, slot, tokens):
        sl = _slot_slice_fn(cache, slot)
        logits, new_sl = M.prefill_chunk(cfg, params, tokens, sl,
                                         impl=impl, moe_impl=moe_impl)
        return logits[0], _slot_write_fn(cache, new_sl, slot)
    return prefill_one


def _make_batched_prefill(cfg: ModelConfig, impl: str, moe_impl: str):
    chunk_fn = build_prefill_chunk_fn(cfg, impl=impl, moe_impl=moe_impl)
    vocab = cfg.vocab_size

    def prefill_many(params, cache, tokens, lengths):
        """One batched bucketed chunk round over ALL slots.
        tokens (nslots, bucket); lengths (nslots,), 0 = inert row.
        Returns (greedy token ids (nslots,), merged cache) — fused
        on-device sampling, full logits never leave the device."""
        logits, new_cache = chunk_fn(params, tokens, cache, lengths)
        toks = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
        return toks, _mask_merge(lengths > 0, new_cache, cache)
    return prefill_many


def _make_decode(cfg: ModelConfig, impl: str, moe_impl: str,
                 decode_append: str):
    decode_step = (M.decode_step_deferred if decode_append == "deferred"
                   else M.decode_step)
    vocab = cfg.vocab_size

    def decode_many(params, cache, tokens, mask):
        logits, new_cache = decode_step(cfg, params, tokens, cache,
                                        impl=impl, moe_impl=moe_impl)
        toks = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
        return toks, _mask_merge(mask, new_cache, cache)
    return decode_many


def _make_paged_step_fns(cfg: ModelConfig, impl: str, moe_impl: str):
    pf, df = build_paged_fns(cfg, impl=impl, moe_impl=moe_impl)

    def prefill_packed(params, k_pools, v_pools, grid, block_tables):
        # one coalesced host->device transfer per round: the tokens,
        # lengths and starts of every slot ride a single (nslots,
        # bucket+2) int32 grid — [toks | lens | starts] — unpacked here
        # (on-device slices are free next to three separate uploads)
        toks = grid[:, :-2]
        lens = grid[:, -2]
        starts = grid[:, -1]
        return pf(params, k_pools, v_pools, toks, starts, lens,
                  block_tables)
    return prefill_packed, df


@functools.lru_cache(maxsize=1)
def _shared_slot_jits():
    return (jax.jit(_slot_slice_fn), jax.jit(_slot_write_fn),
            jax.jit(_reset_slot_fn))


@functools.lru_cache(maxsize=64)
def _shared_legacy_jit(cfg: ModelConfig, impl: str, moe_impl: str):
    return jax.jit(_make_legacy_prefill(cfg, impl, moe_impl))


@functools.lru_cache(maxsize=64)
def _shared_batched_jit(cfg: ModelConfig, impl: str, moe_impl: str):
    return jax.jit(_make_batched_prefill(cfg, impl, moe_impl))


@functools.lru_cache(maxsize=64)
def _shared_decode_jit(cfg: ModelConfig, impl: str, moe_impl: str,
                       decode_append: str):
    return jax.jit(_make_decode(cfg, impl, moe_impl, decode_append))


@functools.lru_cache(maxsize=64)
def _shared_paged_jits(cfg: ModelConfig, impl: str, moe_impl: str):
    pf, df = _make_paged_step_fns(cfg, impl, moe_impl)
    return jax.jit(pf), jax.jit(df)


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, scheduler: Scheduler,
                 ecfg: Optional[EngineConfig] = None,
                 cost_model: Optional[CostModel] = None):
        # copy the config: a shared default (or caller-reused) instance
        # must not be mutated by the per-model chunk clamp below
        ecfg = replace(ecfg) if ecfg is not None else EngineConfig()
        if cfg.window:
            ecfg.chunk = min(ecfg.chunk, cfg.window)
        if ecfg.plane not in ("batched", "legacy", "paged"):
            raise ValueError(f"unknown plane {ecfg.plane!r}")
        if ecfg.decode_append not in ("inline", "deferred"):
            raise ValueError(f"unknown decode_append {ecfg.decode_append!r}")
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.sched = scheduler
        self.cost_model = cost_model
        if scheduler.cost_model is None:
            scheduler.cost_model = cost_model   # auto preempt-mode pricing
        scheduler.cfg.max_running = ecfg.nslots
        # page-rounded capacity: ceil, NOT floor — flooring silently lost
        # up to page_size-1 tokens of capacity while the scheduler kept
        # admitting by raw token count, making OutOfPagesError reachable
        # on schedules the control plane proved feasible.  The scheduler
        # is told the granularity so both sides round identically.
        scheduler.cfg.page_size = ecfg.page_size
        # cache-replacement knobs: an EngineConfig override is written
        # through to the SchedulerConfig (like page_size above) so the
        # control plane — including any simulator shadow built from the
        # same config — and this data plane agree on one policy and on
        # which tier every prefix lands in
        if ecfg.cache_policy is not None:
            scheduler.cfg.cache_policy = ecfg.cache_policy
        if ecfg.cache_demotion is not None:
            scheduler.cfg.cache_demotion = ecfg.cache_demotion
        if ecfg.prefix_lookup is not None:
            scheduler.cfg.prefix_lookup = ecfg.prefix_lookup
        if ecfg.faults is not None:
            scheduler.cfg.faults = ecfg.faults
        if scheduler.cfg.prefix_lookup not in ("trie", "exact"):
            raise ValueError(
                f"unknown prefix_lookup {scheduler.cfg.prefix_lookup!r}")
        # pooled paged data plane: only unbounded dense-attention
        # families are pooled; bounded-state families keep slots
        self._pooled = ecfg.plane == "paged" and paged_supported(cfg)
        if scheduler.cfg.partial_preempt and not self._pooled:
            raise ValueError(
                "partial_preempt needs the pooled paged data plane")
        self._demotion = bool(scheduler.cfg.cache_demotion) \
            and self._pooled and ecfg.prefix_sharing
        self.allocator = PagedAllocator(
            num_pages=max(1, -(-scheduler.cfg.M // ecfg.page_size)),
            page_size=ecfg.page_size,
            policy=make_replacement_policy(scheduler.cfg.cache_policy,
                                           cost_model=cost_model,
                                           M=scheduler.cfg.M),
            on_evict=self._demote_prefix if self._demotion else None)
        if self._pooled:
            pg = ecfg.page_size
            self.max_pages = -(-ecfg.cache_len // pg)
            pool_shape = (cfg.num_layers, self.allocator.num_pages, pg,
                          cfg.num_kv_heads, cfg.head_dim_)
            self.k_pools = jnp.zeros(pool_shape, jnp.dtype(cfg.dtype))
            self.v_pools = jnp.zeros_like(self.k_pools)
            self.cache = None
        else:
            # init_cache caps the per-slot KV length at cfg.window
            self.cache = M.init_cache(cfg, ecfg.nslots, ecfg.cache_len)
        # shared-prefix bookkeeping (pooled plane): chained page keys per
        # rid and the per-grant data-plane skip from a registry hit
        self._page_keys_of: Dict[int, List[int]] = {}
        self._page_tokens_of: Dict[int, List[Tuple[int, ...]]] = {}
        self._prefix_skip: Dict[int, int] = {}
        # (allocator version, device array) — see _block_tables_device
        self._bt_cache: Optional[Tuple[int, jnp.ndarray]] = None
        # persistent host mirror of the device block tables: refreshed
        # row-by-row from the allocator's dirty-rid delta (never rebuilt
        # whole), then uploaded in ONE host->device transfer
        self._bt_host: Optional[np.ndarray] = None
        # device-resident decode inputs keyed by cohort — steady-state
        # decode uploads NOTHING (see _run_decodes_paged)
        self._decode_state: Optional[Dict[str, Any]] = None
        self.free_slots: List[int] = list(range(ecfg.nslots - 1, -1, -1))
        self.slot_of: Dict[int, int] = {}
        self.token_ids: Dict[int, List[int]] = {}
        self.outputs: Dict[int, List[int]] = {}
        self.buckets = _bucket_ladder(ecfg.chunk, ecfg.min_bucket)
        self.swap_store = KVSwapStore(capacity_bytes=ecfg.swap_bytes)
        # --- failure model: fault plan + step-transaction machinery ----- #
        self.fault_plan: Optional[FaultPlan] = (
            FaultPlan(scheduler.cfg.faults)
            if scheduler.cfg.faults is not None else None)
        if self.fault_plan is not None:
            self.allocator.fault_hook = self._alloc_fault_hook
        self._attempt = 0           # retry index of the current step
        self._alloc_ordinal = 0     # allocation counter within an attempt
        self._last_dt = 0.0         # predicted dt of the last batch
        self._last_wall = 0.0       # measured wall of the last batch
        # abort-history counters — deliberately OUTSIDE the step txn:
        # they record aborted attempts, and rolling the step back must
        # not erase the record of the rollback itself
        self.recovery_stats: Dict[str, float] = {
            SK.ROLLBACKS: 0, SK.ALLOC_FAULTS: 0, SK.INTEGRITY_FAILURES: 0,
            SK.DEGRADED_RECOMPUTES: 0, SK.STRAGGLER_REQUEUES: 0,
            SK.WALL_ABORTED_S: 0.0}
        self._straggler: Optional[StragglerMonitor] = (
            StragglerMonitor(deadline_factor=ecfg.straggler_factor)
            if ecfg.straggler_factor else None)
        # wall-clock phase attribution of the pooled step (zero-copy
        # prefix attach / prefill compute / host->device uploads) —
        # OUTSIDE the step txn like ``wall``: time spent by an aborted
        # attempt was still spent
        self.phase_stats: Dict[str, float] = {
            SK.ATTACH_S: 0.0, SK.PREFILL_S: 0.0, SK.UPLOAD_S: 0.0}
        # in-flight async swap-out snapshots (rid -> (store entry whose
        # cache leaves are still device arrays mid-D2H, enqueue step)).
        # An entry enqueued during step N overlaps its D2H copy with
        # step N+1's compute and is finalized at the END of step N+1 —
        # or earlier, on same-window re-admission / double-buffer
        # pressure (more than two transfers in flight).
        self._pending_swaps: "OrderedDict[int, Tuple[SwapEntry, int]]" = \
            OrderedDict()
        # in-flight async prefix-page demotions (chain key -> enqueue
        # step): the PrefixPageEntry's kv leaves stay device arrays
        # mid-D2H; finalized alongside _pending_swaps at the same drain
        # boundaries.  A promotion that lands before the drain simply
        # pops the entry — the bytes never round-trip.
        self._pending_demotes: "OrderedDict[int, int]" = OrderedDict()
        # in-flight async pooled page-run snapshots ((rid, run start) ->
        # (PageRunEntry whose kv leaves are device-side page gathers
        # mid-D2H, enqueue step)) — keyed by run start because tail
        # sheds can stack several runs per rid.  Drained at the same
        # boundaries as _pending_swaps / _pending_demotes.
        self._pending_runs: \
            "OrderedDict[Tuple[int, int], Tuple[Any, int]]" = OrderedDict()
        self._step_no = 0
        # measured host-transfer wall times (fig08 validation column);
        # promotions/demotions are the prefix cache's host-tier traffic
        self.swap_stats: Dict[str, float] = {
            SK.SWAP_OUTS: 0, SK.SWAP_INS: 0, SK.KV_OUT: 0, SK.KV_IN: 0,
            SK.SWAP_FALLBACKS: 0, SK.DRAINS_ON_SWAPIN: 0,
            SK.WALL_OUT_S: 0.0, SK.WALL_IN_S: 0.0,
            SK.PROMOTIONS: 0, SK.DEMOTIONS: 0, SK.DEMOTE_DROPS: 0,
            SK.KV_PROMOTED: 0, SK.KV_DEMOTED: 0,
            SK.WALL_PROMOTE_S: 0.0, SK.WALL_DEMOTE_S: 0.0,
            # fault-injection counters: inside the step txn (this dict
            # is snapshotted), so an aborted attempt's draws roll back
            # and its retry does not double-count them
            SK.PERMANENT_STORE_FAILURES: 0, SK.TRANSIENT_RETRIES: 0,
            SK.BACKOFF_S: 0.0, SK.PREFIX_INTEGRITY: 0,
            # radix-trie attach outcomes (PR 9): attaches that reused
            # at least one page, and the tokens reused by attaches that
            # matched only PART of the queried chain — the reuse the
            # exact-match registry could never see
            SK.TRIE_HITS: 0, SK.PARTIAL_HIT_TOKENS: 0}
        # virtual-time owed by prefix-tier traffic (demotions fire inside
        # allocator reclaims; promotions inside the prefix attach) —
        # folded into the CURRENT batch's swap_s before its dt is priced
        self._tier_swap_s = 0.0
        # swap-out virtual-time charges from rounds that admitted no
        # items, owed to the next executed batch (mirrors the simulator)
        self._carry_swap_s = 0.0
        self._carry_out = 0
        self.now = 0.0
        self.wall = 0.0
        self.batch_logs: List[BatchLog] = []
        self._build_jits()

    # ------------------------------------------------------------------ #
    def _build_jits(self) -> None:
        cfg, ecfg = self.cfg, self.ecfg
        key = (cfg, ecfg.impl, ecfg.moe_impl)
        legacy = ecfg.plane == "legacy"
        if ecfg.share_jits:
            slot = _shared_slot_jits()
            prefill_jit = (_shared_legacy_jit(*key) if legacy
                           else _shared_batched_jit(*key))
            decode_jit = _shared_decode_jit(*key, ecfg.decode_append)
        else:
            slot = tuple(jax.jit(f) for f in _make_slot_fns())
            prefill_jit = jax.jit(_make_legacy_prefill(*key) if legacy
                                  else _make_batched_prefill(*key))
            decode_jit = jax.jit(_make_decode(*key, ecfg.decode_append))
        # swap data plane: slot snapshot (device->host) and slot restore
        self._slot_slice, self._slot_write, self._reset_slot = slot
        if legacy:
            self._prefill_one = prefill_jit
        else:
            self._prefill_many = prefill_jit
        self._decode_many = decode_jit
        # num_compiles counts only the fns THIS plane can reach, so a
        # shared cache (share_jits) never leaks another plane's
        # signatures into this engine's count
        self._jit_fns = [prefill_jit, decode_jit, *slot]
        if self._pooled:
            if ecfg.share_jits:
                ppf, pdf = _shared_paged_jits(*key)
            else:
                pf, df = _make_paged_step_fns(*key)
                ppf, pdf = jax.jit(pf), jax.jit(df)
            self._paged_prefill, self._paged_decode = ppf, pdf
            self._jit_fns = [ppf, pdf]   # the pooled plane uses nothing else

    def warmup(self) -> "Engine":
        """Pre-compile every signature the run loop can hit — one
        prefill per ladder bucket plus the fused decode — with inert
        inputs (zero lengths, all-false masks): outputs are discarded
        and pools/cache stay bit-identical.  Benchmarks call this before
        their timed window (ideally with ``share_jits``) so measured
        tok/s prices data movement and compute, not XLA's
        backend_compile.  The legacy plane cannot warm up by
        construction: its exact-shape signatures depend on request data
        — which is precisely the shape-instability the bucket ladder
        fixes."""
        ns = self.ecfg.nslots
        zi = jnp.zeros((ns,), jnp.int32)
        za = jnp.zeros((ns,), bool)
        if self._pooled:
            bt = jnp.zeros((ns, self.max_pages), jnp.int32)
            for b in self.buckets:
                self._paged_prefill(self.params, self.k_pools,
                                    self.v_pools,
                                    jnp.zeros((ns, b + 2), jnp.int32), bt)
            self._paged_decode(self.params, self.k_pools, self.v_pools,
                               zi, zi, bt, za)
            # the suspend/restore data plane too: page-run gathers and
            # swap-in scatters build one eager executable per run
            # length — a small discrete set bounded by the per-request
            # page budget — and the first preemption would otherwise
            # eat those compiles inside the timed window.  Scattering a
            # page's own bytes back over itself is the identity, so the
            # pools stay bit-identical.
            for npg in range(1, self.max_pages + 1):
                ids = jnp.zeros((npg,), jnp.int32)
                for pool in (self.k_pools, self.v_pools):
                    run = pool[:, ids]
                    jax.block_until_ready(pool.at[:, ids].set(run))  # repro: allow-host-sync(warmup runs BEFORE the timed window by contract - blocking here is the point: compiles must finish before serving starts)
        elif self.ecfg.plane != "legacy":
            for b in self.buckets:
                self._prefill_many(self.params, self.cache,
                                   jnp.zeros((ns, b), jnp.int32), zi)
            self._decode_many(self.params, self.cache, zi, za)
            self._reset_slot(self.cache, 0)
        return self

    @property
    def num_compiles(self) -> int:
        """Distinct XLA compiles across every entry point this plane
        can reach.  The batched plane keeps this a small constant —
        independent of request count, prompt lengths, and preemptions
        (tested).  Under ``share_jits`` the caches are process-global,
        so the count covers every engine sharing them."""
        return sum(f._cache_size() for f in self._jit_fns)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise AssertionError(f"chunk step {n} exceeds ladder {self.buckets}")

    # ------------------------------------------------------------------ #
    def submit(self, r: Request) -> None:
        if r.prompt is None:
            raise ValueError("engine requests need real token ids")
        if len(r.prompt) != r.input_len:
            raise ValueError(
                f"request {r.rid}: prompt length {len(r.prompt)} != "
                f"input_len {r.input_len}")
        # window/ssm archs hold bounded state; dense caches must fit
        if not (self.cfg.window or self.cfg.family == "ssm"
                or r.peak_kv <= self.ecfg.cache_len):
            raise ValueError(
                f"request {r.rid} peak KV {r.peak_kv} > cache_len")
        self.token_ids[r.rid] = list(r.prompt)
        self.outputs[r.rid] = []
        self.sched.add_request(r)

    # ------------------------------------------------------------------ #
    def _claim_slot(self, rid: int, reset: bool = True) -> int:
        slot = self.free_slots.pop()
        self.slot_of[rid] = slot
        if reset:
            self.cache = self._reset_slot(self.cache, slot)
        return slot

    def _release(self, rid: int) -> None:
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self.free_slots.append(slot)
            if self._bt_host is not None:
                # the freed rid won't be in slot_of at the next delta
                # rebuild, so its row must be cleared here
                self._bt_host[slot, :] = 0
        self.allocator.free(rid)  # repro: allow-unpriced-mutation(releasing pages moves no bytes; the preemption decision that led here was already charged - swap_time or refill compute - by the scheduler)
        # refill restarts from scratch: drop generated tokens beyond prompt?
        # NO — generated tokens are kept and re-prefilled (paper §3 refill).

    # --- failure model: fault hooks, guarded puts, integrity ----------- #
    def _alloc_fault_hook(self, need: int) -> None:
        """``PagedAllocator.fault_hook``: a transient device fault on
        this (step, attempt, ordinal) aborts the attempt.  Keyed by
        attempt so the rolled-back retry draws fresh (no livelock), and
        trace-free by construction — an aborted attempt leaves no
        parity-visible state, so the simulator never mirrors these."""
        self._alloc_ordinal += 1
        if self.fault_plan.alloc_fault(self._step_no, self._attempt,
                                       self._alloc_ordinal):
            raise FaultError(
                f"injected allocation fault: step {self._step_no} "
                f"attempt {self._attempt} ordinal {self._alloc_ordinal}")

    def _retry_sleep(self, seconds: float) -> None:
        """Injectable backoff clock for ``run_with_retries``: records
        the schedule in virtual time instead of stalling the step."""
        self.swap_stats[SK.BACKOFF_S] += seconds

    _PERM_KIND = {"store_put": "perm_put", "store_run": "perm_run"}

    def _guarded_put(self, kind: str, key: Tuple, do_put):
        """Run a swap-store write under the fault plan.  A permanent
        draw raises ``PermanentStoreError`` — a ``SwapStoreFullError``
        subclass, so the caller's full-store fallback (drop + degrade
        to recompute) handles it unchanged.  A transient draw fails the
        write 1-3 times and then succeeds under ``run_with_retries``'s
        exponential backoff (rung 1 of the degradation ladder; the
        injected failures always fit the retry budget, so a transient
        fault alone never escalates)."""
        plan = self.fault_plan
        if plan is None:
            return do_put()
        if plan.decide(self._PERM_KIND[kind], *key):
            self.swap_stats[SK.PERMANENT_STORE_FAILURES] += 1
            raise PermanentStoreError(
                f"injected permanent store failure {kind}{key}")
        remaining = [plan.transient_failures(kind, *key)]

        def attempt():
            if remaining[0] > 0:
                remaining[0] -= 1
                self.swap_stats[SK.TRANSIENT_RETRIES] += 1
                raise TransientStoreError(
                    f"injected transient store failure {kind}{key}")
            return do_put()

        return run_with_retries(attempt, retries=3,
                                retry_on=(TransientStoreError,),
                                sleep=self._retry_sleep)

    def _corrupt_draw(self, kind: str, key: Tuple) -> bool:
        return (self.fault_plan is not None
                and self.fault_plan.decide(kind, *key))

    def _finalize_entry(self, entry) -> None:
        """Seal an entry's host bytes once; apply a pending corruption
        marker exactly once.  The seal-once guard doubles as the
        flip-once guard: after a step rollback the engine may re-drain
        an already-finalized entry (entry objects are shared by
        reference across snapshots — see ``txn.snapshot_store``), and
        re-sealing would bless the corruption while re-flipping would
        undo it."""
        if entry.crc is not None:
            return
        seal_entry(entry)
        if entry.corrupt and entry.crc is not None:
            flip_bit(entry.cache if isinstance(entry, SwapEntry)
                     else entry.kv)

    def _drop_snapshot_repair(self, r: Request):
        """Post-rollback repair for a corrupt full-slot snapshot: drop
        the entry and degrade ``r`` to recompute (the suspend never
        stuck, exactly like the store-full fallback)."""
        def repair() -> None:
            self.swap_store.discard(r.rid)
            self._pending_swaps.pop(r.rid, None)
            r.drop_suspended()
            self.sched.num_swaps -= 1
        return repair

    def _drop_runs_repair(self, r: Request, claim: bool):
        """Post-rollback repair for a corrupt page run: drop EVERY
        stored run of ``r`` (a tiling with a rotten stripe is
        unrestorable as a whole) and unwind the matching swap counters —
        the same arithmetic as the store-full fallbacks — degrading the
        request to recompute."""
        def repair() -> None:
            self._purge_pending_runs(r.rid)
            if claim:                      # fully suspended victim
                n = self.swap_store.discard_runs(r.rid)
                for _ in range(n - 1):     # tail runs beyond the base
                    r.swaps -= 1
                    self.sched.num_swaps -= 1
                r.drop_suspended()
                self.sched.num_swaps -= 1
            else:                          # partially shed victim
                for run in self.swap_store.pop_runs(r.rid):
                    r.drop_tail_run(run.num_tokens)
                    self.sched.num_swaps -= 1
        return repair

    # --- §5.4 swap data plane ------------------------------------------ #
    def _swap_out(self, victim: Request) -> bool:
        """Snapshot the victim's slot to the host store, then free it.
        Returns False when the store is full: the snapshot is dropped and
        the victim falls back to discard-and-recompute (finite host
        memory is the five-minute-rule's operating constraint).

        With ``async_swap`` the snapshot is a device-side slice whose
        host copy is started here and finalized later (``_drain_swaps``);
        capacity is charged immediately from array metadata so the
        full-store fallback stays synchronous and deterministic."""
        t0 = time.perf_counter()
        slot = self.slot_of[victim.rid]
        snap = self._slot_slice(self.cache, jnp.int32(slot))
        # content key: identical across engine/simulator and across
        # aborted-attempt retries, so fault draws are idempotent
        fkey = (victim.rid, victim.suspended_m, victim.swaps)
        try:
            if self.ecfg.async_swap:
                nbytes = sum(l.nbytes for l in jax.tree.leaves(snap))
                entry = self._guarded_put(
                    "store_put", fkey,
                    lambda: self.swap_store.put(
                        victim.rid, snap, self.token_ids[victim.rid],
                        victim.suspended_m, nbytes=nbytes))
                entry.corrupt = self._corrupt_draw("corrupt_put", fkey)
                for leaf in jax.tree.leaves(snap):
                    leaf.copy_to_host_async()
                self._pending_swaps[victim.rid] = (entry, self._step_no)
            else:
                snap = jax.device_get(snap)  # repro: allow-host-sync(the synchronous swap-out path async_swap=False selects; charged swap_time in virtual time and measured into wall_out_s)
                entry = self._guarded_put(
                    "store_put", fkey,
                    lambda: self.swap_store.put(
                        victim.rid, snap, self.token_ids[victim.rid],
                        victim.suspended_m))
                if self.ecfg.check_invariants:
                    # repro: allow-host-sync(invariant check reads the already-fetched host snapshot; no extra device traffic)
                    assert int(np.asarray(snap["index"])[0]) \
                        == victim.suspended_m, \
                        (victim.rid, snap["index"], victim.suspended_m)
                entry.corrupt = self._corrupt_draw("corrupt_put", fkey)
                self._finalize_entry(entry)
        except SwapStoreFullError:
            victim.drop_suspended()
            self.sched.num_swaps -= 1   # the suspend did not stick
            self.swap_stats[SK.SWAP_FALLBACKS] += 1
            self._release(victim.rid)
            return False
        self.swap_stats[SK.SWAP_OUTS] += 1
        self.swap_stats[SK.KV_OUT] += victim.suspended_m
        self.swap_stats[SK.WALL_OUT_S] += time.perf_counter() - t0
        self._release(victim.rid)
        # double buffering: finalize the oldest transfer(s) OUTSIDE the
        # timed enqueue window above (the drain bills its own wait into
        # wall_out_s — overlapping windows would double-count it)
        while len(self._pending_swaps) > 2:
            self._drain_swaps(rid=next(iter(self._pending_swaps)))
        return True

    def _drain_swaps(self, rid: Optional[int] = None,
                     before_step: Optional[int] = None) -> None:
        """Finalize in-flight swap-out transfers: block on the async D2H
        copy and replace the store entry's device leaves with host
        arrays.  ``rid`` drains one entry (same-window re-admission,
        double-buffer pressure); ``before_step`` drains entries enqueued
        before that step (the end-of-step boundary); neither drains
        everything (end of run).  In-flight prefix demotions AND pooled
        page-run snapshots share the ``before_step`` / drain-all
        boundaries (``rid`` here is a slot-plane concept; demotes drain
        per chain key via ``_drain_demotes``, runs per rid via
        ``_drain_runs``)."""
        if rid is not None:
            rids = [rid] if rid in self._pending_swaps else []
        elif before_step is not None:
            rids = [r for r, (_, s) in self._pending_swaps.items()
                    if s < before_step]
        else:
            rids = list(self._pending_swaps)
        for r in rids:
            entry, _ = self._pending_swaps.pop(r)
            t0 = time.perf_counter()
            # the drain IS the double-buffer boundary: the one place the
            # slot plane may block on its own already-started D2H copy
            entry.cache = jax.device_get(entry.cache)  # repro: allow-host-sync(async swap-out drain boundary - blocks only on a D2H copy started a step earlier, overlapped with that step's compute)
            if self.ecfg.check_invariants:
                assert int(np.asarray(entry.cache["index"])[0]) \
                    == entry.num_kv, (r, entry.cache["index"], entry.num_kv)
            self._finalize_entry(entry)   # CRC seal (+ fault-plan flip)
            self.swap_stats[SK.WALL_OUT_S] += time.perf_counter() - t0
        if rid is None:
            if before_step is not None:
                keys = [k for k, s in self._pending_demotes.items()
                        if s < before_step]
            else:
                keys = list(self._pending_demotes)
            for k in keys:
                self._drain_demotes(key=k)
            self._drain_runs(before_step=before_step)

    def _drain_demotes(self, key: int) -> None:
        """Finalize one in-flight prefix-page demotion: block on the
        async D2H copy and replace the entry's device leaves with host
        arrays.  A key whose entry was promoted (or discarded) before
        the drain is simply forgotten — its bytes never round-tripped,
        and ``pop_prefix`` already settled the byte accounting."""
        self._pending_demotes.pop(key, None)
        entry = self.swap_store.peek_prefix(key)
        if entry is None:
            return
        t0 = time.perf_counter()
        entry.kv = jax.device_get(entry.kv)  # repro: allow-host-sync(async demotion drain boundary - blocks only on its own already-started D2H page copy)
        seal_entry(entry)   # prefix rot is modeled by flag, never flipped
        self.swap_stats[SK.WALL_DEMOTE_S] += time.perf_counter() - t0

    def _swap_in(self, r: Request) -> None:
        """Restore r's snapshot into a free slot; no refill is needed."""
        if r.rid in self._pending_swaps:
            # re-admitted within the drain window: finalize on demand
            self.swap_stats[SK.DRAINS_ON_SWAPIN] += 1
            self._drain_swaps(rid=r.rid)
        if not verify_entry(self.swap_store.peek(r.rid)):
            # rung 3: corrupt snapshot — abort the step; post-rollback
            # the repair drops the entry and degrades r to recompute
            raise IntegrityError(
                f"rid {r.rid}: corrupt swap snapshot",
                repairs=[self._drop_snapshot_repair(r)])
        t0 = time.perf_counter()
        entry = self.swap_store.pop(r.rid)
        slot = self._claim_slot(r.rid, reset=False)  # fully overwritten
        upd = jax.tree.map(jnp.asarray, entry.cache)
        self.cache = self._slot_write(self.cache, upd, jnp.int32(slot))
        jax.block_until_ready(self.cache["index"])  # repro: allow-host-sync(restore barrier - the slot must be fully written before this step's compute reads it; measured into wall_in_s)
        self.allocator.allocate(r.rid, entry.num_kv)
        restored = r.resume()
        if self.ecfg.check_invariants:
            assert restored == entry.num_kv, (r.rid, restored, entry.num_kv)
            assert self.token_ids[r.rid] == entry.tokens, r.rid
        self.swap_stats[SK.SWAP_INS] += 1
        self.swap_stats[SK.KV_IN] += entry.num_kv
        self.swap_stats[SK.WALL_IN_S] += time.perf_counter() - t0

    # --- pooled (paged) swap data plane -------------------------------- #
    def _check_run_capacity(self, npages: int) -> None:
        """Raise ``SwapStoreFullError`` from shape metadata BEFORE the
        D2H page gather — a doomed snapshot must not pay the transfer
        (mirrors the slot plane's charge-at-enqueue)."""
        cap = self.swap_store.capacity_bytes
        if cap is None:
            return
        itemsize = jnp.dtype(self.cfg.dtype).itemsize
        nbytes = 2 * self.cfg.num_layers * npages * self.ecfg.page_size \
            * self.cfg.num_kv_heads * self.cfg.head_dim_ * itemsize
        if self.swap_store.nbytes + nbytes > cap:
            raise SwapStoreFullError(
                f"page run of {npages} pages ({nbytes}B) over capacity "
                f"({self.swap_store.nbytes}/{cap}B held)")

    def _snapshot_pages(self, page_ids) -> Dict[str, np.ndarray]:
        ids = np.asarray(page_ids, np.int32)
        return {"k": np.asarray(self.k_pools[:, ids]),   # repro: allow-host-sync(the synchronous page gather async_swap=False selects; pooled suspends, tail sheds and prefix demotions all route around it under async_swap)
                "v": np.asarray(self.v_pools[:, ids])}   # repro: allow-host-sync(same sync gather as the k plane above)

    def _gather_pages_device(self, page_ids) -> Dict[str, jnp.ndarray]:
        """Async page snapshot: gather the pages into FRESH device
        buffers (immutable — later pool writes, and even freeing the
        source pages, cannot alias them) and start the D2H copy
        immediately; the host bytes land at a drain boundary
        (``_drain_runs``).  This is what lets ``_shed_tail`` free the
        gathered pages in the same step without waiting on the host
        link."""
        ids = jnp.asarray(np.asarray(page_ids, np.int32))
        kv = {"k": self.k_pools[:, ids], "v": self.v_pools[:, ids]}
        kv["k"].copy_to_host_async()
        kv["v"].copy_to_host_async()
        return kv

    def _drain_runs(self, rid: Optional[int] = None,
                    before_step: Optional[int] = None) -> None:
        """Finalize in-flight pooled page-run snapshots — the paged
        plane's analogue of ``_drain_swaps``: block on the
        already-started D2H copy, replace the entry's device leaves
        with host arrays, CRC-seal (+ apply any pending corruption
        marker)."""
        if rid is not None:
            keys = [k for k in self._pending_runs if k[0] == rid]
        elif before_step is not None:
            keys = [k for k, (_, s) in self._pending_runs.items()
                    if s < before_step]
        else:
            keys = list(self._pending_runs)
        for k in keys:
            entry, _ = self._pending_runs.pop(k)
            t0 = time.perf_counter()
            entry.kv = jax.device_get(entry.kv)  # repro: allow-host-sync(async page-run drain boundary - blocks only on a D2H copy started at suspend time and overlapped with later compute)
            self._finalize_entry(entry)
            self.swap_stats[SK.WALL_OUT_S] += time.perf_counter() - t0

    def _purge_pending_runs(self, rid: int) -> None:
        """Forget in-flight snapshots of runs the store no longer holds
        (full-store unwind, recompute discard, post-rollback repair):
        their entries were already popped, so draining them would
        finalize dangling objects and misattribute wall time."""
        for k in [k for k in self._pending_runs if k[0] == rid]:
            del self._pending_runs[k]

    def _restore_pages(self, page_ids, kv) -> None:
        ids = jnp.asarray(np.asarray(page_ids, np.int32))
        self.k_pools = self.k_pools.at[:, ids].set(jnp.asarray(kv["k"]))
        self.v_pools = self.v_pools.at[:, ids].set(jnp.asarray(kv["v"]))

    def _swap_out_paged(self, victim: Request) -> bool:
        """Full suspend in the pooled plane: one ``PageRunEntry`` run
        covering every device page (tail runs shed earlier are already
        in the store; together they tile [0, suspended_m)).  Returns
        False when the store is full — the victim (and any stored tail
        runs) falls back to discard-and-recompute.

        With ``async_swap`` the snapshot is a device-side page gather
        (fresh immutable buffers, so the freed pages can be reused this
        very step) whose host copy is started here and finalized at a
        drain boundary; capacity is charged from shape metadata before
        the gather, so the full-store fallback stays synchronous."""
        t0 = time.perf_counter()
        tbl = self.allocator.table(victim.rid)
        device_tokens = tbl.num_tokens
        # same key shape as the slot plane's full suspend, so the
        # simulator's fault mirror is plane-agnostic
        fkey = (victim.rid, victim.suspended_m, victim.swaps)
        try:
            self._check_run_capacity(len(tbl.pages))  # before the D2H copy
            if self.ecfg.async_swap:
                kv = self._gather_pages_device(tbl.pages)
                entry = self._guarded_put(
                    "store_put", fkey,
                    lambda: self.swap_store.put_run(
                        victim.rid, start=0, num_tokens=device_tokens,
                        kv=kv, nbytes=kv["k"].nbytes + kv["v"].nbytes))
                entry.corrupt = self._corrupt_draw("corrupt_put", fkey)
                self._pending_runs[(victim.rid, 0)] = (entry, self._step_no)
            else:
                entry = self._guarded_put(
                    "store_put", fkey,
                    lambda: self.swap_store.put_run(
                        victim.rid, start=0, num_tokens=device_tokens,
                        kv=self._snapshot_pages(tbl.pages)))
                entry.corrupt = self._corrupt_draw("corrupt_put", fkey)
                self._finalize_entry(entry)
        except SwapStoreFullError:
            # stored tail runs are unrestorable without the device
            # portion: unwind their swap counts along with this one
            if self.swap_store.has_runs(victim.rid):
                for _ in self.swap_store.pop_runs(victim.rid):
                    victim.swaps -= 1
                    self.sched.num_swaps -= 1
                    self.swap_stats[SK.SWAP_FALLBACKS] += 1
            self._purge_pending_runs(victim.rid)
            victim.drop_suspended()
            self.sched.num_swaps -= 1   # the suspend did not stick
            self.swap_stats[SK.SWAP_FALLBACKS] += 1
            self._release(victim.rid)
            return False
        self.swap_stats[SK.SWAP_OUTS] += 1
        self.swap_stats[SK.KV_OUT] += device_tokens
        self.swap_stats[SK.WALL_OUT_S] += time.perf_counter() - t0
        self._release(victim.rid)
        # double buffering, as in _swap_out: finalize the oldest
        # transfer(s) outside the timed enqueue window above
        while len(self._pending_runs) > 2:
            self._drain_runs(rid=next(iter(self._pending_runs))[0])
        return True

    def _shed_tail(self, r: Request, npages: int, n_tokens: int,
                   mode: str) -> bool:
        """Page-level partial preemption: snapshot (swap mode) and free
        only the victim's last ``npages`` pages.  Returns True iff the
        run was swapped (caller charges its host-link time); a full
        store falls back to recompute for this run."""
        tbl = self.allocator.table(r.rid)
        start = tbl.num_tokens - n_tokens
        swapped = False
        if mode == "swap":
            t0 = time.perf_counter()
            # r.m is already reduced to the run's start by the
            # scheduler's partial_preempt, so this key is stable across
            # attempt retries and reproducible by the simulator
            fkey = (r.rid, r.m, n_tokens, r.partial_preemptions)
            try:
                self._check_run_capacity(npages)   # before the D2H copy
                if self.ecfg.async_swap:
                    # the gather's fresh buffers are what make the
                    # free_tail below safe in the same step
                    kv = self._gather_pages_device(tbl.pages[-npages:])
                    entry = self._guarded_put(
                        "store_run", fkey,
                        lambda: self.swap_store.put_run(
                            r.rid, start=start, num_tokens=n_tokens,
                            kv=kv,
                            nbytes=kv["k"].nbytes + kv["v"].nbytes))
                    entry.corrupt = self._corrupt_draw("corrupt_run", fkey)
                    self._pending_runs[(r.rid, start)] = \
                        (entry, self._step_no)
                else:
                    entry = self._guarded_put(
                        "store_run", fkey,
                        lambda: self.swap_store.put_run(
                            r.rid, start=start, num_tokens=n_tokens,
                            kv=self._snapshot_pages(tbl.pages[-npages:])))
                    entry.corrupt = self._corrupt_draw("corrupt_run", fkey)
                    self._finalize_entry(entry)
                swapped = True
                self.swap_stats[SK.SWAP_OUTS] += 1
                self.swap_stats[SK.KV_OUT] += n_tokens
                self.swap_stats[SK.WALL_OUT_S] += time.perf_counter() - t0
            except SwapStoreFullError:
                r.drop_tail_run(n_tokens)
                self.sched.num_swaps -= 1
                self.swap_stats[SK.SWAP_FALLBACKS] += 1
                # the failed run sits BELOW every run already stored for
                # this rid (the tail is shed top-down), so the stored
                # tiling now has an unrestorable gap: fold those runs
                # back to recompute too
                if self.swap_store.has_runs(r.rid):
                    for run in self.swap_store.pop_runs(r.rid):
                        r.drop_tail_run(run.num_tokens)
                        self.sched.num_swaps -= 1
                        self.swap_stats[SK.SWAP_FALLBACKS] += 1
                self._purge_pending_runs(r.rid)
        removed = self.allocator.free_tail(r.rid, npages)
        if self.ecfg.check_invariants:
            assert removed == n_tokens, (r.rid, removed, n_tokens)
        if swapped:
            while len(self._pending_runs) > 2:
                self._drain_runs(rid=next(iter(self._pending_runs))[0])
        return swapped

    def _swap_in_paged(self, r: Request) -> None:
        """Restore a fully suspended pooled request: fresh pages are
        allocated and every stored run is scattered back in ascending
        start order (their page spans tile the table exactly)."""
        self._restore_runs(r, claim=True, resume=r.resume)

    def _swap_in_tail(self, r: Request) -> None:
        """Restore a partially shed request's tail runs before its next
        compute step (the kept prefix never left the device)."""
        self._restore_runs(r, claim=False, resume=r.resume_tail)

    def _restore_runs(self, r: Request, *, claim: bool, resume) -> None:
        if any(k[0] == r.rid for k in self._pending_runs):
            # re-admitted within the drain window: finalize on demand —
            # BEFORE the verify below, which is trivially true (crc
            # None) on an undrained entry
            self.swap_stats[SK.DRAINS_ON_SWAPIN] += 1
            self._drain_runs(rid=r.rid)
        if not all(verify_entry(run)
                   for run in self.swap_store.peek_runs(r.rid)):
            # rung 3: one rotten stripe poisons the whole tiling —
            # abort; the post-rollback repair drops every stored run
            # and degrades r to recompute
            raise IntegrityError(
                f"rid {r.rid}: corrupt page run",
                repairs=[self._drop_runs_repair(r, claim)])
        t0 = time.perf_counter()
        runs = self.swap_store.pop_runs(r.rid)
        total = sum(run.num_tokens for run in runs)
        if claim:
            self._claim_slot(r.rid, reset=False)
        self.allocator.allocate(r.rid, total)
        self._write_runs(r.rid, runs)
        restored = resume()
        if self.ecfg.check_invariants:
            assert restored == total, (r.rid, restored, total)
        self.swap_stats[SK.SWAP_INS] += len(runs)   # run-for-run with outs
        self.swap_stats[SK.KV_IN] += total
        self.swap_stats[SK.WALL_IN_S] += time.perf_counter() - t0

    def _write_runs(self, rid: int, runs) -> None:
        pg = self.ecfg.page_size
        tbl = self.allocator.table(rid)
        for run in runs:
            invariant(run.start % pg == 0, (rid, run.start))
            p0 = run.start // pg
            npg = -(-run.num_tokens // pg)
            self._restore_pages(tbl.pages[p0:p0 + npg], run.kv)

    # --- shared-prefix reuse (pooled plane) ----------------------------- #
    def _page_keys(self, r: Request) -> List[int]:
        keys = self._page_keys_of.get(r.rid)
        if keys is None:
            keys = chain_keys(r.prompt, self.ecfg.page_size)
            self._page_keys_of[r.rid] = keys
        return keys

    def _page_tokens(self, r: Request, n: int) -> List[Tuple[int, ...]]:
        """Token ids of the first n full prompt pages (the registry's
        collision-verification payload), memoized per rid like
        ``_page_keys`` — the attach and every later registration
        re-derive the same leading pages."""
        toks = self._page_tokens_of.get(r.rid)
        if toks is None or len(toks) < n:
            pg = self.ecfg.page_size
            toks = [tuple(r.prompt[i * pg:(i + 1) * pg]) for i in range(n)]
            self._page_tokens_of[r.rid] = toks
        return toks[:n]

    def _demote_prefix(self, key: int, page: int, tokens, n_kvs: int
                       ) -> None:
        """Allocator eviction hook: snapshot the evicted registry page
        to the host demotion tier (refcount-free ``PrefixPageEntry``)
        instead of discarding its KV.  A full store drops the demotion —
        the page falls back to recompute-on-next-miss, the pre-demotion
        behaviour.  Charged ``swap_time(page_size)`` in virtual time
        (folded into the current batch) and measured on the wall.

        With ``async_swap`` the snapshot is a device-side page gather
        (a fresh immutable buffer, so the pool slot can be reused
        immediately) whose host copy is started here and finalized at
        the next drain boundary; capacity is charged from array
        metadata so the full-store drop stays synchronous.  Without it,
        the gather is a blocking ``device_get`` on the eviction path —
        the stall ROADMAP item 1 measured eating the prefix-sharing
        win."""
        if self.swap_store.has_prefix(key):
            return          # an identical snapshot is already host-resident
        if self.fault_plan is not None \
                and self.fault_plan.decide("demote_fail", key):
            # the async D2H copy "never lands": drop the demotion — the
            # page recomputes on its next miss, the pre-demotion
            # behaviour — with no charge.  PrefixTierSim mirrors the
            # same draw, so demote_drops stays parity-comparable.
            self.swap_stats[SK.DEMOTE_DROPS] += 1
            return
        t0 = time.perf_counter()
        try:
            self._check_run_capacity(1)     # metadata check BEFORE the D2H
            if self.ecfg.async_swap:
                ids = jnp.asarray([page], jnp.int32)
                kv = {"k": self.k_pools[:, ids], "v": self.v_pools[:, ids]}
                self.swap_store.put_prefix(
                    key, tokens, n_kvs, kv,
                    nbytes=kv["k"].nbytes + kv["v"].nbytes)
                kv["k"].copy_to_host_async()
                kv["v"].copy_to_host_async()
                self._pending_demotes[key] = self._step_no
            else:
                seal_entry(self.swap_store.put_prefix(
                    key, tokens, n_kvs, self._snapshot_pages([page])))
        except SwapStoreFullError:
            self.swap_stats[SK.DEMOTE_DROPS] += 1
            return
        pg = self.ecfg.page_size
        self._tier_swap_s += self._swap_time(pg)
        self.swap_stats[SK.DEMOTIONS] += 1
        self.swap_stats[SK.KV_DEMOTED] += pg
        self.swap_stats[SK.WALL_DEMOTE_S] += time.perf_counter() - t0
        # double buffering, as in _swap_out: finalize the oldest
        # transfer(s) outside the timed enqueue window above
        while len(self._pending_demotes) > 2:
            self._drain_demotes(key=next(iter(self._pending_demotes)))

    def _verify_prefix(self, entry) -> bool:
        """Promotion gate of ``attach_prefix_run``: CRC-check the
        host-resident page and consult the fault plan —
        ``corrupt_prefix`` models rot the CRC would catch on a drained
        entry (flagged, never byte-flipped: async drain timing must not
        diverge engine from simulator), ``promote_fail`` a failed host
        read.  A bad entry is dropped by the attach (the page
        recomputes); counted in ``swap_stats`` (step-txn scoped) so the
        simulator mirror stays parity-comparable."""
        plan = self.fault_plan
        ok = verify_entry(entry) and not (
            plan is not None
            and (plan.decide("corrupt_prefix", entry.key)
                 or plan.decide("promote_fail", entry.key)))
        if not ok:
            self.swap_stats[SK.PREFIX_INTEGRITY] += 1
        return ok

    def _promote_restore(self, page: int, kv) -> None:
        t0 = time.perf_counter()
        self._restore_pages([page], kv)
        self.swap_stats[SK.WALL_PROMOTE_S] += time.perf_counter() - t0

    def _attach_prefix(self, r: Request, c: int) -> int:
        """At a fresh claim, map the LONGEST cached run matching the
        prompt's leading full pages into r's block table (radix-trie
        walk — partial hits included) and return the number of tokens
        whose prefill compute is SKIPPED.  The trie resolves the run on
        the device first, then (with demotion enabled) extends it
        against the host tier — a host hit promotes the page back
        through the swap path, charged ``swap_time`` into this batch's
        virtual time exactly like a §5.4 swap-in.  Under
        ``prefix_lookup="exact"`` the attach is all-or-nothing (the
        pre-trie ablation).  Control-plane accounting is untouched
        (each sharer is charged its full page-rounded occupancy —
        sharing only ever reduces physical use), so admitted schedules
        stay allocator-feasible.  At least one granted token is always
        computed (the emitting batch needs real logits), and only pages
        wholly inside this grant qualify."""
        pg = self.ecfg.page_size
        cap = min(r.input_len - 1, c - 1) // pg
        if pg <= 1 or cap <= 0:
            return 0
        attached, promoted = attach_prefix_run(
            self.allocator, r.rid, self._page_keys(r)[:cap],
            self._page_tokens(r, cap),
            host_tier=self.swap_store if self._demotion else None,
            restore=self._promote_restore,
            verify=self._verify_prefix if self._demotion else None,
            exact=self.sched.cfg.prefix_lookup == "exact")
        if promoted:
            self._tier_swap_s += self._swap_time(promoted)
            self.swap_stats[SK.PROMOTIONS] += promoted // pg
            self.swap_stats[SK.KV_PROMOTED] += promoted
        if attached:
            self.swap_stats[SK.TRIE_HITS] += 1
            if attached < cap * pg:
                self.swap_stats[SK.PARTIAL_HIT_TOKENS] += attached
        return attached

    def _register_prefix(self, r: Request, m_new: int) -> None:
        """Publish the now-complete full PROMPT pages to the registry
        (generated-token pages are never shared)."""
        n = min(m_new, r.input_len) // self.ecfg.page_size
        if n > 0 and self.allocator.has(r.rid):
            # repro: allow-unpriced-mutation(registration moves no bytes - the pages already live on device, owned by rid; charges accrue at eviction/demotion/promotion)
            self.allocator.register_prefix(r.rid, self._page_keys(r)[:n],
                                           self._page_tokens(r, n))

    def _cow_guard(self, rid: int, pos: int) -> None:
        """Copy-on-write: an in-page append at token position ``pos``
        writes into an existing page — remap + copy it first if shared
        or registry-pinned (full-page-only sharing makes this rare, but
        the guard is what makes the sharing SAFE)."""
        pg = self.ecfg.page_size
        if pos % pg == 0:
            return                      # boundary: a fresh private page
        moved = self.allocator.ensure_private(rid, pos // pg)  # repro: allow-unpriced-mutation(CoW remap is a device-side page copy with no host traffic; its cost rides the decode batch_time)
        if moved is not None:
            old, new = moved
            self.k_pools = self.k_pools.at[:, new].set(self.k_pools[:, old])
            self.v_pools = self.v_pools.at[:, new].set(self.v_pools[:, old])

    def _block_tables_device(self) -> jnp.ndarray:
        """Device-side (nslots, max_pages) block tables, cached against
        the allocator's mutation version — decode steps that allocated
        nothing new (in-page appends) skip the refresh entirely.  On a
        version bump only the rows of rids whose page list actually
        changed (``consume_dirty``) are rewritten in the persistent
        host mirror, then the whole mirror ships in ONE upload: a
        thousand-slot step that grew one table touches one row."""
        v = self.allocator.version
        if self._bt_cache is not None and self._bt_cache[0] == v:
            return self._bt_cache[1]
        t0 = time.perf_counter()
        if self._bt_host is None:
            self._bt_host = np.zeros((self.ecfg.nslots, self.max_pages),
                                     np.int32)
            self.allocator.consume_dirty()
            dirty = set(self.slot_of)          # first build: all rows
        else:
            dirty = self.allocator.consume_dirty()
        for rid in dirty:
            slot = self.slot_of.get(rid)
            if slot is None:
                continue     # freed rid: _release already zeroed its row
            row = self._bt_host[slot]
            row[:] = 0
            if self.allocator.has(rid):
                pages = self.allocator.table(rid).pages
                row[:len(pages)] = pages
        # the np.array COPY is load-bearing: on CPU jnp.asarray may
        # alias the numpy buffer zero-copy, and later in-place edits of
        # the mirror would corrupt device tables still referenced by
        # step-txn snapshots
        self._bt_cache = (v, jnp.asarray(np.array(self._bt_host)))
        # repro: allow-txn-coverage(phase_stats is measured wall-clock attribution - real time spent is real even on an aborted attempt; parity never compares it)
        self.phase_stats[SK.UPLOAD_S] += time.perf_counter() - t0
        return self._bt_cache[1]

    def _swap_time(self, n_kvs: int) -> float:
        return self.cost_model.swap_time(n_kvs) if self.cost_model else 0.0

    def _sample(self, logits: jnp.ndarray) -> int:
        """Greedy over the REAL vocabulary (padding logits excluded)."""
        return int(jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1))

    # ------------------------------------------------------------------ #
    def _run_prefills_legacy(self, prefill_items) -> Dict[int, int]:
        """PR-1 plane: per-request chunk loop with exact (unpadded)
        shapes — every distinct tail length compiles a new signature."""
        final_tok: Dict[int, int] = {}
        for r, c in prefill_items:
            slot = self.slot_of[r.rid]
            ids = self.token_ids[r.rid]
            start, remaining = r.m, c
            logits = None
            while remaining > 0:
                step_c = min(self.ecfg.chunk, remaining)
                toks = jnp.asarray([ids[start:start + step_c]], jnp.int32)  # repro: allow-dynamic-shape(legacy plane pre-dates bucketing; distinct lengths are bounded by the chunk ladder and pinned by the compile-count test)
                logits, self.cache = self._prefill_one(
                    self.params, self.cache, jnp.int32(slot), toks)
                start += step_c
                remaining -= step_c
            if r.m + c == r.target_context:   # this batch emits a token
                final_tok[r.rid] = self._sample(logits)
        return final_tok

    def _run_prefill_rounds(self, plans, emits, step_fn) -> Dict[int, int]:
        """Shared bucketed round loop of the batched AND paged planes:
        one ``step_fn(toks, lens, starts)`` call per round over the full
        slot grid, sub-chunks padded to the bucket ladder.  Only
        (nslots,) sampled token ids are fetched, and only on rounds
        where some emitting request finishes its batch allotment.
        ``plans`` rows are [request, slot, next-token cursor, remaining]."""
        nslots = self.ecfg.nslots
        final_tok: Dict[int, int] = {}
        while True:
            steps = {p[1]: min(self.ecfg.chunk, p[3])
                     for p in plans if p[3] > 0}
            if not steps:
                break
            bucket = self._bucket_for(max(steps.values()))
            toks = np.zeros((nslots, bucket), np.int32)
            lens = np.zeros((nslots,), np.int32)
            starts = np.zeros((nslots,), np.int32)
            finishing: List[Tuple[Request, int]] = []
            for p in plans:
                r, slot, cursor, rem = p
                if rem <= 0:
                    continue
                sc = steps[slot]
                toks[slot, :sc] = self.token_ids[r.rid][cursor:cursor + sc]
                lens[slot] = sc
                starts[slot] = cursor
                p[2] += sc
                p[3] -= sc
                if p[3] == 0:
                    finishing.append((r, slot))
            tok_ids = step_fn(toks, lens, starts)
            if any(emits[r.rid] for r, _ in finishing):
                host = np.asarray(tok_ids)  # repro: allow-host-sync(per-step sampled-token fetch - ids must reach the host to extend prompts and detect EOS; (nslots,) int32 only)
                for r, slot in finishing:
                    if emits[r.rid]:
                        final_tok[r.rid] = int(host[slot])
        return final_tok

    def _run_prefills_batched(self, prefill_items) -> Dict[int, int]:
        """Shape-stable slot plane: the shared round loop over
        ``prefill_many`` (starts are implicit in the cache index)."""
        plans = [[r, self.slot_of[r.rid], r.m, c] for r, c in prefill_items]
        emits = {r.rid: r.m + c == r.target_context for r, c in prefill_items}

        def step(toks, lens, starts):
            tok_ids, self.cache = self._prefill_many(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(lens))
            return tok_ids

        return self._run_prefill_rounds(plans, emits, step)

    def _run_prefills_paged(self, prefill_items) -> Dict[int, int]:
        """Pooled plane: the shared round loop over ``paged_prefill`` —
        K/V rows are written through the block table into pooled pages.
        A grant's leading registry-shared tokens (``_prefix_skip``) are
        satisfied by page mapping and never computed (the cursor starts
        past them)."""
        plans = []
        for r, c in prefill_items:
            skip = self._prefix_skip.pop(r.rid, 0)
            plans.append([r, self.slot_of[r.rid], r.m + skip, c - skip])
        emits = {r.rid: r.m + c == r.target_context for r, c in prefill_items}
        block_tables = self._block_tables_device()

        def step(toks, lens, starts):
            # one coalesced upload per round — [toks | lens | starts]
            # ride a single (nslots, bucket+2) grid, unpacked on device
            # inside the jitted step (see _make_paged_step_fns)
            t0 = time.perf_counter()
            grid = jnp.asarray(np.concatenate(
                [toks, lens[:, None], starts[:, None]], axis=1))
            self.phase_stats[SK.UPLOAD_S] += time.perf_counter() - t0
            tok_ids, self.k_pools, self.v_pools = self._paged_prefill(
                self.params, self.k_pools, self.v_pools, grid,
                block_tables)
            return tok_ids

        return self._run_prefill_rounds(plans, emits, step)

    def _run_decodes_paged(self, decode_items) -> np.ndarray:
        """One fused decode step over all slots against the pooled KV:
        scatter the new token's K/V through the block table, then
        flash-decode over scalar-prefetched pages.

        Steady-state decode uploads NOTHING: the inputs of step N+1 are
        step N's own device outputs — last step's argmax ids ARE this
        step's tokens, and ctx advances by the (cached) active mask —
        so a stable cohort runs entirely device-resident.  The cohort
        key is (rid, slot, m, len(token_ids)) per row: any admission,
        finish, preemption, swap-in, or recompute-refill (the ntoks
        term — a refill re-emits and appends, so (rid, slot, m) alone
        could match a stale token buffer) perturbs it and forces one
        packed re-upload.  Non-cohort rows carry garbage on a hit,
        harmlessly: inactive scatters route out of bounds and their
        outputs are never read."""
        nslots = self.ecfg.nslots
        key = tuple(sorted(
            (r.rid, self.slot_of[r.rid], r.m, len(self.token_ids[r.rid]))
            for r, _ in decode_items))
        st = self._decode_state
        t0 = time.perf_counter()
        if st is not None and st["key"] == key:
            toks_dev, ctx_dev = st["toks"], st["ctx"]
            active_dev, ones = st["active"], st["ones"]
        else:
            toks = np.zeros((nslots,), np.int32)
            ctx = np.zeros((nslots,), np.int32)
            active = np.zeros((nslots,), bool)
            for r, _ in decode_items:
                slot = self.slot_of[r.rid]
                toks[slot] = self.token_ids[r.rid][-1]
                ctx[slot] = r.m
                active[slot] = True
            packed = jnp.asarray(np.stack([toks, ctx]))  # ONE i32 upload
            toks_dev, ctx_dev = packed[0], packed[1]
            active_dev = jnp.asarray(active)
            ones = active_dev.astype(jnp.int32)
        self.phase_stats[SK.UPLOAD_S] += time.perf_counter() - t0
        tok_ids, self.k_pools, self.v_pools = self._paged_decode(
            self.params, self.k_pools, self.v_pools, toks_dev,
            ctx_dev, self._block_tables_device(), active_dev)
        nxt = tuple(sorted(
            (r.rid, self.slot_of[r.rid], r.m + 1,
             len(self.token_ids[r.rid]) + 1) for r, _ in decode_items))
        self._decode_state = {"key": nxt, "toks": tok_ids,
                              "ctx": ctx_dev + ones,
                              "active": active_dev, "ones": ones}
        return np.asarray(tok_ids)  # repro: allow-host-sync(per-step sampled-token fetch - ids must reach the host to extend prompts and detect EOS; (nslots,) int32 only)

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """Run one scheduler batch ATOMICALLY.  Returns the number of
        items executed.

        The whole batch — preemptions, swap-outs/ins, slot claims,
        prefix attach/CoW, allocation, pricing, compute — runs inside a
        step transaction (``serving.txn``).  An injected transient
        device fault (``FaultError``) or a corrupt host snapshot
        (``IntegrityError``) aborts the attempt: every control-plane
        participant rolls back to batch start, the error's repairs drop
        any poisoned entries (degrading their requests to recompute),
        and the step retries — so generated tokens are identical to the
        fault-free run by construction.  A real ``OutOfPagesError``
        also rolls back (the caller observes invariant-clean state) but
        re-raises: it signals an accounting bug, not a survivable
        fault."""
        if not self.sched.has_work():
            return 0
        self._step_no += 1  # repro: allow-txn-coverage(step identity deliberately survives rollback - a retried attempt is the SAME step, and drain/fault keying depends on that)
        for attempt in range(_MAX_STEP_ATTEMPTS):
            # repro: allow-txn-coverage(attempt bookkeeping is reset at every attempt start and keys the per-attempt fault draws - restoring it would replay attempt 0's faults forever)
            self._attempt, self._alloc_ordinal = attempt, 0
            txn = self._begin_txn()
            t0 = time.perf_counter()
            try:
                executed = self._step_attempt()
            except (FaultError, IntegrityError) as e:
                txn.rollback()
                aborted_s = time.perf_counter() - t0
                self.wall += aborted_s  # repro: allow-txn-coverage(measured wall of the aborted attempt is real elapsed time - rolling it back would hide the Fig. 9 recovery cost)
                # repro: allow-txn-coverage(recovery accounting counts rollbacks so it must survive them - written only AFTER txn.rollback, never inside a txn)
                self.recovery_stats[SK.ROLLBACKS] += 1
                self.recovery_stats[SK.WALL_ABORTED_S] += aborted_s
                if isinstance(e, IntegrityError):
                    self.recovery_stats[SK.INTEGRITY_FAILURES] += 1
                    self.recovery_stats[SK.DEGRADED_RECOMPUTES] += 1
                    for repair in e.repairs:   # on rolled-back state
                        repair()
                else:
                    self.recovery_stats[SK.ALLOC_FAULTS] += 1
                continue
            except OutOfPagesError:
                txn.rollback()
                self.recovery_stats[SK.ROLLBACKS] += 1
                raise
            if (self._straggler is not None and executed
                    and self._straggler.observe(predicted_s=self._last_dt,
                                                actual_s=self._last_wall)):
                self._requeue_stragglers()
            return executed
        raise RuntimeError(
            f"step {self._step_no}: {_MAX_STEP_ATTEMPTS} fault-recovery "
            f"attempts exhausted")

    def _begin_txn(self) -> StepTxn:
        """Open the step transaction: common participants via
        ``begin_step_txn`` plus the engine-local view.  Device KV needs
        only reference saves — JAX arrays are immutable, so restoring
        ``cache``/``k_pools``/``v_pools`` rolls back every in-step
        scatter for free."""
        txn = begin_step_txn(
            scheduler=self.sched, allocator=self.allocator,
            store=self.swap_store,
            requests=self.sched.waiting + self.sched.running)
        cache = self.cache
        pools = (self.k_pools, self.v_pools) if self._pooled else None
        slot_of, free_slots = dict(self.slot_of), list(self.free_slots)
        token_ids = {k: list(v) for k, v in self.token_ids.items()}
        outputs = {k: list(v) for k, v in self.outputs.items()}
        page_keys = dict(self._page_keys_of)
        page_tokens = dict(self._page_tokens_of)
        skip = dict(self._prefix_skip)
        bt_cache = self._bt_cache
        # deep copy: the delta rebuild mutates the mirror in place
        bt_host = np.array(self._bt_host) \
            if self._bt_host is not None else None
        decode_state = self._decode_state   # replaced wholesale per step
        pending = OrderedDict(self._pending_swaps)
        demotes = OrderedDict(self._pending_demotes)
        runs = OrderedDict(self._pending_runs)
        scalars = (self._tier_swap_s, self._carry_swap_s,
                   self._carry_out, self.now)
        stats = dict(self.swap_stats)
        nlogs = len(self.batch_logs)

        def restore() -> None:
            self.cache = cache
            if pools is not None:
                self.k_pools, self.v_pools = pools
            self.slot_of, self.free_slots = dict(slot_of), list(free_slots)
            self.token_ids = {k: list(v) for k, v in token_ids.items()}
            self.outputs = {k: list(v) for k, v in outputs.items()}
            self._page_keys_of = dict(page_keys)
            self._page_tokens_of = dict(page_tokens)
            self._prefix_skip = dict(skip)
            self._bt_cache = bt_cache
            self._bt_host = bt_host
            self._decode_state = decode_state
            self._pending_swaps = OrderedDict(pending)
            self._pending_demotes = OrderedDict(demotes)
            self._pending_runs = OrderedDict(runs)
            (self._tier_swap_s, self._carry_swap_s,
             self._carry_out, self.now) = scalars
            self.swap_stats = dict(stats)
            del self.batch_logs[nlogs:]

        txn.add(restore)
        return txn

    def _requeue_stragglers(self) -> None:
        """``StragglerMonitor`` flagged the step (measured wall far past
        the cost-model prediction): requeue every running request
        through the scheduler's preemption path so the next batch
        re-plans from a clean slate.  Swap charges are owed to the next
        executed batch, exactly like an empty-admission round."""
        self.recovery_stats[SK.STRAGGLER_REQUEUES] += 1
        for victim in list(self.sched.running):
            self.sched._preempt(victim)
            s, o = self._handle_preempted(victim)
            self._carry_swap_s += s
            self._carry_out += o

    def _handle_preempted(self, victim: Request) -> Tuple[float, int]:
        """Free (or swap out) one full-preemption victim; returns the
        (virtual swap time, swap-out count) owed to the draining
        batch."""
        if victim.suspended:
            m = victim.swap_out_m   # device-resident portion only
            swapper = (self._swap_out_paged if self._pooled
                       else self._swap_out)
            if swapper(victim):      # False: store full, fell back
                return self._swap_time(m), 1
        else:
            if self._pooled:
                self.swap_store.discard_runs(victim.rid)
                self._purge_pending_runs(victim.rid)
            self._release(victim.rid)
        return 0.0, 0

    def _step_attempt(self) -> int:
        """One attempt at the current step (see ``step``)."""
        t0 = time.perf_counter()
        self.allocator.now = self.now   # replacement-policy clock
        batch = self.sched.get_next_batch()
        swap_s = 0.0
        num_swap_out = num_swap_in = 0
        # page-level partial preemptions first: chronologically they
        # precede any later FULL preemption of the same victim, and the
        # tail pages must be snapshotted before the remainder is
        for r, npages, n_tokens, mode in batch.partial_preempted:
            if not r.running:
                # the victim was ALSO fully preempted later this round.
                # A swap-mode shed folds into the full suspend: the
                # full-preempt path below snapshots the WHOLE table
                # (tail included) as one run, so skip the data movement
                # but keep the per-run virtual-time charge — the
                # simulator charges it at shed time too.  A
                # recompute-mode shed must still come OFF the table so
                # the full snapshot (or release) matches the request's
                # reduced bookkeeping (suspended_m excludes it).
                if mode == "swap":
                    swap_s += self._swap_time(n_tokens)
                    num_swap_out += 1
                else:
                    removed = self.allocator.free_tail(r.rid, npages)
                    if self.ecfg.check_invariants:
                        assert removed == n_tokens, (r.rid, removed,
                                                     n_tokens)
                continue
            if self._shed_tail(r, npages, n_tokens, mode):
                swap_s += self._swap_time(n_tokens)
                num_swap_out += 1
        for victim in batch.preempted:
            s, o = self._handle_preempted(victim)
            swap_s += s
            num_swap_out += o
        if not batch.items:
            # swap-outs still happened: owe their virtual-time charge to
            # the next executed batch (mirrors the simulator's carry)
            self._carry_swap_s += swap_s
            self._carry_out += num_swap_out
            self._drain_swaps(before_step=self._step_no)
            self.wall += time.perf_counter() - t0
            return 0
        swap_s += self._carry_swap_s
        num_swap_out += self._carry_out
        self._carry_swap_s, self._carry_out = 0.0, 0

        # swap-ins: restore suspended re-admissions before classification
        # so they re-enter as decodes/short prefills, not full refills;
        # partially shed requests restore their tail runs the same way
        for r, _ in batch.items:
            if r.suspended:
                swap_s += self._swap_time(r.suspended_m)
                num_swap_in += 1
                (self._swap_in_paged if self._pooled else self._swap_in)(r)
            elif r.tail_suspended_m > 0:
                swap_s += self._swap_time(r.tail_suspended_m)
                num_swap_in += 1
                self._swap_in_tail(r)

        # classify + virtual-time the batch up front
        spec = BatchSpec()
        prefill_items: List[Tuple[Request, int]] = []
        decode_items: List[Tuple[Request, int]] = []
        for r, c in batch.items:
            if r.generated > 0 and c == 1 and r.remaining_prefill == 1:
                decode_items.append((r, c))
                spec.decodes.append((c, r.m))
            else:
                prefill_items.append((r, c))
                spec.prefills.append((c, r.m))

        # claim slots + control-plane allocation BEFORE pricing the
        # batch: the prefix attach may PROMOTE host-demoted pages, and
        # any allocation (or CoW remap) may reclaim-and-DEMOTE registry
        # entries — those host-link swap_time charges belong to THIS
        # batch's virtual time, mirroring the simulator shadow
        t_attach = time.perf_counter()
        for r, c in prefill_items:
            if r.rid not in self.slot_of:
                self._claim_slot(r.rid, reset=not self._pooled)
            skip = 0
            if (self._pooled and self.ecfg.prefix_sharing
                    and r.m == 0 and not self.allocator.has(r.rid)):
                skip = self._attach_prefix(r, c)
            if self._pooled:
                self._prefix_skip[r.rid] = skip
            self.allocator.allocate(r.rid, c - skip)
            if self._pooled:
                self._cow_guard(r.rid, r.m + skip)
        self.phase_stats[SK.ATTACH_S] += time.perf_counter() - t_attach
        for r, _ in decode_items:
            self.allocator.allocate(r.rid, 1)
            if self._pooled:
                self._cow_guard(r.rid, r.m)
        swap_s += self._tier_swap_s
        self._tier_swap_s = 0.0

        dt = (self.cost_model.batch_time(spec) if self.cost_model else 0.0) \
            + swap_s
        self.now += dt

        # ---- prefills (one batched bucketed call per round) ------------- #
        if prefill_items:
            runner = {"batched": self._run_prefills_batched,
                      "legacy": self._run_prefills_legacy,
                      "paged": (self._run_prefills_paged if self._pooled
                                else self._run_prefills_batched)}[
                                    self.ecfg.plane]
            t_pf = time.perf_counter()
            final_tok = runner(prefill_items)
            self.phase_stats[SK.PREFILL_S] += time.perf_counter() - t_pf
            for r, c in prefill_items:
                m_new = r.m + c
                generated = r.advance(c, self.now)
                if self._pooled and self.ecfg.prefix_sharing:
                    self._register_prefix(r, m_new)
                if generated:
                    tok = final_tok[r.rid]
                    self.outputs[r.rid].append(tok)
                    if r.finished:
                        self.sched.complete(r)
                        self._release(r.rid)
                    else:
                        self.token_ids[r.rid].append(tok)

        # ---- decodes (one batched fused step over all slots) ------------ #
        if decode_items:
            nslots = self.ecfg.nslots
            if self._pooled:
                host = self._run_decodes_paged(decode_items)
            else:
                toks = np.zeros((nslots,), np.int32)
                mask = np.zeros((nslots,), bool)
                for r, _ in decode_items:
                    slot = self.slot_of[r.rid]
                    toks[slot] = self.token_ids[r.rid][-1]
                    mask[slot] = True
                tok_ids, self.cache = self._decode_many(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(mask))
                host = np.asarray(tok_ids)  # repro: allow-host-sync(per-step sampled-token fetch - ids must reach the host to extend prompts and detect EOS; (nslots,) int32 only)
            for r, c in decode_items:
                slot = self.slot_of[r.rid]
                r.advance(c, self.now)
                tok = int(host[slot])
                self.outputs[r.rid].append(tok)
                if r.finished:
                    self.sched.complete(r)
                    self._release(r.rid)
                else:
                    self.token_ids[r.rid].append(tok)

        # end-of-step boundary: snapshots enqueued in EARLIER steps have
        # had a full step of compute to overlap their D2H copy; finalize
        # them now (this step's own snapshots stay in flight)
        self._drain_swaps(before_step=self._step_no)
        wall_s = time.perf_counter() - t0
        self.wall += wall_s
        self._last_dt, self._last_wall = dt, wall_s   # straggler inputs  # repro: allow-txn-coverage(straggler-monitor inputs describe the attempt that COMMITTED - an aborted attempt never reaches this line)
        if self.ecfg.check_invariants:
            self.allocator.check_invariants()
            self.swap_store.check_invariants()
            self._check_index_sync(batch)
        kv_used = sum(r.m for r in self.sched.running)
        self.batch_logs.append(BatchLog(
            t_start=self.now - dt, t_end=self.now,
            num_prefill=len(spec.prefills), num_decode=len(spec.decodes),
            tokens=spec.total_tokens, kv_used=kv_used,
            preempted=len(batch.preempted) + len(batch.partial_preempted),
            swapped_out=num_swap_out, swapped_in=num_swap_in,
            swap_s=swap_s, wall_s=wall_s,
            pages_used=self.allocator.table_pages))
        return len(batch.items)

    def _check_index_sync(self, batch) -> None:
        if self._pooled:
            # no device index in the pooled plane: the allocator's token
            # count is the position book — it must track r.m exactly
            for r, _ in batch.items:
                if r.finished or r.rid not in self.slot_of:
                    continue
                nt = (self.allocator.table(r.rid).num_tokens
                      if self.allocator.has(r.rid) else 0)
                invariant(nt == r.m, (r.rid, nt, r.m))
            return
        idx = np.asarray(self.cache["index"])  # repro: allow-host-sync(check_invariants-gated debug validation; off in benchmark configurations)
        for r, _ in batch.items:
            if r.finished or r.rid not in self.slot_of:
                continue
            slot = self.slot_of[r.rid]
            invariant(idx[slot] == r.m, (r.rid, idx[slot], r.m))

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Request],
            max_batches: int = 100_000) -> "EngineResult":
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        for _ in range(max_batches):
            while i < len(pending) and pending[i].arrival <= self.now + 1e-12:
                self.submit(pending[i])
                i += 1
            if not self.sched.has_work():
                if i >= len(pending):
                    break
                self.now = pending[i].arrival
                continue
            executed = self.step()
            if executed == 0:
                if i < len(pending):     # blocked until the next arrival
                    self.now = max(self.now, pending[i].arrival)
                    continue
                raise RuntimeError(
                    "engine deadlock: work remains but nothing schedulable")
        else:
            raise RuntimeError("engine did not converge")
        self._drain_swaps()
        if self.ecfg.check_invariants:
            assert not self._pending_swaps
            assert not self._pending_demotes
            assert not self._pending_runs
            assert len(self.swap_store) == 0, \
                f"swap store leaked rids {self.swap_store.suspended_rids}"
        sim = SimResult(requests=list(requests), batches=self.batch_logs,
                        num_preemptions=self.sched.num_preemptions,
                        num_partial_preempts=self.sched.num_partial_preempts,
                        num_swaps=self.sched.num_swaps)
        return EngineResult(outputs=dict(self.outputs), metrics=sim,
                            wall_time=self.wall,
                            swap_stats=dict(self.swap_stats),
                            num_compiles=self.num_compiles,
                            recovery_stats=dict(self.recovery_stats),
                            phase_stats=dict(self.phase_stats))


@dataclass
class EngineResult:
    outputs: Dict[int, List[int]]
    metrics: SimResult
    wall_time: float
    swap_stats: Dict[str, float] = field(default_factory=dict)
    num_compiles: int = 0
    recovery_stats: Dict[str, float] = field(default_factory=dict)
    # wall-clock attribution of the pooled step (attach_s / prefill_s /
    # upload_s) — the fig_prefix_sharing phase columns
    phase_stats: Dict[str, float] = field(default_factory=dict)


# --------------------------------------------------------------------- #
# reference generation (no scheduler) — the parity oracle
# --------------------------------------------------------------------- #


@functools.lru_cache(maxsize=64)
def _reference_decode_fn(cfg: ModelConfig, impl: str, moe_impl: str):
    """Jitted (params, cur (1,), cache) -> (next token (1,), cache) with
    fused greedy sampling; cached per (cfg, impl, moe_impl) so repeated
    parity-oracle calls stop paying an uncompiled decode per token."""

    def step(params, cur, cache):
        logits, cache = M.decode_step(cfg, params, cur, cache,
                                      impl=impl, moe_impl=moe_impl)
        nxt = jnp.argmax(logits[:, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
        return nxt, cache

    return jax.jit(step)


def generate_reference(cfg: ModelConfig, params: Any, prompt: Sequence[int],
                       num_tokens: int, *, cache_len: int,
                       impl: str = "reference",
                       moe_impl: str = "dense") -> List[int]:
    """Greedy generation of one request, full prefill + sequential decode.
    The decode loop is jitted (one compile per (cfg, cache shape), reused
    across calls) and samples on device — only token ids reach the host."""
    toks = jnp.asarray([list(prompt)], jnp.int32)
    logits, cache = M.prefill(cfg, params, {"tokens": toks},
                              cache_len=cache_len, impl=impl,
                              moe_impl=moe_impl)
    out: List[int] = []
    cur = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    out.append(int(cur[0]))
    decode = _reference_decode_fn(cfg, impl, moe_impl)
    for _ in range(num_tokens - 1):
        cur, cache = decode(params, cur, cache)
        out.append(int(cur[0]))
    return out
