"""Continuous-batching serving engine — REAL JAX execution of the paper's
schedules (the "deployment" path of Fig. 1; the simulator is the blue
path).

The engine drives the unified ``Scheduler`` (Algorithm 1) against an
actual model: chunked prefill via ``model.prefill_chunk`` per request,
one *batched* decode step over all active slots per batch.  Token-level
memory accounting (the scheduler's M) is backed by a ``PagedAllocator``;
the data plane stores each request in a contiguous cache slot (on TPU,
dynamic-slice slots are the idiomatic layout — pointer-chasing page
tables are a CUDA idiom; see DESIGN.md).

Preemption supports BOTH §5.4 restoration paths, selected by
``SchedulerConfig.preempt_mode``:

* ``recompute`` — the victim's slot is freed and its KVs discarded; on
  re-admission it pays a full refill prefill (the §3 refill).
* ``swap`` — the victim's slot slice (every cache leaf, including the
  position index and recurrent SSM state) is snapshotted to a host-side
  ``KVSwapStore``; on re-admission the snapshot is written back into a
  free slot and generation continues where it stopped —
  ``Request.remaining_prefill`` sees the restored KVs, so no refill runs.
  If the store's ``EngineConfig.swap_bytes`` capacity is exhausted the
  victim falls back to discard-and-recompute for that preemption.
* ``auto`` — per-victim Fig. 8 decision via the cost model
  (``swap_time`` vs ``kv_projection_time``/``recompute_time``).

Virtual time charges ``cost_model.swap_time`` for each swap-out and
swap-in, mirroring the simulator, so simulated and engine schedules
agree.  Measured wall times of the host transfers are tracked in
``Engine.swap_stats`` (the fig08 validation column).

Correctness contract (tested): scheduling, chunking, batching and
preemption — under recompute, swap, AND auto — NEVER change the
generated tokens, exactly the paper's "standard inference optimization
techniques that do not affect inference outputs".
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import BatchSpec, CostModel
from repro.core.kvcache import PagedAllocator
from repro.core.request import Request
from repro.core.scheduler import Scheduler
from repro.core.simulator import BatchLog, SimResult
from repro.models import model as M
from repro.serving.swap_store import KVSwapStore, SwapStoreFullError


@dataclass
class EngineConfig:
    nslots: int = 8
    cache_len: int = 256          # per-slot context capacity (tokens)
    chunk: int = 64               # chunked-prefill chunk size
    page_size: int = 1            # allocator granularity (1 = token-exact,
    #                               matching the scheduler's M accounting)
    impl: str = "reference"       # attention backend
    moe_impl: str = "dense"       # chunk-invariant dispatch for parity
    swap_bytes: Optional[int] = None   # host swap-store capacity (None =
    #                                    unbounded); a full store makes the
    #                                    victim fall back to recompute
    check_invariants: bool = True


def _slot_axis(leaf: jnp.ndarray) -> int:
    """Cache leaves are (L, B, ...) except index (B,)."""
    return 0 if leaf.ndim == 1 else 1


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, scheduler: Scheduler,
                 ecfg: Optional[EngineConfig] = None,
                 cost_model: Optional[CostModel] = None):
        # copy the config: a shared default (or caller-reused) instance
        # must not be mutated by the per-model chunk clamp below
        ecfg = replace(ecfg) if ecfg is not None else EngineConfig()
        if cfg.window:
            ecfg.chunk = min(ecfg.chunk, cfg.window)
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.sched = scheduler
        self.cost_model = cost_model
        if scheduler.cost_model is None:
            scheduler.cost_model = cost_model   # auto preempt-mode pricing
        scheduler.cfg.max_running = ecfg.nslots
        # init_cache caps the per-slot KV length at cfg.window internally
        self.cache = M.init_cache(cfg, ecfg.nslots, ecfg.cache_len)
        self.allocator = PagedAllocator(
            num_pages=max(1, scheduler.cfg.M // ecfg.page_size),
            page_size=ecfg.page_size)
        self.free_slots: List[int] = list(range(ecfg.nslots - 1, -1, -1))
        self.slot_of: Dict[int, int] = {}
        self.token_ids: Dict[int, List[int]] = {}
        self.outputs: Dict[int, List[int]] = {}
        self.swap_store = KVSwapStore(capacity_bytes=ecfg.swap_bytes)
        # measured host-transfer wall times (fig08 validation column)
        self.swap_stats: Dict[str, float] = dict(
            swap_outs=0, swap_ins=0, kv_out=0, kv_in=0, swap_fallbacks=0,
            wall_out_s=0.0, wall_in_s=0.0)
        # swap-out virtual-time charges from rounds that admitted no
        # items, owed to the next executed batch (mirrors the simulator)
        self._carry_swap_s = 0.0
        self._carry_out = 0
        self.now = 0.0
        self.wall = 0.0
        self.batch_logs: List[BatchLog] = []
        self._build_jits()

    # ------------------------------------------------------------------ #
    def _build_jits(self) -> None:
        cfg, ecfg = self.cfg, self.ecfg

        def slot_slice(cache, slot):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1,
                                                       _slot_axis(a)), cache)

        def slot_write(cache, upd, slot):
            return jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_slice_in_dim(
                    a, u, slot, _slot_axis(a)), cache, upd)

        def prefill_one(params, cache, slot, tokens):
            sl = slot_slice(cache, slot)
            logits, new_sl = M.prefill_chunk(cfg, params, tokens, sl,
                                             impl=ecfg.impl,
                                             moe_impl=ecfg.moe_impl)
            return logits[0], slot_write(cache, new_sl, slot)

        def decode_all(params, cache, tokens, mask):
            logits, new_cache = M.decode_step(cfg, params, tokens, cache,
                                              impl=ecfg.impl,
                                              moe_impl=ecfg.moe_impl)

            def merge(new, old):
                ax = _slot_axis(new)
                m = mask.reshape((1,) * ax + (-1,) + (1,) * (new.ndim - ax - 1))
                return jnp.where(m, new, old)

            return logits, jax.tree.map(merge, new_cache, cache)

        def reset_slot(cache, slot):
            zeroed = jax.tree.map(
                lambda a: jnp.zeros_like(
                    jax.lax.dynamic_slice_in_dim(a, slot, 1, _slot_axis(a))),
                cache)
            return slot_write(cache, zeroed, slot)

        self._prefill_one = jax.jit(prefill_one)
        self._decode_all = jax.jit(decode_all)
        self._reset_slot = jax.jit(reset_slot)
        # swap data plane: slot snapshot (device->host via device_get on
        # the sliced result) and slot restore (host->device write)
        self._slot_slice = jax.jit(slot_slice)
        self._slot_write = jax.jit(slot_write)

    # ------------------------------------------------------------------ #
    def submit(self, r: Request) -> None:
        assert r.prompt is not None, "engine requests need real token ids"
        assert len(r.prompt) == r.input_len
        # window/ssm archs hold bounded state; dense caches must fit
        assert self.cfg.window or self.cfg.family == "ssm" \
            or r.peak_kv <= self.ecfg.cache_len, \
            f"request {r.rid} peak KV {r.peak_kv} > cache_len"
        self.token_ids[r.rid] = list(r.prompt)
        self.outputs[r.rid] = []
        self.sched.add_request(r)

    # ------------------------------------------------------------------ #
    def _claim_slot(self, rid: int, reset: bool = True) -> int:
        slot = self.free_slots.pop()
        self.slot_of[rid] = slot
        if reset:
            self.cache = self._reset_slot(self.cache, slot)
        return slot

    def _release(self, rid: int) -> None:
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self.free_slots.append(slot)
        self.allocator.free(rid)
        # refill restarts from scratch: drop generated tokens beyond prompt?
        # NO — generated tokens are kept and re-prefilled (paper §3 refill).

    # --- §5.4 swap data plane ------------------------------------------ #
    def _swap_out(self, victim: Request) -> bool:
        """Snapshot the victim's slot to the host store, then free it.
        Returns False when the store is full: the snapshot is dropped and
        the victim falls back to discard-and-recompute (finite host
        memory is the five-minute-rule's operating constraint)."""
        t0 = time.perf_counter()
        slot = self.slot_of[victim.rid]
        snap = jax.device_get(self._slot_slice(self.cache, jnp.int32(slot)))
        try:
            self.swap_store.put(victim.rid, snap, self.token_ids[victim.rid],
                                victim.suspended_m)
        except SwapStoreFullError:
            victim.drop_suspended()
            self.sched.num_swaps -= 1   # the suspend did not stick
            self.swap_stats["swap_fallbacks"] += 1
            self._release(victim.rid)
            return False
        if self.ecfg.check_invariants:
            assert int(np.asarray(snap["index"])[0]) == victim.suspended_m, \
                (victim.rid, snap["index"], victim.suspended_m)
        self.swap_stats["swap_outs"] += 1
        self.swap_stats["kv_out"] += victim.suspended_m
        self.swap_stats["wall_out_s"] += time.perf_counter() - t0
        self._release(victim.rid)
        return True

    def _swap_in(self, r: Request) -> None:
        """Restore r's snapshot into a free slot; no refill is needed."""
        t0 = time.perf_counter()
        entry = self.swap_store.pop(r.rid)
        slot = self._claim_slot(r.rid, reset=False)  # fully overwritten
        upd = jax.tree.map(jnp.asarray, entry.cache)
        self.cache = self._slot_write(self.cache, upd, jnp.int32(slot))
        jax.block_until_ready(self.cache["index"])
        self.allocator.allocate(r.rid, entry.num_kv)
        restored = r.resume()
        if self.ecfg.check_invariants:
            assert restored == entry.num_kv, (r.rid, restored, entry.num_kv)
            assert self.token_ids[r.rid] == entry.tokens, r.rid
        self.swap_stats["swap_ins"] += 1
        self.swap_stats["kv_in"] += entry.num_kv
        self.swap_stats["wall_in_s"] += time.perf_counter() - t0

    def _swap_time(self, n_kvs: int) -> float:
        return self.cost_model.swap_time(n_kvs) if self.cost_model else 0.0

    def _sample(self, logits: jnp.ndarray) -> int:
        """Greedy over the REAL vocabulary (padding logits excluded)."""
        return int(jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1))

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """Run one scheduler batch. Returns the number of items executed."""
        if not self.sched.has_work():
            return 0
        t0 = time.perf_counter()
        batch = self.sched.get_next_batch()
        swap_s = 0.0
        num_swap_out = num_swap_in = 0
        for victim in batch.preempted:
            if victim.suspended:
                m = victim.suspended_m
                if self._swap_out(victim):   # False: store full, fell back
                    swap_s += self._swap_time(m)
                    num_swap_out += 1
            else:
                self._release(victim.rid)
        if not batch.items:
            # swap-outs still happened: owe their virtual-time charge to
            # the next executed batch (mirrors the simulator's carry)
            self._carry_swap_s += swap_s
            self._carry_out += num_swap_out
            self.wall += time.perf_counter() - t0
            return 0
        swap_s += self._carry_swap_s
        num_swap_out += self._carry_out
        self._carry_swap_s, self._carry_out = 0.0, 0

        # swap-ins: restore suspended re-admissions before classification
        # so they re-enter as decodes/short prefills, not full refills
        for r, _ in batch.items:
            if r.suspended:
                swap_s += self._swap_time(r.suspended_m)
                num_swap_in += 1
                self._swap_in(r)

        # classify + virtual-time the batch up front
        spec = BatchSpec()
        prefill_items: List[Tuple[Request, int]] = []
        decode_items: List[Tuple[Request, int]] = []
        for r, c in batch.items:
            if r.generated > 0 and c == 1 and r.remaining_prefill == 1:
                decode_items.append((r, c))
                spec.decodes.append((c, r.m))
            else:
                prefill_items.append((r, c))
                spec.prefills.append((c, r.m))
        dt = (self.cost_model.batch_time(spec) if self.cost_model else 0.0) \
            + swap_s
        self.now += dt

        # ---- prefills (per request, chunked) --------------------------- #
        for r, c in prefill_items:
            if r.rid not in self.slot_of:
                self._claim_slot(r.rid)
            self.allocator.allocate(r.rid, c)
            slot = self.slot_of[r.rid]
            ids = self.token_ids[r.rid]
            start, remaining = r.m, c
            logits = None
            while remaining > 0:
                step_c = min(self.ecfg.chunk, remaining)
                toks = jnp.asarray([ids[start:start + step_c]], jnp.int32)
                logits, self.cache = self._prefill_one(
                    self.params, self.cache, jnp.int32(slot), toks)
                start += step_c
                remaining -= step_c
            generated = r.advance(c, self.now)
            if generated:
                tok = self._sample(logits)
                self.outputs[r.rid].append(tok)
                if r.finished:
                    self.sched.complete(r)
                    self._release(r.rid)
                else:
                    self.token_ids[r.rid].append(tok)

        # ---- decodes (one batched step over all slots) ------------------ #
        if decode_items:
            nslots = self.ecfg.nslots
            toks = np.zeros((nslots,), np.int32)
            mask = np.zeros((nslots,), bool)
            for r, _ in decode_items:
                slot = self.slot_of[r.rid]
                toks[slot] = self.token_ids[r.rid][-1]
                mask[slot] = True
                self.allocator.allocate(r.rid, 1)
            logits, self.cache = self._decode_all(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(mask))
            logits = np.asarray(logits[..., :self.cfg.vocab_size])
            for r, c in decode_items:
                slot = self.slot_of[r.rid]
                r.advance(c, self.now)
                tok = int(np.argmax(logits[slot]))
                self.outputs[r.rid].append(tok)
                if r.finished:
                    self.sched.complete(r)
                    self._release(r.rid)
                else:
                    self.token_ids[r.rid].append(tok)

        self.wall += time.perf_counter() - t0
        if self.ecfg.check_invariants:
            self.allocator.check_invariants()
            self.swap_store.check_invariants()
            self._check_index_sync(batch)
        kv_used = sum(r.m for r in self.sched.running)
        self.batch_logs.append(BatchLog(
            t_start=self.now - dt, t_end=self.now,
            num_prefill=len(spec.prefills), num_decode=len(spec.decodes),
            tokens=spec.total_tokens, kv_used=kv_used,
            preempted=len(batch.preempted),
            swapped_out=num_swap_out, swapped_in=num_swap_in,
            swap_s=swap_s))
        return len(batch.items)

    def _check_index_sync(self, batch) -> None:
        idx = np.asarray(self.cache["index"])
        for r, _ in batch.items:
            if r.finished or r.rid not in self.slot_of:
                continue
            slot = self.slot_of[r.rid]
            assert idx[slot] == r.m, (r.rid, idx[slot], r.m)

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Request],
            max_batches: int = 100_000) -> "EngineResult":
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        for _ in range(max_batches):
            while i < len(pending) and pending[i].arrival <= self.now + 1e-12:
                self.submit(pending[i])
                i += 1
            if not self.sched.has_work():
                if i >= len(pending):
                    break
                self.now = pending[i].arrival
                continue
            executed = self.step()
            if executed == 0:
                if i < len(pending):     # blocked until the next arrival
                    self.now = max(self.now, pending[i].arrival)
                    continue
                raise RuntimeError(
                    "engine deadlock: work remains but nothing schedulable")
        else:
            raise RuntimeError("engine did not converge")
        if self.ecfg.check_invariants:
            assert len(self.swap_store) == 0, \
                f"swap store leaked rids {self.swap_store.suspended_rids}"
        sim = SimResult(requests=list(requests), batches=self.batch_logs,
                        num_preemptions=self.sched.num_preemptions,
                        num_swaps=self.sched.num_swaps)
        return EngineResult(outputs=dict(self.outputs), metrics=sim,
                            wall_time=self.wall,
                            swap_stats=dict(self.swap_stats))


@dataclass
class EngineResult:
    outputs: Dict[int, List[int]]
    metrics: SimResult
    wall_time: float
    swap_stats: Dict[str, float] = field(default_factory=dict)


# --------------------------------------------------------------------- #
# reference generation (no scheduler) — the parity oracle
# --------------------------------------------------------------------- #

def generate_reference(cfg: ModelConfig, params: Any, prompt: Sequence[int],
                       num_tokens: int, *, cache_len: int,
                       impl: str = "reference",
                       moe_impl: str = "dense") -> List[int]:
    """Greedy generation of one request, full prefill + sequential decode."""
    toks = jnp.asarray([list(prompt)], jnp.int32)
    logits, cache = M.prefill(cfg, params, {"tokens": toks},
                              cache_len=cache_len, impl=impl,
                              moe_impl=moe_impl)
    out: List[int] = []
    cur = int(jnp.argmax(logits[0, :cfg.vocab_size]))
    out.append(cur)
    for _ in range(num_tokens - 1):
        logits, cache = M.decode_step(cfg, params, jnp.asarray([cur]), cache,
                                      impl=impl, moe_impl=moe_impl)
        cur = int(jnp.argmax(logits[0, :cfg.vocab_size]))
        out.append(cur)
    return out
