"""Pooled-KV model steps for the engine's ``plane="paged"`` (PR 4).

Attention KV lives in shared per-layer page pools ``(num_pages,
page_size, Hkv, D)`` instead of per-slot contiguous buffers; a request's
pages are named by the ``PagedAllocator`` block table, threaded in as a
``(B, max_pages)`` int32 array.  Both steps are shape-stable (fixed pool
/ table / grid shapes; prefill tokens padded to the engine's bucket
ladder with a per-row ``lengths`` mask), so the paged plane keeps the
batched plane's constant-compile-count property.

* ``prefill``: the chunk's K/V are projected, then ONE fused op —
  ``kernels.paged_attention.ops.paged_prefill`` — writes the chunk's
  rows through the block table into the pools (padded rows route out of
  bounds and drop — pool bytes of other requests are untouchable by
  construction) and attends causally over [own pages ++ the chunk].
  On TPU that is the Pallas gather-write-attend kernel streaming owned
  pages through a flash reduction; on CPU a jnp gather oracle with the
  dense plane's exact reduction order (bit parity preserved).
* ``decode``: the new token's K/V row is scattered into its page, then
  attention runs via ``kernels.paged_attention.ops.paged_decode`` — the
  Pallas flash-decoding kernel over scalar-prefetched block tables on
  TPU, a jnp block-table gather on CPU.

Only unbounded dense-attention families are pooled: sliding-window and
SSM/RWKV state is O(1) per request, so the engine keeps it slot-resident
(paging a bounded ring buys nothing and recurrent state cannot be
partially evicted anyway — there is no "tail" to shed).

The pools ARE the persistent memory layout — which is what makes
page-level partial preemption, refcounted shared-prefix pages, and the
prefix cache's host demotion tier possible upstream: a demoted registry
page is snapshotted straight out of these pools before eviction and
scattered back into a freshly promoted page on the next registry hit
(the engine's ``_snapshot_pages`` / ``_restore_pages`` on pool slices).
Both paths read/write pages in place on TPU (the Pallas kernels DMA
exactly the owned pages; prefill updates pools via
``input_output_aliases``), so device residency is ``num_pages`` for the
pools' persistent bytes plus chunk-sized activations — the old
per-bucket ``(B, max_pages*page, Hkv, D)`` gather transient is gone
from the kernel path (the CPU oracle still materializes it; parity
matters more than speed off-accelerator).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import ops as pa_ops
from repro.kernels.paged_attention.ref import scatter_rows as _scatter_rows
from repro.models import attention as attn
from repro.models import model as M
from repro.models.common import rms_norm


def paged_supported(cfg: ModelConfig) -> bool:
    """True iff the family's attention KV is unbounded dense (the only
    state worth paging)."""
    return (cfg.num_heads > 0 and not cfg.window
            and cfg.family not in ("ssm", "hybrid"))


def _attn_paged_chunk(lp: Any, cfg: ModelConfig, h: jnp.ndarray,
                      k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                      starts: jnp.ndarray, lengths: jnp.ndarray,
                      block_tables: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked prefill attention against pooled KV.  h (B, c, d); pools
    (P, page, Hkv, D); starts/lengths (B,); block_tables (B, maxp).
    Returns (attn out (B, c, q_dim-projected), new pools)."""
    B, c, _ = h.shape
    positions = starts[:, None] + jnp.arange(c)[None, :]        # (B, c)
    q, k, v = attn._project_qkv(lp, cfg, h, positions)
    out, new_k, new_v = pa_ops.paged_prefill(
        q, k, v, k_pool, v_pool, block_tables, starts, lengths)
    out = out.reshape(B, c, cfg.q_dim) @ lp["wo"]
    return out, new_k, new_v


def _attn_paged_decode(lp: Any, cfg: ModelConfig, h: jnp.ndarray,
                       k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                       ctx: jnp.ndarray, block_tables: jnp.ndarray,
                       active: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against pooled KV.  h (B, 1, d); ctx (B,) valid
    context (also the new token's position); active (B,) row mask."""
    B = h.shape[0]
    P, pg = k_pool.shape[0], k_pool.shape[1]
    maxp = block_tables.shape[1]
    positions = ctx[:, None]
    q, k, v = attn._project_qkv(lp, cfg, h, positions)
    page_idx = jnp.take_along_axis(
        block_tables, jnp.clip(positions // pg, 0, maxp - 1), axis=1)[:, 0]
    dest = jnp.where(active, page_idx * pg + ctx % pg, P * pg)
    new_k = _scatter_rows(k_pool, dest, k[:, 0])
    new_v = _scatter_rows(v_pool, dest, v[:, 0])
    # write-then-attend: context_lens = ctx + 1 includes the new token
    out = pa_ops.paged_decode(q[:, 0], new_k, new_v, block_tables, ctx + 1)
    out = out.reshape(B, cfg.q_dim) @ lp["wo"]
    return out[:, None, :], new_k, new_v


def build_paged_fns(cfg: ModelConfig, *, impl: str = "reference",
                    moe_impl: str = "dense"
                    ) -> Tuple[Callable, Callable]:
    """Returns jit-ready ``(prefill_fn, decode_fn)`` over pooled KV.

    prefill_fn(params, k_pools, v_pools, tokens (B, bucket),
               starts (B,), lengths (B,), block_tables (B, maxp))
        -> (greedy ids (B,), new_k_pools, new_v_pools)
    decode_fn(params, k_pools, v_pools, tokens (B,), ctx (B,),
              block_tables (B, maxp), active (B,))
        -> (greedy ids (B,), new_k_pools, new_v_pools)

    Pools are stacked over layers: (L, P, page, Hkv, D).  Sampling is
    fused (argmax over the real vocabulary on device); ``impl`` selects
    only the decode backend via ``ops.paged_decode``'s dispatch — the
    prefill backend is chosen by ``ops.paged_prefill`` itself.
    """
    if not paged_supported(cfg):
        raise ValueError("paged pools need unbounded dense attention, "
                         f"got {cfg.family!r}")
    vocab = cfg.vocab_size

    def _block(lp, x, attn_out):
        x = x + attn_out
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + M._mlp_or_moe(cfg, lp, h2, moe_impl)

    def prefill_fn(params, k_pools, v_pools, tokens, starts, lengths,
                   block_tables):
        B, c = tokens.shape
        positions = starts[:, None] + jnp.arange(c)[None, :]
        x, _ = M._embed(cfg, params, tokens, positions, None)

        def body(xc, per_layer):
            lp, (kp, vp) = per_layer
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            a, kp, vp = _attn_paged_chunk(lp["attn"], cfg, h, kp, vp,
                                          starts, lengths, block_tables)
            return _block(lp, xc, a), (kp, vp)

        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["layers"], (k_pools, v_pools)))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        last = jnp.maximum(lengths - 1, 0)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        logits = M._logits(cfg, params, x_last)
        toks = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
        return toks, new_k, new_v

    def decode_fn(params, k_pools, v_pools, tokens, ctx, block_tables,
                  active):
        if tokens.ndim == 1:
            tokens = tokens[:, None]
        x, _ = M._embed(cfg, params, tokens, ctx[:, None], None)

        def body(xc, per_layer):
            lp, (kp, vp) = per_layer
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            a, kp, vp = _attn_paged_decode(lp["attn"], cfg, h, kp, vp,
                                           ctx, block_tables, active)
            return _block(lp, xc, a), (kp, vp)

        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["layers"], (k_pools, v_pools)))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = M._logits(cfg, params, x[:, 0])
        toks = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
        return toks, new_k, new_v

    return prefill_fn, decode_fn
