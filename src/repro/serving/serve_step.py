"""Jitted serve-step builders (used by the engine, examples and the
multi-pod dry-run).

``build_prefill_fn`` / ``build_decode_fn`` return pure functions of
(params, batch/cache) with STATIC shapes, suitable for
``jax.jit(...).lower(...).compile()`` against ShapeDtypeStruct inputs.

``serve_input_specs`` produces the ShapeDtypeStruct stand-ins for every
input of the given (arch x shape) cell — weak-type-correct, shardable,
no device allocation (assignment step 2 of the MULTI-POD DRY-RUN).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M


def build_prefill_fn(cfg: ModelConfig, *, cache_len: int,
                     impl: str = "reference", moe_impl: str = "sparse",
                     unroll: bool = False) -> Callable:
    """(params, batch) -> (last-token logits, KV cache)."""

    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, cache_len=cache_len,
                         impl=impl, moe_impl=moe_impl, unroll=unroll)

    return prefill_step


def build_prefill_chunk_fn(cfg: ModelConfig, *, impl: str = "reference",
                           moe_impl: str = "sparse",
                           unroll: bool = False) -> Callable:
    """(params, tokens (B, bucket), cache, lengths (B,)) -> (logits, cache).

    The shape-stable bucketed chunk step of the engine's batched
    execution plane: tokens are padded to a fixed bucket length and
    ``lengths`` marks each row's real prefix (0 = inert row), so one
    compiled signature per bucket serves every chunk size."""

    def chunk_step(params, tokens, cache, lengths):
        return M.prefill_chunk(cfg, params, tokens, cache, impl=impl,
                               moe_impl=moe_impl, unroll=unroll,
                               length=lengths)

    return chunk_step


def build_decode_fn(cfg: ModelConfig, *, impl: str = "reference",
                    moe_impl: str = "sparse", unroll: bool = False,
                    append: str = "inline") -> Callable:
    """(params, tokens, cache) -> (logits, cache) — one serve_step.
    append='deferred' uses the once-per-step cache scatter (§Perf)."""
    step = (M.decode_step_deferred if append == "deferred"
            else M.decode_step)

    def serve_step(params, tokens, cache):
        return step(cfg, params, tokens, cache,
                    impl=impl, moe_impl=moe_impl, unroll=unroll)

    return serve_step


def build_train_fn(cfg: ModelConfig, *, impl: str = "reference",
                   moe_impl: str = "sparse", remat: bool = True,
                   unroll: bool = False) -> Callable:
    """(params, batch) -> scalar loss (grad-able train objective)."""

    def loss_fn(params, batch):
        return M.train_loss(cfg, params, batch, impl=impl,
                            moe_impl=moe_impl, remat=remat, unroll=unroll)

    return loss_fn


# --------------------------------------------------------------------- #
# ShapeDtypeStruct stand-ins
# --------------------------------------------------------------------- #


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def param_specs(cfg: ModelConfig) -> Any:
    """Parameter pytree as ShapeDtypeStructs (eval_shape over init)."""
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Any:
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, cache_len))


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig
                      ) -> Dict[str, Any]:
    """All inputs of the cell's entry point, as ShapeDtypeStructs.

    train  -> {tokens, labels[, patch_embeds]}
    prefill-> {tokens[, patch_embeds]}
    decode -> {tokens (B,), cache}  (one new token against seq_len KVs)
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs: Dict[str, Any] = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        if cfg.frontend == "patch":
            specs["patch_embeds"] = _sds((B, cfg.num_patches, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.frontend == "patch":
            specs["patch_embeds"] = _sds((B, cfg.num_patches, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
        return specs
    if shape.kind == "decode":
        return {
            "tokens": _sds((B,), jnp.int32),
            "cache": cache_specs(cfg, B, S),
        }
    raise ValueError(shape.kind)
