"""Serving layer: continuous-batching engine + jitted serve steps."""
from repro.serving.engine import (  # noqa: F401
    Engine,
    EngineConfig,
    EngineResult,
    generate_reference,
)
from repro.serving.swap_store import (  # noqa: F401
    KVSwapStore,
    PageRunEntry,
    SwapEntry,
    SwapStoreFullError,
)
from repro.serving.serve_step import (  # noqa: F401
    build_decode_fn,
    build_prefill_chunk_fn,
    build_prefill_fn,
    build_train_fn,
    cache_specs,
    param_specs,
    serve_input_specs,
)
