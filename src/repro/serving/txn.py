"""Step-scoped transactions for the serving control plane.

The engine's batch loop mutates four coupled state machines per step —
the :class:`PagedAllocator` (tables, refcounts, prefix registry), the
:class:`KVSwapStore` (byte-accounted host snapshots), the
:class:`Scheduler` (queues, counters, histogram), and every
:class:`Request`'s own state machine — plus engine-local slot/output
maps.  A failure between claim/attach/CoW and pricing used to leak
pages and strand registry entries; a :class:`StepTxn` makes the step
atomic: snapshot everything at batch start, and on a mid-step fault
restore every participant to exactly that point, so the retried (or
degraded) step starts from a state where ``check_invariants`` holds.

Snapshots are cheap by construction:

* Device KV (the batched slot cache, the paged per-layer pools) needs
  **no** copying — JAX arrays are immutable, so saving the *references*
  (``engine.cache`` / ``engine.k_pools`` / ``engine.v_pools``) and
  restoring them rolls back every in-step scatter.  The engine does
  this itself; this module covers the Python-side state.
* Python state is snapshotted one-to-two container levels deep:
  request/entry *objects* are shared by reference (their mutable
  fields are captured separately), inner tuples are immutable.
* Replacement policies and the histogram are captured generically via
  :func:`copy_state` (container attributes copied, leaf objects like
  the policy's ``cost_model`` shared by reference).

The simulator's shadow (``core.simulator``) reuses these functions —
lazily imported there — so engine and simulator roll back through the
same code and stay in parity batch-for-batch under injected faults.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

from repro.core.kvcache import BlockTable, PagedAllocator
from repro.core.request import Request

# Request fields mutated by the state machine mid-step.  ``token_times``
# is the one mutable container; everything else is a scalar.
_REQUEST_FIELDS = (
    "m", "generated", "running", "preemptions", "suspended",
    "suspended_m", "swaps", "tail_suspended_m", "partial_preemptions",
    "swap_out_m", "first_token_time", "finish_time", "predicted_output",
)


def _copy_val(v: Any, depth: int = 2) -> Any:
    """Copy dict/list/set containers up to ``depth`` levels; share
    everything else (objects, tuples, scalars) by reference."""
    if isinstance(v, dict):
        if depth <= 1:
            return v.copy()            # preserves OrderedDict order/type
        out = v.copy()
        for k, x in out.items():
            out[k] = _copy_val(x, depth - 1)
        return out
    if isinstance(v, list):
        return [_copy_val(x, depth - 1) for x in v] if depth > 1 \
            else list(v)
    if isinstance(v, set):
        return set(v)
    return v


def copy_state(obj: Any) -> Dict[str, Any]:
    """Generic ``__dict__`` snapshot (containers copied two levels
    deep, leaves shared).  Suits the replacement policies (whose only
    mutable state is dicts of scalars/tuples) and the histogram."""
    return {k: _copy_val(v) for k, v in obj.__dict__.items()}


def restore_state(obj: Any, snap: Dict[str, Any]) -> None:
    obj.__dict__.clear()
    obj.__dict__.update(snap)


# --------------------------------------------------------------------- #
# participant snapshots
# --------------------------------------------------------------------- #

def snapshot_allocator(alloc: PagedAllocator) -> Callable[[], None]:
    """Capture the allocator (tables, free list, refcounts, pins,
    virtual clock, stats) *and* its radix-trie prefix registry +
    replacement policy.

    The trie is a snapshot participant in its own right
    (``RadixPrefixRegistry.snapshot_state``): a rolled-back step undoes
    node inserts, splits, merges, and tail evictions structurally.
    Node REFCOUNTS need no capture — they are derived from the
    allocator's page refcounts, which this snapshot already restores,
    so structure and leases can never roll back out of sync."""
    free = list(alloc._free)
    tables = {rid: BlockTable(list(t.pages), t.num_tokens)
              for rid, t in alloc._tables.items()}
    refs = dict(alloc._refs)
    pinned = set(alloc._pinned)
    now, version = alloc.now, alloc.version
    dirty = set(alloc.dirty)
    stats = dict(alloc.stats)
    pc = alloc.prefix_cache
    pc_state = pc.snapshot_state()
    policy_state = copy_state(pc.policy)

    def restore() -> None:
        alloc._free = list(free)
        alloc._tables = {rid: BlockTable(list(t.pages), t.num_tokens)
                         for rid, t in tables.items()}
        alloc._refs = dict(refs)
        alloc._pinned = set(pinned)
        alloc.now, alloc.version = now, version
        alloc.dirty = set(dirty)
        alloc.stats = dict(stats)
        pc.restore_state(pc_state)
        restore_state(pc.policy, {k: _copy_val(v)
                                  for k, v in policy_state.items()})
    return restore


def snapshot_store(store: Any) -> Callable[[], None]:
    """Capture the swap store's entry maps and byte accounting.

    Entry *objects* are shared by reference: post-rollback in-place
    mutations on pre-existing entries (async-drain materialization,
    CRC sealing, the idempotent corruption flip) are convergent by
    design — see ``swap_store.seal_entry``."""
    entries = dict(store._entries)
    runs = {rid: list(rs) for rid, rs in store._runs.items()}
    prefixes = dict(store._prefixes)
    nbytes = store._nbytes

    def restore() -> None:
        store._entries = dict(entries)
        store._runs = {rid: list(rs) for rid, rs in runs.items()}
        store._prefixes = dict(prefixes)
        store._nbytes = nbytes
    return restore


def snapshot_scheduler(sched: Any) -> Callable[[], None]:
    waiting, running = list(sched.waiting), list(sched.running)
    counters = (sched.num_preemptions, sched.num_partial_preempts,
                sched.num_swaps, sched.num_batches)
    hist = copy_state(sched.histogram) if sched.histogram is not None \
        else None

    def restore() -> None:
        sched.waiting, sched.running = list(waiting), list(running)
        (sched.num_preemptions, sched.num_partial_preempts,
         sched.num_swaps, sched.num_batches) = counters
        if hist is not None:
            restore_state(sched.histogram,
                          {k: _copy_val(v) for k, v in hist.items()})
    return restore


def snapshot_requests(requests: List[Request]) -> Callable[[], None]:
    saved = [(r, {f: getattr(r, f) for f in _REQUEST_FIELDS},
              list(r.token_times)) for r in requests]

    def restore() -> None:
        for r, fields, times in saved:
            for f, v in fields.items():
                setattr(r, f, v)
            r.token_times = list(times)
    return restore


# --------------------------------------------------------------------- #
# the transaction object
# --------------------------------------------------------------------- #

class StepTxn:
    """An undo journal over one scheduler batch.

    ``add`` registers restore closures (typically the ``snapshot_*``
    functions above plus driver-local ones); ``rollback`` replays them
    LIFO.  A txn may be rolled back at most once — the driver opens a
    fresh one per attempt, so snapshots are never reused."""

    def __init__(self) -> None:
        self._restores: List[Callable[[], None]] = []
        self.rolled_back = False

    def add(self, restore: Callable[[], None]) -> None:
        self._restores.append(restore)

    def rollback(self) -> None:
        if self.rolled_back:
            raise RuntimeError("StepTxn rolled back twice")
        for restore in reversed(self._restores):
            restore()
        self.rolled_back = True


def begin_step_txn(*, scheduler=None, allocator=None, store=None,
                   requests=None) -> StepTxn:
    """Convenience constructor covering the common participants; the
    driver adds its own locals with ``txn.add``."""
    txn = StepTxn()
    if scheduler is not None:
        txn.add(snapshot_scheduler(scheduler))
    if allocator is not None:
        txn.add(snapshot_allocator(allocator))
    if store is not None:
        txn.add(snapshot_store(store))
    if requests is not None:
        txn.add(snapshot_requests(list(requests)))
    return txn
