"""CLI: ``python -m repro.analysis [paths...]``.

Exit code 0 iff no blocking findings (not suppressed inline, not in the
committed baseline).  ``--json`` for machine output, ``--write-baseline``
to regenerate the grandfather file, ``--artifact`` to additionally run
the compiled-artifact audit (builds a tiny engine; slow).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.findings import write_baseline
from repro.analysis.runner import ALL_RULES, run_paths

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_BASELINE = os.path.join(REPO_ROOT, "analysis_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis "
                    "(rules: %s)" % ", ".join(ALL_RULES))
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to scan (default: src/)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file (default: analysis_baseline.json "
                         "at the repo root)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from this run and exit 0")
    ap.add_argument("--artifact", action="store_true",
                    help="also audit lowered HLO + compile count of a "
                         "tiny engine run (slow; builds a model)")
    args = ap.parse_args(argv)

    paths = args.paths or [os.path.join(REPO_ROOT, "src")]
    rules = args.rules.split(",") if args.rules else None

    if args.write_baseline:
        findings = run_paths(paths, rules=rules, baseline=None)
        fps = write_baseline(args.baseline, findings)
        print(f"wrote {len(fps)} fingerprints to {args.baseline}")
        return 0

    baseline = None if args.no_baseline else args.baseline
    findings = run_paths(paths, rules=rules, baseline=baseline)

    if args.artifact:
        from repro.analysis.artifact import audit_artifacts
        findings.extend(audit_artifacts())

    blocking = [f for f in findings if f.blocking]
    per_rule = {rule: sum(1 for f in findings if f.rule == rule)
                for rule in ALL_RULES}
    if args.json:
        print(json.dumps({"findings": [f.to_dict() for f in findings],
                          "blocking": len(blocking),
                          "per_rule": per_rule}, indent=1))
    else:
        for f in findings:
            print(f.render())
        n_sup = sum(1 for f in findings if f.suppressed)
        n_base = sum(1 for f in findings if f.baselined)
        print("-- per rule: " + ", ".join(
            f"{rule}={n}" for rule, n in per_rule.items()))
        print(f"-- {len(findings)} findings: {len(blocking)} blocking, "
              f"{n_sup} allowed inline, {n_base} baselined")
    return 1 if blocking else 0


if __name__ == "__main__":
    sys.exit(main())
