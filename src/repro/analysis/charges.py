"""Checker 3 — the charge auditor: no unpriced resource mutations, and
one config source for mirrored knobs.

The paper's core move is giving inference a DBMS-style resource cost
model: every KV movement (swap-out/in, demotion, promotion, reclaim)
carries a virtual-time charge or a stats update, and PR 5 established
that the engine and the simulator shadow read ONE source for the
policy/demotion knobs so their charges agree batch-for-batch.  Both
contracts were enforced by hand.  This checker audits them statically:

* ``unpriced-mutation`` — in ``serving/`` + ``core/``, every call to a
  state-mutating method of ``PagedAllocator`` / ``KVSwapStore`` (and
  the ``attach_prefix_run`` helper) must be *paired* with a charge or
  accounting update in the same function: a ``swap_time`` /
  ``batch_time`` pricing call, a virtual-clock advance (``now``,
  ``swap_s``, ``_tier_swap_s``), or a stats/bookkeeping touch
  (``stats[...]`` / ``swap_stats[...]`` / ``version`` / ``_nbytes`` /
  ``num_swaps`` / ``record_*``).  Pairing is control-flow aware: a
  charge sitting in a SIBLING branch arm of the mutation does not
  count (it can never execute on the mutation's path); a charge on the
  same straight-line path — before, after, or in a conditional the
  mutation dominates — does.  Mutations that are deliberately free
  (releasing pages costs nothing; the re-admission pays) carry an
  ``# repro: allow-unpriced-mutation(<reason>)``.

* ``config-mirror`` — every field name shared by ``SchedulerConfig``
  and ``EngineConfig`` is a mirrored knob and must be written through
  in ``Engine.__init__`` (``scheduler.cfg.<field> = ...``), the "one
  source" rule: a knob added to both configs but not threaded lets the
  engine's allocator and the simulator shadow silently disagree on
  which tier a prefix lands in.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.astutil import (ModuleIndex, dotted_name, last_attr,
                                    paths_compatible)
from repro.analysis.findings import Finding

RULE = "unpriced-mutation"
RULE_MIRROR = "config-mirror"

SCOPES = ("serving/", "core/")

#: distinctive mutator method names — flagged on ANY receiver
MUTATORS_ANY_RECV = {
    "put_run", "put_prefix", "pop_runs", "pop_prefix", "register_prefix",
    "promote_prefix", "extend_shared", "ensure_private", "free_tail",
    "attach_prefix_run",
}
#: generic method names — flagged only on receivers that look like the
#: allocator / swap store (``self.allocator``, ``shadow.alloc``,
#: ``self.swap_store``, ``host_tier`` ...)
MUTATORS_STATE_RECV = {"allocate", "share", "free", "put", "pop"}
STATE_RECEIVERS = {"allocator", "alloc", "swap_store", "store",
                   "host_tier"}

#: what counts as a charge / accounting update
CHARGE_CALLS = {"swap_time", "_swap_time", "batch_time", "charge",
                "record_hit", "record_insert", "record_remove"}
CHARGE_NAMES = {"swap_s", "_tier_swap_s", "_carry_swap_s", "now",
                "num_swaps", "version", "_nbytes", "nbytes"}
CHARGE_SUBSCRIPTS = {"stats", "swap_stats", "prefix_stats"}


def in_scope(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(s in norm for s in SCOPES)


def _receiver(call: ast.Call) -> str:
    """Last attribute of the receiver chain ('' for bare calls):
    self.allocator.allocate(...) -> 'allocator'."""
    func = call.func
    if isinstance(func, ast.Attribute):
        recv = func.value
        name = dotted_name(recv)
        return last_attr(name)
    return ""


def _is_mutator(call: ast.Call) -> Optional[str]:
    name = dotted_name(call.func)
    bare = last_attr(name)
    if bare in MUTATORS_ANY_RECV:
        return bare
    if bare in MUTATORS_STATE_RECV and _receiver(call) in STATE_RECEIVERS:
        return bare
    return None


def _is_charge(node: ast.AST) -> bool:
    if isinstance(node, ast.Call):
        return last_attr(dotted_name(node.func)) in CHARGE_CALLS
    if isinstance(node, (ast.AugAssign, ast.Assign)):
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            if isinstance(t, (ast.Name, ast.Attribute)) \
                    and last_attr(dotted_name(t)) in CHARGE_NAMES:
                return True
            if isinstance(t, ast.Subscript) \
                    and last_attr(dotted_name(t.value)) \
                    in CHARGE_SUBSCRIPTS:
                return True
    return False


def check_module(mod: ModuleIndex) -> List[Finding]:
    out: List[Finding] = []
    if in_scope(mod.path):
        out.extend(_check_unpriced(mod))
    out.extend(_check_config_mirror(mod))
    return out


def _check_unpriced(mod: ModuleIndex) -> List[Finding]:
    out: List[Finding] = []
    for qual, info in sorted(mod.functions.items()):
        mutations = []
        charges = []
        for node in _own_body(info.node):
            if isinstance(node, ast.Call):
                m = _is_mutator(node)
                if m:
                    mutations.append((node, m))
            if _is_charge(node):
                charges.append(node)
        for node, method in mutations:
            mpath = mod.branch_path(node)
            if any(paths_compatible(mod.branch_path(c), mpath)
                   for c in charges):
                continue
            out.append(Finding(
                rule=RULE, path=mod.path, line=node.lineno,
                col=node.col_offset + 1, symbol=qual,
                message=f"`.{method}()` mutates allocator/swap-store "
                        f"state with no virtual-time charge or stats "
                        f"update on its control-flow path — unpriced "
                        f"resource traffic breaks the cost model's "
                        f"engine<->simulator parity"))
    return out


# --------------------------------------------------------------------- #
# config-mirror
# --------------------------------------------------------------------- #

def _dataclass_fields(cls: ast.ClassDef) -> Set[str]:
    return {stmt.target.id for stmt in cls.body
            if isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)}


#: knobs whose mirroring is structural, not assignment-based (the
#: engine passes nslots as the scheduler's max_running, etc.)
_MIRROR_EXEMPT: Set[str] = set()


def _check_config_mirror(mod: ModuleIndex) -> List[Finding]:
    """Runs on the module that defines ``EngineConfig`` + ``Engine``;
    pulls ``SchedulerConfig`` from its import site lazily (the checker
    is handed one module at a time, so the scheduler fields are parsed
    from the sibling file)."""
    if "EngineConfig" not in mod.classes or "Engine" not in mod.classes:
        return []
    sched_fields = _sibling_scheduler_fields(mod)
    if not sched_fields:
        return []
    eng_fields = _dataclass_fields(mod.classes["EngineConfig"])
    shared = (eng_fields & sched_fields) - _MIRROR_EXEMPT
    if not shared:
        return []

    threaded: Set[str] = set()
    init = None
    for stmt in mod.classes["Engine"].body:
        if isinstance(stmt, ast.FunctionDef) and stmt.name == "__init__":
            init = stmt
            break
    if init is not None:
        for node in ast.walk(init):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Attribute) \
                            and t.value.attr == "cfg":
                        threaded.add(t.attr)
    out = []
    for name in sorted(shared - threaded):
        out.append(Finding(
            rule=RULE_MIRROR, path=mod.path,
            line=mod.classes["EngineConfig"].lineno, col=1,
            symbol="EngineConfig",
            message=f"mirrored knob '{name}' exists in both "
                    f"EngineConfig and SchedulerConfig but is not "
                    f"written through in Engine.__init__ "
                    f"(scheduler.cfg.{name} = ...) — the engine "
                    f"allocator and the simulator shadow would read "
                    f"different sources"))
    return out


def _sibling_scheduler_fields(mod: ModuleIndex) -> Set[str]:
    import os
    base = os.path.dirname(os.path.dirname(mod.path))
    cand = os.path.join(base, "core", "scheduler.py")
    if not os.path.exists(cand):
        # findings carry repo-root-relative paths; resolve against the
        # repo root when the scan runs from elsewhere
        from repro.analysis.runner import REPO_ROOT
        cand = os.path.join(REPO_ROOT, cand)
        if not os.path.exists(cand):
            return set()
    with open(cand) as f:
        tree = ast.parse(f.read())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "SchedulerConfig":
            return _dataclass_fields(node)
    return set()


def _own_body(fn_node: ast.AST):
    work = list(ast.iter_child_nodes(fn_node))
    while work:
        node = work.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        work.extend(ast.iter_child_nodes(node))
