"""Checker 7 — async swap-protocol discipline: starts register, reads
drain, results drain everything, drains stay off the trace.

The PR 2/8 double-buffered swap path overlaps D2H copies with compute:
``copy_to_host_async`` starts a transfer whose host bytes only exist
after a drain boundary (``_drain_swaps`` / ``_drain_runs`` /
``_drain_demotes`` blocks on the copy, replaces device leaves with host
arrays, CRC-seals).  Between start and drain the entry sits in an
in-flight buffer (``_pending_swaps`` / ``_pending_runs`` /
``_pending_demotes``).  Four protocol obligations, each one checkable
against the local call graph:

* **every start is registered** — a ``copy_to_host_async()`` call must
  be paired, on a compatible control-flow path, with a store into a
  ``self._pending_*`` buffer: in the same function, or (for payload
  builders like ``_gather_pages_device`` that return the in-flight
  buffers) at every call site.  An unregistered start is a transfer no
  drain boundary will ever finalize — the entry's CRC seals over
  device buffers and verification goes undefined.

* **payload reads are dominated by a drain** — popping an entry out of
  the swap store (``pop`` / ``pop_runs`` on a store-like receiver) and
  consuming its PAYLOAD (``.cache`` / ``.kv``, or passing the entry
  whole to a writer) requires a lexically-earlier, path-compatible
  ``_drain_*`` call in the same function.  Metadata-only pops (the
  rollback repairs read ``num_tokens`` to unwind counters) need no
  drain and are not flagged.

* **the final result drains the world** — the function constructing
  ``EngineResult(...)`` must call a zero-argument ``_drain_swaps()``
  first (the full drain cascades to demotes and runs); otherwise
  still-in-flight entries leak device arrays into the returned stats.

* **drains stay host-side** — a ``_drain_*`` call inside jit-reachable
  code would bake a blocking ``device_get`` into a traced computation
  (at best a constant-folded surprise, at worst a tracer error).

All four share one rule (``async-drain``); messages distinguish the
obligation.  Intentional exceptions carry
``# repro: allow-async-drain(<why the protocol holds anyway>)``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.astutil import (ModuleIndex, dotted_name, last_attr,
                                    paths_compatible)
from repro.analysis.findings import Finding

RULE = "async-drain"

SCOPES = ("serving/", "core/")

#: payload-popping methods on store-like receivers
POP_METHODS = {"pop", "pop_runs", "pop_prefix"}
STORE_RECEIVERS = {"swap_store", "store", "host_tier"}
#: attributes whose access means the entry's PAYLOAD is consumed
PAYLOAD_ATTRS = {"cache", "kv"}


def in_scope(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(s in norm for s in SCOPES)


def check_module(mod: ModuleIndex) -> List[Finding]:
    if not in_scope(mod.path):
        return []
    out: List[Finding] = []
    out.extend(_check_start_registration(mod))
    out.extend(_check_pop_drained(mod))
    out.extend(_check_result_drained(mod))
    out.extend(_check_drain_off_trace(mod))
    return out


# --------------------------------------------------------------------- #
# shared scanning helpers
# --------------------------------------------------------------------- #

def _own_body(fn_node: ast.AST):
    work = list(ast.iter_child_nodes(fn_node))
    while work:
        node = work.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        work.extend(ast.iter_child_nodes(node))


def _pending_stores(mod: ModuleIndex, fn_node: ast.AST) -> List[ast.AST]:
    """Stores into ``self._pending_*[...]`` within a function body."""
    out = []
    for node in _own_body(fn_node):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) \
                        and last_attr(dotted_name(t.value)) \
                        .startswith("_pending"):
                    out.append(node)
    return out


def _drain_calls(mod: ModuleIndex, fn_node: ast.AST) -> List[ast.Call]:
    out = []
    for node in _own_body(fn_node):
        if isinstance(node, ast.Call) and _is_drain_name(
                last_attr(dotted_name(node.func))):
            out.append(node)
    return out


def _is_drain_name(bare: str) -> bool:
    return bare.startswith("_drain") or bare == "drain"


def _call_sites(mod: ModuleIndex, callee: str) -> List[Tuple[str, ast.Call]]:
    """(caller qualname, call node) pairs for calls to ``callee``."""
    sites = []
    for qual, info in sorted(mod.functions.items()):
        if last_attr(callee) not in {last_attr(c) for c in info.calls}:
            continue
        for node in _own_body(info.node):
            if isinstance(node, ast.Call) \
                    and last_attr(dotted_name(node.func)) \
                    == last_attr(callee):
                sites.append((qual, node))
    return sites


# --------------------------------------------------------------------- #
# 1. every copy_to_host_async start lands in a pending buffer
# --------------------------------------------------------------------- #

def _check_start_registration(mod: ModuleIndex) -> List[Finding]:
    out: List[Finding] = []
    flagged_fns: Set[str] = set()
    for qual, info in sorted(mod.functions.items()):
        starts = [node for node in _own_body(info.node)
                  if isinstance(node, ast.Call)
                  and last_attr(dotted_name(node.func))
                  == "copy_to_host_async"]
        if not starts:
            continue
        stores = _pending_stores(mod, info.node)
        for start in starts:
            spath = mod.branch_path(start)
            if any(paths_compatible(mod.branch_path(s), spath)
                   for s in stores):
                continue
            # builder pattern: the CALLERS register the returned buffers
            if qual not in flagged_fns:
                flagged_fns.add(qual)
                out.extend(_check_caller_registration(mod, qual, start))
    return out


def _check_caller_registration(mod: ModuleIndex, qual: str,
                               start: ast.Call) -> List[Finding]:
    bare = qual.rsplit(".", 1)[-1]
    sites = [(c, n) for c, n in _call_sites(mod, bare) if c != qual]
    if not sites:
        return [Finding(
            rule=RULE, path=mod.path, line=start.lineno,
            col=start.col_offset + 1, symbol=qual,
            message=f"copy_to_host_async started in {bare} is never "
                    f"registered in a self._pending_* buffer (here or "
                    f"at any call site) — no drain boundary will ever "
                    f"finalize this transfer")]
    out = []
    for caller, node in sites:
        cinfo = mod.functions.get(caller)
        if cinfo is None:
            continue
        stores = _pending_stores(mod, cinfo.node)
        npath = mod.branch_path(node)
        if any(paths_compatible(mod.branch_path(s), npath)
               for s in stores):
            continue
        out.append(Finding(
            rule=RULE, path=mod.path, line=node.lineno,
            col=node.col_offset + 1, symbol=caller,
            message=f"{bare}() starts an async D2H copy but this call "
                    f"site never registers the result in a "
                    f"self._pending_* buffer on its control-flow path "
                    f"— the transfer has no drain boundary"))
    return out


# --------------------------------------------------------------------- #
# 2. payload-consuming pops are dominated by a drain
# --------------------------------------------------------------------- #

def _check_pop_drained(mod: ModuleIndex) -> List[Finding]:
    out: List[Finding] = []
    for qual, info in sorted(mod.functions.items()):
        drains = _drain_calls(mod, info.node)
        for pop, consumer in _consumed_pops(mod, info.node):
            ppath = mod.branch_path(pop)
            if any(d.lineno < pop.lineno
                   and paths_compatible(mod.branch_path(d), ppath)
                   for d in drains):
                continue
            method = last_attr(dotted_name(pop.func))
            out.append(Finding(
                rule=RULE, path=mod.path, line=pop.lineno,
                col=pop.col_offset + 1, symbol=qual,
                message=f".{method}() hands out a swap entry whose "
                        f"payload is consumed ({consumer}) with no "
                        f"preceding _drain_* call on this path — an "
                        f"in-flight entry still holds device buffers "
                        f"here"))
    return out


def _consumed_pops(mod: ModuleIndex, fn_node: ast.AST
                   ) -> List[Tuple[ast.Call, str]]:
    """Pop calls whose result's payload is consumed in this function."""
    body = list(_own_body(fn_node))
    pops: List[Tuple[ast.Call, str]] = []
    for node in body:
        if not (isinstance(node, ast.Call)
                and last_attr(dotted_name(node.func)) in POP_METHODS
                and _receiver(node) in STORE_RECEIVERS):
            continue
        parent = mod.parent(node)
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1 \
                and isinstance(parent.targets[0], ast.Name):
            use = _payload_use(body, parent.targets[0].id, node.lineno)
            if use:
                pops.append((node, use))
        elif isinstance(parent, ast.For) and parent.iter is node \
                and isinstance(parent.target, ast.Name):
            use = _payload_use(list(ast.walk(parent)),
                               parent.target.id, node.lineno)
            if use:
                pops.append((node, use))
    return pops


def _payload_use(nodes: Iterable[ast.AST], binding: str,
                 after_line: int) -> str:
    """How (if at all) ``binding``'s payload is consumed: a ``.cache`` /
    ``.kv`` access, or the entry passed whole as a call argument."""
    for node in nodes:
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == binding \
                and node.attr in PAYLOAD_ATTRS \
                and node.lineno >= after_line:
            return f".{node.attr} read"
        if isinstance(node, ast.Call) and node.lineno >= after_line:
            for a in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(a, ast.Name) and a.id == binding:
                    callee = last_attr(dotted_name(node.func))
                    return f"passed whole to {callee}()"
    return ""


def _receiver(call: ast.Call) -> str:
    func = call.func
    if isinstance(func, ast.Attribute):
        return last_attr(dotted_name(func.value))
    return ""


# --------------------------------------------------------------------- #
# 3. EngineResult construction happens on fully-drained state
# --------------------------------------------------------------------- #

def _check_result_drained(mod: ModuleIndex) -> List[Finding]:
    out: List[Finding] = []
    for qual, info in sorted(mod.functions.items()):
        for node in _own_body(info.node):
            if not (isinstance(node, ast.Call)
                    and last_attr(dotted_name(node.func))
                    == "EngineResult"):
                continue
            npath = mod.branch_path(node)
            full = [d for d in _drain_calls(mod, info.node)
                    if not d.args and not d.keywords
                    and d.lineno < node.lineno
                    and paths_compatible(mod.branch_path(d), npath)]
            if full:
                continue
            out.append(Finding(
                rule=RULE, path=mod.path, line=node.lineno,
                col=node.col_offset + 1, symbol=qual,
                message="EngineResult is built with no preceding "
                        "zero-argument _drain_swaps() on this path — "
                        "in-flight swap/demote/run transfers would "
                        "leak device buffers into the returned stats"))
    return out


# --------------------------------------------------------------------- #
# 4. drains never run under a jit trace
# --------------------------------------------------------------------- #

def _check_drain_off_trace(mod: ModuleIndex) -> List[Finding]:
    reach = mod.jit_reachable()
    if not reach:
        return []
    out: List[Finding] = []
    for qual in sorted(reach):
        info = mod.functions.get(qual)
        if info is None:
            continue
        for node in _own_body(info.node):
            if isinstance(node, ast.Call) and _is_drain_name(
                    last_attr(dotted_name(node.func))):
                out.append(Finding(
                    rule=RULE, path=mod.path, line=node.lineno,
                    col=node.col_offset + 1, symbol=qual,
                    message=f"drain call inside jit-reachable code "
                            f"({qual}) — the blocking device_get would "
                            f"be traced into the compiled computation"))
    return out
