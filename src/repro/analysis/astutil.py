"""Shared AST infrastructure for the repo-specific checkers.

One ``ModuleIndex`` per file: the parsed tree with

* every function (including nested ones and methods) indexed by
  qualname, with its parameters, the bare names it calls, and the
  function-valued names it passes as callbacks (``jax.lax.scan`` bodies
  and friends are traced, not called by name);
* the set of *jit roots* — functions handed to ``jax.jit`` / ``pmap`` /
  ``shard_map`` (as a call argument or decorator) — and the transitive
  *jit-reachable* closure over the local call graph, which is the scope
  of the recompile-hazard rules;
* the names jitted callables are BOUND to (``self._prefill_many =
  jax.jit(prefill_many)``), which is how call sites of compiled entry
  points are recognised;
* a *branch path* per AST node — the chain of (branch statement,
  branch arm) it sits under — so checkers can reason about control
  flow: two nodes are on *compatible* paths iff neither sits in a
  sibling arm of the other (then one always executes when the other
  does, modulo exceptions/loop trip counts).

Everything here is heuristic in the way any Python static analysis is:
names, not types.  The checkers are tuned to THIS repo's idioms and
verified against fixture corpora in ``tests/test_analysis.py``.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

BranchPath = Tuple[Tuple[int, str], ...]

#: callables whose function-valued arguments run under the caller's
#: trace (so a jitted caller makes them jit-reachable)
_TRACING_CALLEES = {
    "scan", "while_loop", "fori_loop", "cond", "switch", "map",
    "tree_map", "custom_vjp", "custom_jvp", "checkpoint", "remat",
    "vmap", "grad", "value_and_grad",
}

_JIT_WRAPPERS = {"jit", "pmap", "shard_map"}


def dotted_name(node: ast.AST) -> str:
    """'jax.lax.scan' for Attribute chains, 'f' for Names, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    if parts:                       # e.g. a call/subscript receiver
        return "." + ".".join(reversed(parts))
    return ""


def last_attr(name: str) -> str:
    return name.rsplit(".", 1)[-1] if name else ""


def free_names(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def paths_compatible(a: BranchPath, b: BranchPath) -> bool:
    """True iff neither node lives in a sibling branch arm of the other
    (one path is a prefix of the other)."""
    n = min(len(a), len(b))
    return a[:n] == b[:n]


@dataclass
class FunctionInfo:
    qualname: str
    node: ast.AST                       # FunctionDef / AsyncFunctionDef
    params: List[str]
    calls: Set[str] = field(default_factory=set)       # dotted names
    callback_args: Set[str] = field(default_factory=set)
    parent: Optional[str] = None        # enclosing function qualname


class ModuleIndex:
    def __init__(self, path: str, source: str):
        self.path = path
        self.source_lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_bare_name: Dict[str, List[str]] = {}
        self.jit_roots: Set[str] = set()        # function qualnames
        self.jit_handles: Set[str] = set()      # bound names of jitted fns
        # functions whose body calls jax.lax.* / pallas_call: traced by
        # construction even when the jax.jit boundary lives in another
        # module (the engine jits paged_plane's builders' closures)
        self.trace_roots: Set[str] = set()
        self.classes: Dict[str, ast.ClassDef] = {}
        self._parents: Dict[int, ast.AST] = {}
        self._branch: Dict[int, BranchPath] = {}
        self._enclosing_fn: Dict[int, str] = {}
        self._index()

    # ------------------------------------------------------------------ #
    def branch_path(self, node: ast.AST) -> BranchPath:
        return self._branch.get(id(node), ())

    def enclosing_function(self, node: ast.AST) -> str:
        return self._enclosing_fn.get(id(node), "")

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def resolve(self, name: str) -> List[FunctionInfo]:
        """Functions matching a (possibly dotted) called name, by bare
        final name — the local-call-graph approximation."""
        return [self.functions[q]
                for q in self.by_bare_name.get(last_attr(name), [])]

    # ------------------------------------------------------------------ #
    def _index(self) -> None:
        # handles first: _walk consults them for callback collection
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and self._is_jit_wrapper(node.value):
                for t in node.targets:
                    handle = last_attr(dotted_name(t))
                    if handle:
                        self.jit_handles.add(handle)
        self._walk(self.tree, fn=None, path=())
        self._find_jit_bindings()

    def _walk(self, node: ast.AST, fn: Optional[str],
              path: BranchPath) -> None:
        for fieldname, value in ast.iter_fields(node):
            kids = value if isinstance(value, list) else [value]
            for kid in kids:
                if not isinstance(kid, ast.AST):
                    continue
                self._parents[id(kid)] = node
                kid_fn, kid_path = fn, path
                if isinstance(node, (ast.If, ast.Try, ast.For, ast.While,
                                     ast.ExceptHandler, ast.With)) \
                        and fieldname in ("body", "orelse", "handlers",
                                          "finalbody"):
                    kid_path = path + ((id(node), fieldname),)
                if isinstance(kid, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{fn}.{kid.name}" if fn else kid.name
                    info = FunctionInfo(
                        qualname=qual, node=kid,
                        params=[a.arg for a in (
                            kid.args.posonlyargs + kid.args.args
                            + kid.args.kwonlyargs)],
                        parent=fn)
                    self.functions[qual] = info
                    self.by_bare_name.setdefault(kid.name, []).append(qual)
                    for dec in kid.decorator_list:
                        if self._is_jit_wrapper(dec):
                            self.jit_roots.add(qual)
                    kid_fn, kid_path = qual, ()
                elif isinstance(kid, ast.ClassDef):
                    self.classes[kid.name] = kid
                elif isinstance(kid, ast.Call) and fn:
                    info = self.functions[fn]
                    name = dotted_name(kid.func)
                    if name:
                        info.calls.add(name)
                        if ".lax." in f".{name}" \
                                or last_attr(name) == "pallas_call":
                            self.trace_roots.add(fn)
                    if last_attr(name) in _TRACING_CALLEES \
                            or name in self.jit_handles:
                        for a in list(kid.args) + [k.value
                                                   for k in kid.keywords]:
                            if isinstance(a, ast.Name):
                                info.callback_args.add(a.id)
                self._branch[id(kid)] = kid_path
                if kid_fn:
                    self._enclosing_fn[id(kid)] = kid_fn
                self._walk(kid, kid_fn, kid_path)

    def _is_jit_wrapper(self, node: ast.AST) -> bool:
        """jax.jit / jit / pmap / shard_map, or partial(jax.jit, ...)."""
        name = dotted_name(node)
        if last_attr(name) in _JIT_WRAPPERS:
            return True
        if isinstance(node, ast.Call):
            if last_attr(dotted_name(node.func)) in _JIT_WRAPPERS:
                return True
            if last_attr(dotted_name(node.func)) == "partial" and node.args:
                return last_attr(dotted_name(node.args[0])) in _JIT_WRAPPERS
        return False

    def _find_jit_bindings(self) -> None:
        """jax.jit(f) calls: f becomes a root; an assignment target
        becomes a known compiled-entry-point handle.  Resolution is
        scope-aware: a local ``step`` closure handed to ``jax.jit``
        must not implicate an unrelated method that shares its bare
        name (``Engine.step``)."""
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and node.args \
                    and self._is_jit_wrapper(node):
                tgt = node.args[0]
                if isinstance(tgt, ast.Name):
                    cands = self.by_bare_name.get(tgt.id, [])
                    scope = self.enclosing_function(node)
                    local = [q for q in cands
                             if self.functions[q].parent == scope]
                    for q in (local or cands):
                        self.jit_roots.add(q)

    # ------------------------------------------------------------------ #
    def jit_reachable(self) -> Set[str]:
        """Qualnames of functions reachable from any jit boundary over
        the local call graph (callbacks included)."""
        seen: Set[str] = set()
        work = list(self.jit_roots | self.trace_roots)
        while work:
            q = work.pop()
            if q in seen or q not in self.functions:
                continue
            seen.add(q)
            info = self.functions[q]
            for name in list(info.calls) + list(info.callback_args):
                cands = self.resolve(name)
                local = [t for t in cands if t.parent == q]
                for target in (local or cands):
                    if target.qualname not in seen:
                        work.append(target.qualname)
        return seen


def index_module(path: str) -> ModuleIndex:
    with open(path) as f:
        return ModuleIndex(path, f.read())
