"""Checker 6 — engine<->simulator counter parity, statically.

The validation methodology stands on the serving engine and the
virtual-time simulator reporting the SAME counters for the same
traffic: ``Engine.swap_stats``/``recovery_stats`` on one side,
``PrefixTierSim.stats``/``_FaultMirror.stats`` on the other, plus the
per-batch ``BatchLog`` rows both sides emit.  A key written on one side
only is parity drift that no typo survives a diff of — but that a
runtime parity test only catches on a workload that happens to bump the
counter.  This checker diffs the written key sets at analysis time.

Key collection is precise because the keys are constants
(``core/stat_keys.py``): every subscript store / aug-assign / dict
literal keyed by a string literal or an ``SK.NAME`` attribute resolves
to its literal value; dynamic keys are ignored (none exist in-tree).

Sanctioned asymmetries are DATA, not checker special cases: the
``ENGINE_ONLY_KEYS`` / ``SIM_ONLY_KEYS`` /
``ENGINE_ONLY_BATCHLOG_FIELDS`` sets in ``stat_keys.py`` are parsed
from source, and every entry there documents why the other side cannot
mirror it.  The checker flags:

* an engine-side ``swap_stats``/``recovery_stats`` key never written by
  ``PrefixTierSim``/``_FaultMirror`` and absent from
  ``ENGINE_ONLY_KEYS`` (anchored at its first engine write);
* the reverse sim-only drift modulo ``SIM_ONLY_KEYS`` (anchored at the
  first sim write);
* ``BatchLog(...)`` constructor fields populated on one side only,
  modulo ``ENGINE_ONLY_BATCHLOG_FIELDS``.

``PagedAllocator.stats`` and the ``EngineResult``-only fields are out
of scope by construction: the allocator is the same class on both
sides (drift impossible), and ``EngineResult`` wraps the shared
``SimResult`` — its extra fields are the stat dicts checked above.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import ModuleIndex, dotted_name, last_attr
from repro.analysis.findings import Finding

RULE = "stat-mirror"

_STAT_KEYS_PATH = "src/repro/core/stat_keys.py"
_ENGINE_PATH = "src/repro/serving/engine.py"
_SIM_PATH = "src/repro/core/simulator.py"

#: engine-side stat-dict receivers (attribute name of the subscript base)
ENGINE_DICTS = ("swap_stats", "recovery_stats")
#: simulator-side classes whose ``self.stats`` mirrors the engine dicts
SIM_CLASSES = ("PrefixTierSim", "_FaultMirror")


# --------------------------------------------------------------------- #
# stat_keys.py parsing
# --------------------------------------------------------------------- #

def _load_stat_keys(near: str) -> Tuple[Dict[str, str], Dict[str, Set[str]]]:
    """(constant name -> literal key, allowlist name -> literal set)."""
    from repro.analysis.txncov import _parse_sibling
    tree = _parse_sibling(_STAT_KEYS_PATH, near)
    consts: Dict[str, str] = {}
    allow: Dict[str, Set[str]] = {}
    if tree is None:
        return consts, allow
    for node in tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1 \
                or not isinstance(node.targets[0], ast.Name):
            continue
        name, val = node.targets[0].id, node.value
        if isinstance(val, ast.Constant) and isinstance(val.value, str):
            consts[name] = val.value
        elif isinstance(val, ast.Call) \
                and last_attr(dotted_name(val.func)) == "frozenset" \
                and val.args and isinstance(val.args[0], ast.Set):
            keys: Set[str] = set()
            for el in val.args[0].elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    keys.add(el.value)
                elif isinstance(el, ast.Name) and el.id in consts:
                    keys.add(consts[el.id])
            allow[name] = keys
    return consts, allow


def _key_of(node: ast.AST, consts: Dict[str, str]) -> Optional[str]:
    """Literal value of a key expression: 'x', SK.X, stat_keys.X."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Attribute):
        return consts.get(node.attr)
    return None


# --------------------------------------------------------------------- #
# key collection
# --------------------------------------------------------------------- #

def _written_keys(tree: ast.AST, receivers: Tuple[str, ...],
                  consts: Dict[str, str]) -> Dict[str, Tuple[int, int]]:
    """key -> first (line, col) where it is written into a dict whose
    base attribute is named in ``receivers``: subscript stores,
    aug-assigns, and dict-literal (re)initialisations."""
    out: Dict[str, Tuple[int, int]] = {}

    def note(key: Optional[str], node: ast.AST) -> None:
        if key is None:
            return
        pos = (node.lineno, node.col_offset + 1)
        if key not in out or pos < out[key]:
            out[key] = pos

    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            if isinstance(node, ast.AnnAssign) and node.value is None:
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) \
                        and last_attr(dotted_name(t.value)) in receivers:
                    note(_key_of(t.slice, consts), t)
                elif isinstance(t, (ast.Name, ast.Attribute)) \
                        and last_attr(dotted_name(t)) in receivers \
                        and isinstance(node.value, ast.Dict):
                    for k in node.value.keys:
                        if k is not None:
                            note(_key_of(k, consts), k)
    return out


def _batchlog_kwargs(tree: ast.AST) -> Dict[str, Tuple[int, int]]:
    out: Dict[str, Tuple[int, int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and last_attr(dotted_name(node.func)) == "BatchLog":
            for kw in node.keywords:
                if kw.arg and kw.arg not in out:
                    out[kw.arg] = (node.lineno, node.col_offset + 1)
    return out


def _sim_stats_tree(tree: ast.Module) -> List[ast.ClassDef]:
    return [node for node in ast.walk(tree)
            if isinstance(node, ast.ClassDef) and node.name in SIM_CLASSES]


def _collect_sim(tree: ast.Module, consts: Dict[str, str]
                 ) -> Tuple[Dict[str, Tuple[int, int]],
                            Dict[str, Tuple[int, int]]]:
    keys: Dict[str, Tuple[int, int]] = {}
    for cls in _sim_stats_tree(tree):
        for key, pos in _written_keys(cls, ("stats",), consts).items():
            if key not in keys or pos < keys[key]:
                keys[key] = pos
    return keys, _batchlog_kwargs(tree)


def _collect_engine(tree: ast.AST, consts: Dict[str, str]
                    ) -> Tuple[Dict[str, Tuple[int, int]],
                               Dict[str, Tuple[int, int]]]:
    return (_written_keys(tree, ENGINE_DICTS, consts),
            _batchlog_kwargs(tree))


# --------------------------------------------------------------------- #
# checks
# --------------------------------------------------------------------- #

def check_module(mod: ModuleIndex) -> List[Finding]:
    from repro.analysis.txncov import _parse_sibling
    is_engine = "Engine" in mod.classes and "EngineResult" in mod.classes
    is_sim = all(c in mod.classes for c in SIM_CLASSES)
    if not (is_engine or is_sim):
        return []
    consts, allow = _load_stat_keys(mod.path)
    out: List[Finding] = []
    if is_engine:
        sib = _parse_sibling(_SIM_PATH, mod.path)
        if sib is not None:
            eng_keys, eng_blog = _collect_engine(mod.tree, consts)
            sim_keys, sim_blog = _collect_sim(sib, consts)
            out.extend(_diff(
                mod, eng_keys, set(sim_keys),
                allow.get("ENGINE_ONLY_KEYS", set()),
                "engine", "simulator mirror (PrefixTierSim/_FaultMirror)",
                "ENGINE_ONLY_KEYS"))
            out.extend(_diff_blog(
                mod, eng_blog, set(sim_blog),
                allow.get("ENGINE_ONLY_BATCHLOG_FIELDS", set()),
                "engine", "simulator"))
    if is_sim:
        sib = _parse_sibling(_ENGINE_PATH, mod.path)
        if sib is not None:
            sim_keys, sim_blog = _collect_sim(mod.tree, consts)
            eng_keys, eng_blog = _collect_engine(sib, consts)
            out.extend(_diff(
                mod, sim_keys, set(eng_keys),
                allow.get("SIM_ONLY_KEYS", set()),
                "simulator", "engine (swap_stats/recovery_stats)",
                "SIM_ONLY_KEYS"))
            out.extend(_diff_blog(
                mod, sim_blog, set(eng_blog),
                allow.get("ENGINE_ONLY_BATCHLOG_FIELDS", set()),
                "simulator", "engine"))
    return out


def _diff(mod: ModuleIndex, ours: Dict[str, Tuple[int, int]],
          theirs: Set[str], allowed: Set[str], us: str, them: str,
          allowlist: str) -> List[Finding]:
    out: List[Finding] = []
    for key in sorted(ours):
        if key in theirs or key in allowed:
            continue
        line, col = ours[key]
        out.append(Finding(
            rule=RULE, path=mod.path, line=line, col=col,
            symbol=us,
            message=f"stat key '{key}' is written on the {us} side but "
                    f"never by the {them} and is not a sanctioned "
                    f"asymmetry (stat_keys.{allowlist}) — parity drift"))
    return out


def _diff_blog(mod: ModuleIndex, ours: Dict[str, Tuple[int, int]],
               theirs: Set[str], allowed: Set[str], us: str,
               them: str) -> List[Finding]:
    out: List[Finding] = []
    if not ours or not theirs:
        return out              # a side that logs no batches has no row
    for field in sorted(ours):
        if field in theirs or field in allowed:
            continue
        line, col = ours[field]
        out.append(Finding(
            rule=RULE, path=mod.path, line=line, col=col,
            symbol=us,
            message=f"BatchLog field '{field}' is populated on the "
                    f"{us} side but never by the {them} and is not in "
                    f"stat_keys.ENGINE_ONLY_BATCHLOG_FIELDS — per-batch "
                    f"parity drift"))
    return out
