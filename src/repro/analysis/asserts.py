"""Checker 5 — bare ``assert`` statements guarding control-plane state.

The failure-model work (step transactions, fault injection) leans on
``check_invariants`` staying meaningful after every rollback — but a
plain ``assert`` disappears under ``python -O``, so an invariant
guarded by one is unenforced exactly when someone benchmarks with
optimizations on.  In the control plane (``serving/`` and ``core/``)
every assertion must therefore be one of:

* a real exception — ``ValueError`` for argument/config validation,
  ``repro.core.invariants.invariant`` (an always-armed
  ``AssertionError`` subclass) for state invariants;
* an ``assert`` nested under an ``if ... check_invariants ...`` gate —
  those are explicitly opt-in debug validation, armed by config rather
  than by interpreter flags, and the gate documents the intent;
* annotated with ``# repro: allow-bare-invariant-assert(<reason>)``
  when a bare assert is genuinely the right tool (e.g. a
  type-narrowing hint).

Everything else is a finding.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.astutil import ModuleIndex
from repro.analysis.findings import Finding

RULE = "bare-invariant-assert"

#: the control plane the step-transaction machinery must trust
SCOPE = ("src/repro/serving/", "src/repro/core/")

_GATE_NAME = "check_invariants"


def in_scope(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(s in norm for s in SCOPE)


def _gated(mod: ModuleIndex, node: ast.AST) -> bool:
    """True when an ancestor ``if`` test mentions check_invariants."""
    cur = mod.parent(node)
    while cur is not None:
        if isinstance(cur, ast.If) and any(
                isinstance(n, (ast.Name, ast.Attribute))
                and getattr(n, "id", getattr(n, "attr", None)) == _GATE_NAME
                for n in ast.walk(cur.test)):
            return True
        cur = mod.parent(cur)
    return False


def check_module(mod: ModuleIndex) -> List[Finding]:
    if not in_scope(mod.path):
        return []
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assert) or _gated(mod, node):
            continue
        out.append(Finding(
            rule=RULE, path=mod.path, line=node.lineno,
            col=node.col_offset + 1,
            symbol=mod.enclosing_function(node),
            message="bare `assert` vanishes under python -O: raise "
                    "ValueError (argument validation) or "
                    "`repro.core.invariants.invariant` (state "
                    "invariant), or gate it under check_invariants"))
    return out
