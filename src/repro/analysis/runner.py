"""Drives the checkers over a set of files and folds in suppressions
and the committed baseline."""
from __future__ import annotations

import os
from typing import Iterable, List, Optional

from repro.analysis import (asserts, asyncdrain, charges, hostsync,
                            recompile, statmirror, txncov)
from repro.analysis.astutil import ModuleIndex
from repro.analysis.findings import (Finding, apply_baseline,
                                     apply_suppressions, load_baseline,
                                     parse_suppressions)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

ALL_RULES = (
    recompile.RULE, recompile.RULE_SHAPE,
    hostsync.RULE,
    charges.RULE, charges.RULE_MIRROR,
    asserts.RULE,
    txncov.RULE, statmirror.RULE, asyncdrain.RULE,
    "bad-suppression",
)

_CHECKERS = (recompile.check_module, hostsync.check_module,
             charges.check_module, asserts.check_module,
             txncov.check_module, statmirror.check_module,
             asyncdrain.check_module)


def iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
    return sorted(set(out))


def _display_path(path: str) -> str:
    """Repo-root-relative with forward slashes when under the repo —
    keeps finding fingerprints (and so the baseline) stable across
    invocation directories."""
    ap = os.path.abspath(path)
    if ap.startswith(REPO_ROOT + os.sep):
        return os.path.relpath(ap, REPO_ROOT).replace(os.sep, "/")
    return path.replace(os.sep, "/")


def check_file(path: str, rules: Optional[Iterable[str]] = None
               ) -> List[Finding]:
    shown = _display_path(path)
    try:
        with open(path) as f:
            mod = ModuleIndex(shown, f.read())
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=shown,
                        line=e.lineno or 1, col=e.offset or 1,
                        message=str(e.msg))]
    findings: List[Finding] = []
    for checker in _CHECKERS:
        findings.extend(checker(mod))
    by_line, bad = parse_suppressions(mod.source_lines, shown)
    findings = apply_suppressions(findings, by_line)
    findings.extend(bad)
    if rules is not None:
        keep = set(rules)
        findings = [f for f in findings if f.rule in keep]
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def run_paths(paths: Iterable[str],
              rules: Optional[Iterable[str]] = None,
              baseline: Optional[str] = None) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(check_file(path, rules=rules))
    if baseline:
        findings = apply_baseline(findings, load_baseline(baseline))
    return findings
