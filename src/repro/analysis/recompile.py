"""Checker 1 — recompile hazards inside jit boundaries.

PR 2's headline invariant is that the engine's distinct-XLA-compile
count is a small CONSTANT: shape-stable bucketed entry points, masked
inert rows, fused sampling.  Nothing enforces that statically — a
regression only shows up when the compile-count regression test runs a
whole engine workload.  This checker guards the invariant at lint time:

* ``recompile-hazard`` — inside any function reachable from a
  ``jax.jit`` / ``pmap`` / ``shard_map`` boundary (call graph +
  ``lax.scan``-style callbacks):

  - host materialization of traced values: ``.item()`` / ``.tolist()``,
    ``np.asarray`` / ``np.array``, ``jax.device_get``, and
    ``int()``/``float()``/``bool()`` over non-static expressions.  Under
    trace these either raise ``ConcretizationTypeError`` or silently
    force a constant — re-specializing (recompiling) per value.
  - Python ``if``/``while`` on traced values.  Branching on ``.shape``
    / ``.ndim`` / ``.dtype`` / ``len(...)`` / ``is None`` / dict
    membership is STATIC under trace and allowed; branching on array
    *values* bakes the branch into the compiled artifact.
  - f-string interpolation of traced values (shape/value interpolation
    into a jitted closure concretizes, and a changing string constant
    re-keys the trace).

* ``dynamic-shape`` — in any function that CALLS a compiled entry
  point (a name bound from ``jax.jit(...)``, e.g. the engine's
  ``self._prefill_many``): a ``jnp.asarray``/``np.asarray`` over a
  dynamic-length expression (a slice, list literal, comprehension or
  concatenation).  Every distinct length compiles a fresh XLA
  signature — the PR-2 contract is that token buffers are staged into
  fixed ``(nslots, bucket)`` grids from the bucket ladder first.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.astutil import (ModuleIndex, dotted_name, free_names,
                                    last_attr)
from repro.analysis.findings import Finding

RULE = "recompile-hazard"
RULE_SHAPE = "dynamic-shape"

#: parameters that hold configs / backend selectors, not traced arrays
STATIC_PARAM_NAMES = {"self", "cls", "cfg", "config", "scfg", "ecfg",
                      "hw", "impl", "moe_impl", "mode", "axis", "name"}
#: attribute accesses that are static under trace
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "at"}
STATIC_CALLS = {"len", "isinstance", "getattr", "hasattr", "type",
                "range", "enumerate", "zip"}
_ASARRAY = {"asarray", "array"}
_NP_MODULES = {"np", "numpy", "onp"}


def _is_static_use(mod: ModuleIndex, name_node: ast.AST,
                   stop: ast.AST) -> bool:
    """True when this reference to a traced candidate resolves to
    trace-static information (shape/ndim/dtype/len/identity/membership)."""
    node = name_node
    while node is not None and node is not stop:
        parent = mod.parent(node)
        if isinstance(parent, ast.Attribute) and parent.value is node \
                and parent.attr in STATIC_ATTRS:
            return True
        if isinstance(parent, ast.Call) and node is not parent.func \
                and last_attr(dotted_name(parent.func)) in STATIC_CALLS:
            return True
        if isinstance(parent, ast.Compare) \
                and all(isinstance(op, (ast.Is, ast.IsNot, ast.In,
                                        ast.NotIn))
                        for op in parent.ops):
            return True
        node = parent
    return False


def _traced_candidates(info) -> Set[str]:
    return {p for p in info.params if p not in STATIC_PARAM_NAMES
            and not p.startswith("_")}


def _np_asarray(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    if "." not in name:
        return False
    mod_part, attr = name.rsplit(".", 1)
    return attr in _ASARRAY and last_attr(mod_part) in _NP_MODULES


def _dynamic_length(node: ast.AST) -> bool:
    """Expressions whose length depends on runtime values."""
    if isinstance(node, ast.Subscript):
        sl = node.slice
        return isinstance(sl, ast.Slice) or isinstance(sl, ast.Tuple) \
            and any(isinstance(e, ast.Slice) for e in sl.elts)
    if isinstance(node, (ast.ListComp, ast.GeneratorExp)):
        return True
    if isinstance(node, (ast.List, ast.Tuple)):
        return any(_dynamic_length(e) for e in node.elts)
    if isinstance(node, ast.BinOp):
        return _dynamic_length(node.left) or _dynamic_length(node.right)
    if isinstance(node, ast.Call) \
            and last_attr(dotted_name(node.func)) == "list":
        return True
    return False


def check_module(mod: ModuleIndex) -> List[Finding]:
    out: List[Finding] = []
    reachable = mod.jit_reachable()

    for qual in sorted(reachable):
        info = mod.functions.get(qual)
        if info is None:
            continue
        candidates = _traced_candidates(info)
        out.extend(_check_jitted_fn(mod, info, candidates))

    out.extend(_check_entry_point_calls(mod))
    return out


def _check_jitted_fn(mod: ModuleIndex, info, candidates) -> List[Finding]:
    out: List[Finding] = []
    own_nodes = _own_body(info.node)

    for node in own_nodes:
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            bare = last_attr(name)
            if bare in ("item", "tolist"):
                out.append(_f(mod, node, info,
                              f"`.{bare}()` inside a jitted computation "
                              f"forces the traced value to the host"))
            elif name in ("jax.device_get", "device_get"):
                out.append(_f(mod, node, info,
                              "`jax.device_get` inside a jitted "
                              "computation is a host round-trip"))
            elif _np_asarray(node):
                out.append(_f(mod, node, info,
                              f"`{name}` inside a jitted computation "
                              f"materializes the traced value on host "
                              f"(use jnp, or hoist out of the jit)"))
            elif bare in ("int", "float", "bool") and "." not in name \
                    and node.args and not isinstance(node.args[0],
                                                     ast.Constant):
                arg = node.args[0]
                names = free_names(arg) & candidates
                refs = [n for n in ast.walk(arg)
                        if isinstance(n, ast.Name) and n.id in names]
                if any(not _is_static_use(mod, r, node) for r in refs):
                    out.append(_f(mod, node, info,
                                  f"`{bare}()` over traced value "
                                  f"{sorted(names)} concretizes under "
                                  f"trace (recompile per value)"))
        elif isinstance(node, (ast.If, ast.While)):
            test = node.test
            names = free_names(test) & candidates
            refs = [n for n in ast.walk(test)
                    if isinstance(n, ast.Name) and n.id in names]
            bad = [r.id for r in refs
                   if not _is_static_use(mod, r, test)
                   and not _is_static_use(mod, r, node)]
            if bad:
                out.append(_f(mod, node, info,
                              f"Python branch on traced value "
                              f"{sorted(set(bad))} inside a jitted "
                              f"computation (use jnp.where / lax.cond; "
                              f"shape/ndim/dtype branches are fine)"))
        elif isinstance(node, ast.JoinedStr):
            for part in node.values:
                if not isinstance(part, ast.FormattedValue):
                    continue
                names = free_names(part.value) & candidates
                refs = [n for n in ast.walk(part.value)
                        if isinstance(n, ast.Name) and n.id in names]
                bad = [r.id for r in refs
                       if not _is_static_use(mod, r, part)]
                if bad:
                    out.append(_f(mod, node, info,
                                  f"f-string interpolates traced value "
                                  f"{sorted(set(bad))} inside a jitted "
                                  f"computation (concretizes; re-keys "
                                  f"the trace)"))
                    break
    return out


def _check_entry_point_calls(mod: ModuleIndex) -> List[Finding]:
    """dynamic-shape: unbucketed dynamic-length arrays staged in
    functions that drive compiled entry points."""
    out: List[Finding] = []
    if not mod.jit_handles:
        return out
    for qual, info in sorted(mod.functions.items()):
        calls_handle = any(last_attr(c) in mod.jit_handles
                           for c in info.calls)
        if not calls_handle:
            continue
        for node in _own_body(info.node):
            if not (isinstance(node, ast.Call)
                    and last_attr(dotted_name(node.func)) in _ASARRAY
                    and node.args):
                continue
            src = node.args[0]
            if _dynamic_length(src):
                handles = sorted({last_attr(c) for c in info.calls
                                  if last_attr(c) in mod.jit_handles})
                out.append(Finding(
                    rule=RULE_SHAPE, path=mod.path, line=node.lineno,
                    col=node.col_offset + 1, symbol=qual,
                    message="dynamic-length array staged in a function "
                            f"driving compiled entry points {handles}: "
                            "every distinct length compiles a fresh XLA "
                            "signature — pad into a fixed (nslots, "
                            "bucket) grid from the bucket ladder"))
    return out


def _own_body(fn_node: ast.AST):
    """All nodes of a function EXCLUDING nested function bodies (those
    are indexed and checked as their own functions)."""
    work = list(ast.iter_child_nodes(fn_node))
    while work:
        node = work.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        work.extend(ast.iter_child_nodes(node))


def _f(mod: ModuleIndex, node: ast.AST, info, message: str) -> Finding:
    return Finding(rule=RULE, path=mod.path, line=node.lineno,
                   col=node.col_offset + 1, symbol=info.qualname,
                   message=message)
