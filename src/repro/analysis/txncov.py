"""Checker 5 — rollback completeness: every step-reachable mutation is
covered by a step-txn snapshot.

PR 7 made the batch loop transactional: a mid-step fault rolls the
scheduler, allocator, swap store, every request, and the engine-local
view back to batch start (``serving/txn.py`` + ``Engine._begin_txn``).
The snapshot closures were hand-audited once; every new mutable
attribute added since is a silent hole — rollback "succeeds" and leaves
the new state poisoned.  This checker recomputes the write-sets
statically and cross-checks them against what the snapshots capture:

* **participant classes** (``PagedAllocator`` / ``KVSwapStore`` /
  ``Scheduler``) — when processing the module that DEFINES the class,
  its attribute write-set (self-attr stores, subscript stores/deletes,
  aug-assigns, container-mutator calls, in every method except
  ``__init__``) is compared against the attributes the matching
  ``txn.snapshot_*`` function reads off its participant parameter.
  A mutated-but-never-captured attribute is a finding at its first
  mutation site (so an intentional hole carries its allow right where
  the mutation lives).

* **``Request``** — the mutable-field surface (self-stores in
  ``Request`` methods, plus stores through request-typed receivers in
  the engine/scheduler/simulator, e.g. ``cand.predicted_output = ...``)
  is compared against ``txn._REQUEST_FIELDS`` + the container fields
  ``snapshot_requests`` copies explicitly.  Deleting one field from the
  snapshot list is exactly one finding.

* **the engine** — the attribute write-set of everything reachable
  from ``Engine.step()`` over the local call graph (the post-rollback
  ``repair`` closures included — they run by design on restored state)
  is compared against the first-level ``self.*`` attributes
  ``_begin_txn`` captures or hands to ``begin_step_txn``.  State that
  deliberately survives rollback (measured wall, recovery accounting,
  attempt bookkeeping) carries a rationale-bearing
  ``# repro: allow-txn-coverage(...)`` at its first mutation.

* **snapshot-bearing classes** (anything with a ``snapshot`` /
  ``snapshot_state`` method: ``PrefixTierSim``, ``_FaultMirror``,
  ``RadixPrefixRegistry``) — write-set of the other methods vs the
  attributes the snapshot reads plus the ones ``restore_state`` puts
  back (derived state may be captured on the restore side only).

Granularity is FIRST-LEVEL attributes: ``self.sched.num_swaps -= 1``
charges attr ``sched``, whose rollback is the scheduler snapshot's
job — each layer audits its own surface.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.astutil import ModuleIndex, dotted_name, last_attr
from repro.analysis.findings import Finding

RULE = "txn-coverage"

SCOPES = ("serving/", "core/")

#: container-method calls that mutate their receiver
MUTATING_METHODS = {
    "append", "extend", "insert", "add", "update", "setdefault",
    "pop", "popitem", "remove", "discard", "clear", "sort", "appendleft",
}

#: participant class -> (txn snapshot function, human name)
PARTICIPANTS = {
    "PagedAllocator": "snapshot_allocator",
    "KVSwapStore": "snapshot_store",
    "Scheduler": "snapshot_scheduler",
}

#: local names the engine/scheduler/simulator bind Request objects to —
#: stores through these receivers count toward the Request write-set
REQUEST_RECEIVERS = {"r", "req", "v", "victim", "w", "winner", "cand"}

#: modules scanned for external Request-field stores (repo-relative)
_REQUEST_MUTATOR_MODULES = (
    "src/repro/serving/engine.py",
    "src/repro/core/scheduler.py",
    "src/repro/core/simulator.py",
)

_TXN_PATH = "src/repro/serving/txn.py"


def in_scope(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(s in norm for s in SCOPES)


# --------------------------------------------------------------------- #
# write-set extraction
# --------------------------------------------------------------------- #

def _base_attr(node: ast.AST, recv: str) -> str:
    """First-level attribute of an access chain rooted at name ``recv``:
    ``self.sched.num_swaps`` -> 'sched', ``self._tables[rid].pages`` ->
    '_tables', ``other.x`` -> ''."""
    attr = ""
    while True:
        if isinstance(node, ast.Attribute):
            attr, node = node.attr, node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        else:
            break
    if isinstance(node, ast.Name) and node.id == recv:
        return attr
    return ""


def _mutated_attrs(body: Iterable[ast.AST], recv: str
                   ) -> Dict[str, ast.AST]:
    """attr -> lexically-first mutation node, for stores / deletes /
    aug-assigns / mutating method calls rooted at ``recv``."""
    out: Dict[str, ast.AST] = {}

    def note(attr: str, node: ast.AST) -> None:
        if attr and (attr not in out
                     or node.lineno < out[attr].lineno):
            out[attr] = node

    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                               else [t]):
                        note(_base_attr(el, recv), node)
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    note(_base_attr(t, recv), node)
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in MUTATING_METHODS:
                note(_base_attr(node.func.value, recv), node)
    return out


def _class_methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {stmt.name: stmt for stmt in cls.body
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _class_write_set(cls: ast.ClassDef,
                     exclude: Tuple[str, ...] = ("__init__", "__post_init__")
                     ) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for name, fn in _class_methods(cls).items():
        if name in exclude or name.startswith("snapshot") \
                or name.startswith("restore"):
            continue
        for attr, node in _mutated_attrs(fn.body, "self").items():
            if attr not in out or node.lineno < out[attr].lineno:
                out[attr] = node
    return out


def _loaded_attrs(tree: ast.AST, recv: str) -> Set[str]:
    """First-level attributes READ off ``recv`` anywhere under ``tree``
    (a snapshot captures a field by loading it)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.ctx, ast.Load) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == recv:
            out.add(node.attr)
    return out


def _stored_attrs(tree: ast.AST, recv: str) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.ctx, ast.Store) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == recv:
            out.add(node.attr)
    return out


# --------------------------------------------------------------------- #
# sibling parsing (the checker is handed one module at a time)
# --------------------------------------------------------------------- #

def _repo_file(rel: str, near: str) -> Optional[str]:
    """Resolve a repo-relative path against the scanned file's location,
    falling back to the repo root (findings carry root-relative paths)."""
    parts = rel.split("/")
    norm = near.replace("\\", "/")
    if "src/repro/" in norm:
        base = norm[:norm.index("src/repro/")]
        cand = os.path.join(base or ".", *parts)
        if os.path.exists(cand):
            return cand
    from repro.analysis.runner import REPO_ROOT
    cand = os.path.join(REPO_ROOT, *parts)
    return cand if os.path.exists(cand) else None


def _parse_sibling(rel: str, near: str) -> Optional[ast.Module]:
    path = _repo_file(rel, near)
    if path is None:
        return None
    try:
        with open(path) as f:
            return ast.parse(f.read())
    except (OSError, SyntaxError):
        return None


def _txn_function(tree: ast.Module, name: str
                  ) -> Optional[ast.FunctionDef]:
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _snapshot_captures(tree: ast.Module, fn_name: str) -> Set[str]:
    """Attributes a ``txn.snapshot_*`` function reads off its first
    (participant) parameter — the captured surface."""
    fn = _txn_function(tree, fn_name)
    if fn is None or not (fn.args.args or fn.args.posonlyargs):
        return set()
    param = (fn.args.posonlyargs + fn.args.args)[0].arg
    return _loaded_attrs(fn, param)


def _request_fields(tree: ast.Module) -> Set[str]:
    """``_REQUEST_FIELDS`` literals + the container fields
    ``snapshot_requests`` copies off the request loop variable."""
    fields: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "_REQUEST_FIELDS" \
                        and isinstance(node.value, (ast.Tuple, ast.List)):
                    fields |= {e.value for e in node.value.elts
                               if isinstance(e, ast.Constant)
                               and isinstance(e.value, str)}
    fn = _txn_function(tree, "snapshot_requests")
    if fn is not None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "r":
                fields.add(node.attr)
    return fields


# --------------------------------------------------------------------- #
# checks
# --------------------------------------------------------------------- #

def check_module(mod: ModuleIndex) -> List[Finding]:
    if not in_scope(mod.path):
        return []
    out: List[Finding] = []
    out.extend(_check_participants(mod))
    out.extend(_check_request(mod))
    out.extend(_check_engine(mod))
    out.extend(_check_snapshot_classes(mod))
    return out


def _check_participants(mod: ModuleIndex) -> List[Finding]:
    """Modules defining a txn participant class: write-set vs what the
    sibling ``txn.snapshot_*`` captures."""
    hits = [(c, s) for c, s in PARTICIPANTS.items() if c in mod.classes]
    if not hits:
        return []
    txn_tree = _parse_sibling(_TXN_PATH, mod.path)
    if txn_tree is None:
        return []
    out: List[Finding] = []
    for clsname, snap_fn in hits:
        captured = _snapshot_captures(txn_tree, snap_fn)
        if not captured:        # snapshot gone entirely: other tests fail
            continue
        for attr, node in sorted(_class_write_set(
                mod.classes[clsname]).items()):
            if attr in captured:
                continue
            out.append(Finding(
                rule=RULE, path=mod.path, line=node.lineno,
                col=node.col_offset + 1, symbol=clsname,
                message=f"{clsname}.{attr} is mutated by step-reachable "
                        f"code but txn.{snap_fn} never captures it — "
                        f"a rolled-back step leaves it poisoned"))
    return out


def _check_request(mod: ModuleIndex) -> List[Finding]:
    """The module defining ``Request``: its mutable-field surface
    (internal self-stores plus request-receiver stores in the engine/
    scheduler/simulator) vs the ``snapshot_requests`` field list."""
    if "Request" not in mod.classes or "drop_suspended" not in \
            _class_methods(mod.classes["Request"]):
        return []                # the real state machine, not a stub
    txn_tree = _parse_sibling(_TXN_PATH, mod.path)
    if txn_tree is None:
        return []
    covered = _request_fields(txn_tree)
    if not covered:
        return []
    cls = mod.classes["Request"]
    internal = _class_write_set(cls)
    external: Dict[str, str] = {}
    for rel in _REQUEST_MUTATOR_MODULES:
        tree = _parse_sibling(rel, mod.path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id in REQUEST_RECEIVERS:
                    external.setdefault(
                        t.attr, f"{rel}:{node.lineno}")
    #: init-only or derived request attributes that no step mutates
    out: List[Finding] = []
    for field in sorted(set(internal) | set(external)):
        if field in covered:
            continue
        if field in internal:
            node = internal[field]
            line, col, where = node.lineno, node.col_offset + 1, \
                "Request methods"
        else:
            line, col = cls.lineno, 1
            where = external[field]
        out.append(Finding(
            rule=RULE, path=mod.path, line=line, col=col,
            symbol="Request",
            message=f"Request.{field} is mutated mid-step (via {where}) "
                    f"but txn.snapshot_requests never restores it — "
                    f"add it to _REQUEST_FIELDS or capture it "
                    f"explicitly"))
    return out


def _check_engine(mod: ModuleIndex) -> List[Finding]:
    """The module defining the engine: self-attr write-set of everything
    reachable from ``step`` vs what ``_begin_txn`` captures."""
    if "step" not in mod.functions or "_begin_txn" not in mod.functions:
        return []
    begin = mod.functions["_begin_txn"].node
    covered = _loaded_attrs(begin, "self")
    if not covered:
        return []

    # reachability closure from step over the local call graph
    reach: Set[str] = set()
    work = ["step"]
    while work:
        q = work.pop()
        if q in reach or q not in mod.functions:
            continue
        reach.add(q)
        for name in mod.functions[q].calls:
            for target in mod.resolve(name):
                if target.qualname not in reach:
                    work.append(target.qualname)
    reach.discard("_begin_txn")

    mutated: Dict[str, ast.AST] = {}
    for q in sorted(reach):
        fn = mod.functions[q].node
        body = fn.body if isinstance(
            fn, (ast.FunctionDef, ast.AsyncFunctionDef)) else [fn]
        for attr, node in _mutated_attrs_own(mod, body, "self").items():
            if attr not in mutated or node.lineno < mutated[attr].lineno:
                mutated[attr] = node

    out: List[Finding] = []
    for attr, node in sorted(mutated.items()):
        if attr in covered:
            continue
        out.append(Finding(
            rule=RULE, path=mod.path, line=node.lineno,
            col=node.col_offset + 1,
            symbol=mod.enclosing_function(node) or "step",
            message=f"self.{attr} is mutated on a path reachable from "
                    f"step() but _begin_txn neither captures it nor "
                    f"hands it to begin_step_txn — rollback leaves it "
                    f"poisoned"))
    return out


def _mutated_attrs_own(mod: ModuleIndex, body: Iterable[ast.AST],
                       recv: str) -> Dict[str, ast.AST]:
    """Like ``_mutated_attrs`` but skips nested function bodies — those
    are separate call-graph nodes (the engine's repair/restore closures
    are reached, or deliberately not, on their own)."""
    out: Dict[str, ast.AST] = {}
    stack = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        for attr, mnode in _shallow_mutations(node, recv):
            if attr and (attr not in out
                         or mnode.lineno < out[attr].lineno):
                out[attr] = mnode
        stack.extend(ast.iter_child_nodes(node))
    return out


def _shallow_mutations(node: ast.AST, recv: str
                       ) -> List[Tuple[str, ast.AST]]:
    hits: List[Tuple[str, ast.AST]] = []
    if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                hits.append((_base_attr(el, recv), node))
    elif isinstance(node, ast.Delete):
        for t in node.targets:
            hits.append((_base_attr(t, recv), node))
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in MUTATING_METHODS:
        hits.append((_base_attr(node.func.value, recv), node))
    return hits


def _check_snapshot_classes(mod: ModuleIndex) -> List[Finding]:
    """Any class carrying its own ``snapshot``/``snapshot_state``:
    write-set of the other methods vs snapshot loads + restore stores."""
    out: List[Finding] = []
    for clsname, cls in sorted(mod.classes.items()):
        if clsname in PARTICIPANTS or clsname == "Request":
            continue            # audited against txn.py above
        methods = _class_methods(cls)
        snap = methods.get("snapshot") or methods.get("snapshot_state")
        if snap is None:
            continue
        captured = _loaded_attrs(snap, "self") \
            | _stored_attrs(snap, "self")
        restore = methods.get("restore_state") or methods.get("restore")
        if restore is not None:
            captured |= _stored_attrs(restore, "self")
        for attr, node in sorted(_class_write_set(cls).items()):
            if attr in captured:
                continue
            out.append(Finding(
                rule=RULE, path=mod.path, line=node.lineno,
                col=node.col_offset + 1, symbol=clsname,
                message=f"{clsname}.{attr} is mutated outside __init__ "
                        f"but {clsname}.{snap.name} never captures it — "
                        f"a rolled-back step leaves it poisoned"))
    return out
