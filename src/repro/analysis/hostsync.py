"""Checker 2 — hidden host synchronizations in the serving hot path.

ROADMAP item 1's diagnosis of the prefix-sharing regression was a class
of bug no parity test catches: the virtual-time cost model charges a
``swap_time`` for host traffic, but a *synchronous* ``jax.device_get``
also stalls the device pipeline on the WALL clock — the win exists in
the metrics (pages, hits) while the measured tok/s gets eaten.  This
checker makes every device→host synchronization in the hot path
(``serving/`` and ``core/kvcache.py``) explicit: each one is either a
finding or carries an ``# repro: allow-host-sync(<reason>)`` rationale
saying why blocking there is the design (e.g. the double-buffer's drain
boundary, or a restore that must complete before compute reads it).

Flagged (outside jit-reachable functions — inside them the recompile
checker owns the diagnosis):

* ``jax.device_get(...)`` — synchronous D2H copy;
* ``jax.block_until_ready(...)`` / ``x.block_until_ready()`` —
  explicit pipeline stall;
* ``np.asarray`` / ``np.array`` over device-resident values — an
  IMPLICIT device_get.  Device-residency is a per-module taint: names
  assigned from ``jnp.*`` / jitted entry-point calls, ``self``
  attributes assigned such values anywhere in the class (the engine's
  ``cache`` / ``k_pools`` / ``v_pools``), and ``jax.tree`` views of
  either.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.astutil import (ModuleIndex, dotted_name, free_names,
                                    last_attr)
from repro.analysis.findings import Finding

RULE = "host-sync"

#: files the rule applies to (the serving hot path); everything else is
#: offline tooling where a sync is harmless
HOT_PATHS = ("serving/", "core/kvcache.py")

_ASARRAY = {"asarray", "array"}
_NP_MODULES = {"np", "numpy", "onp"}


def in_scope(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(h in norm for h in HOT_PATHS)


def _device_attrs(mod: ModuleIndex) -> Set[str]:
    """self.<attr> names assigned device-producing values anywhere."""
    attrs: Set[str] = set()
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.Assign, ast.AugAssign)):
            continue
        value = node.value
        if not _device_producing(mod, value, set(), attrs):
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                if isinstance(el, ast.Attribute) \
                        and isinstance(el.value, ast.Name) \
                        and el.value.id == "self":
                    attrs.add(el.attr)
    return attrs


def _device_producing(mod: ModuleIndex, node: ast.AST,
                      tainted: Set[str], device_attrs: Set[str]) -> bool:
    """Heuristic: does this expression yield a device array?"""
    for n in ast.walk(node):
        name = ""
        if isinstance(n, ast.Call):
            name = dotted_name(n.func)
        elif isinstance(n, (ast.Attribute, ast.Name)):
            name = dotted_name(n)
        if not name:
            continue
        head, bare = name.split(".")[0], last_attr(name)
        if head in ("jnp", "jax") and bare not in ("device_get",):
            return True
        if bare in mod.jit_handles:
            return True
        if isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name) \
                and n.value.id == "self" and n.attr in device_attrs:
            return True
        if isinstance(n, ast.Name) and n.id in tainted:
            return True
    return False


def check_module(mod: ModuleIndex) -> List[Finding]:
    if not in_scope(mod.path):
        return []
    out: List[Finding] = []
    reachable = mod.jit_reachable()
    device_attrs = _device_attrs(mod)

    for qual, info in sorted(mod.functions.items()):
        if qual in reachable:
            continue                    # the recompile checker's domain
        tainted = _taint_locals(mod, info, device_attrs)
        for node in _own_body(info.node):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            bare = last_attr(name)
            if name in ("jax.device_get", "device_get"):
                out.append(_f(mod, node, qual,
                              "synchronous `jax.device_get` stalls the "
                              "device pipeline (route through the "
                              "async_swap double-buffer, or annotate)"))
            elif bare == "block_until_ready":
                out.append(_f(mod, node, qual,
                              "`block_until_ready` is an explicit "
                              "pipeline stall in the hot path"))
            elif _np_asarray(name) and node.args:
                arg = node.args[0]
                if _device_producing(mod, arg, tainted, device_attrs):
                    out.append(_f(mod, node, qual,
                                  f"`{name}` over a device-resident "
                                  f"value is an implicit synchronous "
                                  f"device_get"))
    return out


def _taint_locals(mod: ModuleIndex, info, device_attrs: Set[str]
                  ) -> Set[str]:
    """Local names assigned device-producing expressions (one forward
    pass; enough for the hot path's straight-line staging code)."""
    tainted: Set[str] = set()
    assigns = sorted((n for n in _own_body(info.node)
                      if isinstance(n, ast.Assign)),
                     key=lambda n: n.lineno)
    for _ in range(2):                  # second pass settles chains
        for node in assigns:
            if _device_producing(mod, node.value, tainted, device_attrs):
                for t in node.targets:
                    for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                        if isinstance(el, ast.Name):
                            tainted.add(el.id)
    return tainted


def _np_asarray(name: str) -> bool:
    if "." not in name:
        return False
    mod_part, attr = name.rsplit(".", 1)
    return attr in _ASARRAY and last_attr(mod_part) in _NP_MODULES


def _own_body(fn_node: ast.AST):
    work = list(ast.iter_child_nodes(fn_node))
    while work:
        node = work.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        work.extend(ast.iter_child_nodes(node))


def _f(mod: ModuleIndex, node: ast.AST, qual: str,
       message: str) -> Finding:
    return Finding(rule=RULE, path=mod.path, line=node.lineno,
                   col=node.col_offset + 1, symbol=qual, message=message)
