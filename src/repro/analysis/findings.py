"""Findings, inline suppressions, and the committed baseline.

Every checker reports ``Finding``s.  A finding is *suppressed* by an
inline comment on the same line (or the line directly above):

    # repro: allow-<rule>(<rationale>)

The rationale is MANDATORY — an ``allow-`` marker without a non-empty
reason is itself reported (rule ``bad-suppression``): the point of the
allowlist is that every intentional violation documents WHY the cost
model tolerates it (which paper section / PR contract it trades
against), not just that someone silenced the tool.

Grandfathered findings live in a committed baseline file (JSON, one
line-number-insensitive fingerprint per finding) so the gate can be
green while old debt is paid down file-by-file; ``--write-baseline``
regenerates it and a meta-test asserts the committed file matches a
fresh run.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

# allow-<rule>(<reason>)  |  allow-<rule>  (reason missing -> violation)
_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow-([a-z][a-z0-9-]*)\s*(?:\(([^)]*)\))?")


@dataclass
class Finding:
    rule: str                   # e.g. "host-sync", "recompile-hazard"
    path: str                   # repo-relative file path
    line: int                   # 1-based
    col: int
    message: str
    symbol: str = ""            # enclosing function/class qualname
    suppressed: bool = False
    reason: str = ""            # suppression rationale, when suppressed
    baselined: bool = False

    @property
    def fingerprint(self) -> str:
        """Line/column-insensitive identity — stable across unrelated
        edits so the baseline does not churn on every reflow."""
        return f"{self.rule}::{self.path}::{self.symbol}::{self.message}"

    @property
    def blocking(self) -> bool:
        return not (self.suppressed or self.baselined)

    def to_dict(self) -> Dict:
        d = asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def render(self) -> str:
        tag = ""
        if self.suppressed:
            tag = f"  [allowed: {self.reason}]"
        elif self.baselined:
            tag = "  [baselined]"
        sym = f" ({self.symbol})" if self.symbol else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}: "
                f"{self.message}{sym}{tag}")


@dataclass
class Suppression:
    rule: str
    line: int                   # line the comment sits on (1-based)
    reason: str
    used: bool = False


def parse_suppressions(source_lines: List[str], path: str
                       ) -> Tuple[Dict[int, List[Suppression]],
                                  List[Finding]]:
    """Scan a file's lines for ``# repro: allow-...`` markers.

    Returns (suppressions keyed by the line they APPLY to, malformed-
    suppression findings).  A marker applies to its own line and to the
    line below it (comment-above style), so both placements work.
    """
    by_line: Dict[int, List[Suppression]] = {}
    bad: List[Finding] = []
    for i, text in enumerate(source_lines, start=1):
        for m in _SUPPRESS_RE.finditer(text):
            rule, reason = m.group(1), (m.group(2) or "").strip()
            if not reason:
                bad.append(Finding(
                    rule="bad-suppression", path=path, line=i,
                    col=m.start() + 1,
                    message=f"allow-{rule} needs a rationale: "
                            f"# repro: allow-{rule}(<why the cost model "
                            f"tolerates this>)"))
                continue
            sup = Suppression(rule=rule, line=i, reason=reason)
            # applies to this line, and to the next (comment-above)
            by_line.setdefault(i, []).append(sup)
            by_line.setdefault(i + 1, []).append(sup)
    return by_line, bad


def apply_suppressions(findings: Iterable[Finding],
                       by_line: Dict[int, List[Suppression]]
                       ) -> List[Finding]:
    """Mark findings whose line carries a matching allow- marker."""
    out = []
    for f in findings:
        for sup in by_line.get(f.line, []):
            if sup.rule == f.rule:
                f.suppressed = True
                f.reason = sup.reason
                sup.used = True
                break
        out.append(f)
    return out


# --------------------------------------------------------------------- #
# baseline
# --------------------------------------------------------------------- #

BASELINE_NOTE = ("grandfathered findings; regenerate with "
                 "`python -m repro.analysis src/ --write-baseline` "
                 "(see src/repro/analysis/README.md)")


def load_baseline(path: str) -> List[str]:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return []
    return list(data.get("fingerprints", []))


def write_baseline(path: str, findings: Iterable[Finding]) -> List[str]:
    """Persist the fingerprints of all BLOCKING findings (suppressed
    ones stay suppressed in-source; baselining them too would hide a
    later edit that drops the annotation)."""
    fps = sorted({f.fingerprint for f in findings if f.blocking})
    with open(path, "w") as f:
        json.dump({"note": BASELINE_NOTE, "fingerprints": fps}, f, indent=1)
        f.write("\n")
    return fps


def apply_baseline(findings: Iterable[Finding],
                   fingerprints: Iterable[str]) -> List[Finding]:
    known = set(fingerprints)
    out = []
    for f in findings:
        if not f.suppressed and f.fingerprint in known:
            f.baselined = True
        out.append(f)
    return out
