"""Checker 4 — the compiled-artifact audit (``--artifact``).

The Python-level checkers can only see what the SOURCE does; this one
audits what XLA actually built:

* ``artifact-hlo`` — lower the serving cells (prefill + decode, the
  same ``serve_step`` builders the engine jits) for a tiny reduced
  model and scan the HLO text: any ``infeed``/``outfeed``/``send``/
  ``recv`` op means a host round-trip got baked INTO the compiled
  artifact (invisible to the host-sync checker), and any
  ``custom_call_target`` outside the expected allowlist means
  something escaped XLA's scheduler (a stray host callback or debug
  hook serializes the whole entry point).

* ``compile-budget`` — run a tiny engine workload per execution plane
  and assert ``Engine.num_compiles`` against the checked-in budget
  (``compile_budget.json``).  This is PR 2's shape-stability invariant
  as a static gate: a dynamic shape sneaking into an entry point shows
  up as extra distinct compiles long before any perf benchmark does.

Budgets and the custom-call allowlist live in
``src/repro/analysis/compile_budget.json``; regenerate by running with
``REPRO_WRITE_COMPILE_BUDGET=1`` after an intentional change.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List

from repro.analysis.findings import Finding

BUDGET_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "compile_budget.json")

RULE_HLO = "artifact-hlo"
RULE_BUDGET = "compile-budget"

_MODEL = "tinyllama-1.1b"
_PLANES = ("batched", "paged")


def _tiny_engine(plane: str):
    import jax
    from repro.configs import get_config
    from repro.core import (TheoreticalCostModel, get_hardware,
                            make_scheduler)
    from repro.models import model as M
    from repro.serving import Engine, EngineConfig

    cfg = dataclasses.replace(get_config(_MODEL).reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sched = make_scheduler("vllm", 256, S=512, replacement="srf")
    ekw = dict(nslots=4, cache_len=64, chunk=16, plane=plane)
    if plane == "paged":
        ekw.update(page_size=8, cache_policy="lru", cache_demotion=True)
    eng = Engine(cfg, params, sched, EngineConfig(**ekw),
                 cost_model=TheoreticalCostModel(cfg,
                                                 get_hardware("tpu_v5e")))
    return cfg, eng


def _run_tiny(plane: str) -> int:
    from repro.data.workloads import zipf_shared_prefix
    cfg, eng = _tiny_engine(plane)
    eng.run(zipf_shared_prefix(n=10, num_groups=3, page_size=8, seed=3,
                               vocab=cfg.vocab_size))
    return eng.num_compiles


def _lowered_hlo():
    """(name, hlo_text) for the serving cells the engine compiles."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.models import model as M
    from repro.serving import serve_step

    cfg = dataclasses.replace(get_config(_MODEL).reduced(),
                              dtype="float32")
    params = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    out = []
    pf = serve_step.build_prefill_fn(cfg, cache_len=64)
    specs = serve_step.serve_input_specs(
        cfg, ShapeConfig("audit_prefill", 16, 2, "prefill"))
    out.append(("prefill",
                jax.jit(pf).lower(params, specs).as_text()))
    df = serve_step.build_decode_fn(cfg)
    specs = serve_step.serve_input_specs(
        cfg, ShapeConfig("audit_decode", 16, 2, "decode"))
    out.append(("decode",
                jax.jit(df).lower(params, specs["tokens"],
                                  specs["cache"]).as_text()))
    return out


def audit_artifacts(budget_path: str = BUDGET_PATH) -> List[Finding]:
    from repro.launch.hlo_analysis import custom_calls, host_transfer_ops

    findings: List[Finding] = []
    rel = os.path.relpath(budget_path,
                          os.path.join(os.path.dirname(budget_path),
                                       "..", "..", ".."))
    try:
        with open(budget_path) as f:
            budget = json.load(f)
    except FileNotFoundError:
        budget = {}
    write = bool(os.environ.get("REPRO_WRITE_COMPILE_BUDGET"))

    allowed = set(budget.get("allowed_custom_calls", []))
    seen_calls = set()
    for name, hlo in _lowered_hlo():
        transfers = host_transfer_ops(hlo)
        if transfers:
            findings.append(Finding(
                rule=RULE_HLO, path=rel, line=1, col=1, symbol=name,
                message=f"host-transfer ops baked into the lowered "
                        f"{name} artifact: {transfers} — a compiled "
                        f"serving entry point must not round-trip to "
                        f"the host mid-step"))
        calls = custom_calls(hlo)
        seen_calls.update(calls)
        unexpected = sorted(set(calls) - allowed)
        if unexpected and not write:
            findings.append(Finding(
                rule=RULE_HLO, path=rel, line=1, col=1, symbol=name,
                message=f"unexpected custom_call targets in the lowered "
                        f"{name} artifact: {unexpected} (expected "
                        f"subset of {sorted(allowed)}; regenerate "
                        f"{rel} if intentional)"))

    budgets = budget.get("num_compiles", {})
    measured = {}
    for plane in _PLANES:
        n = _run_tiny(plane)
        measured[plane] = n
        cap = budgets.get(plane)
        if cap is None and not write:
            findings.append(Finding(
                rule=RULE_BUDGET, path=rel, line=1, col=1, symbol=plane,
                message=f"no compile budget recorded for plane "
                        f"'{plane}' (measured {n}); set "
                        f"REPRO_WRITE_COMPILE_BUDGET=1 to record"))
        elif cap is not None and n > cap:
            findings.append(Finding(
                rule=RULE_BUDGET, path=rel, line=1, col=1, symbol=plane,
                message=f"plane '{plane}' compiled {n} distinct XLA "
                        f"programs on the audit workload, budget is "
                        f"{cap} — a dynamic shape is leaking into a "
                        f"jitted entry point (PR 2 shape-stability)"))

    if write:
        with open(budget_path, "w") as f:
            json.dump({"note": "compiled-artifact audit budget; "
                               "regenerate with "
                               "REPRO_WRITE_COMPILE_BUDGET=1 "
                               "python -m repro.analysis --artifact",
                       "num_compiles": measured,
                       "allowed_custom_calls": sorted(allowed
                                                      | seen_calls)},
                      f, indent=1)
            f.write("\n")
    return findings
