"""Repo-specific static analysis: recompile hazards, host syncs,
unpriced resource mutations, config mirroring, and (optionally) a
compiled-artifact audit.  Run as ``python -m repro.analysis src/``.
See ``src/repro/analysis/README.md``.
"""
from repro.analysis.findings import Finding  # noqa: F401
from repro.analysis.runner import ALL_RULES, run_paths  # noqa: F401
