"""Model / shape configuration system.

Every assigned architecture gets a ``ModelConfig``; the four input-shape
sets are global (``SHAPES``).  ``reduced()`` produces the CPU-smoke variant
of any config (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ShapeConfig:
    """One (seq_len, global_batch) workload cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | vlm | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int  # query heads (0 => attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    # --- options ---
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    max_context: int = 32768
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    window: int = 0  # sliding-window attention size (0 = full attention)
    # --- modality stub frontends ---
    frontend: str = "none"  # none | patch | frames
    num_patches: int = 0  # VLM: number of image patch embeddings
    # --- numerics / padding ---
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    expert_pad_multiple: int = 16

    # ------------------------------------------------------------------ #
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 64

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim_

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad_multiple)

    @property
    def padded_experts(self) -> int:
        if not self.num_experts:
            return 0
        return pad_to(self.num_experts, self.expert_pad_multiple)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (bounded state)."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------------ #
    def num_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.head_dim_
        p = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            p += self.padded_vocab * d  # lm head
        per_layer = 0
        if self.family != "ssm":
            # attention
            per_layer += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            # in_proj (x,z), conv-ish mix, dt/decay projections, out_proj
            per_layer += d * 2 * di + di * self.ssm_state * 2 + di * d
            per_layer += di * 2  # gates / dt bias
        if self.num_experts:
            e = self.padded_experts
            per_layer += e * (3 * d * self.moe_d_ff) + d * e  # experts+router
            per_layer += self.num_shared_experts * 3 * d * self.moe_d_ff
        else:
            per_layer += 3 * d * self.d_ff  # gated mlp
        per_layer += 2 * d  # norms
        return p + self.num_layers * per_layer

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed top-k)."""
        if not self.num_experts:
            return self.num_params()
        d = self.d_model
        dense = self.num_params() - self.num_layers * self.padded_experts * 3 * d * self.moe_d_ff
        active_moe = self.num_layers * self.experts_per_token * 3 * d * self.moe_d_ff
        return dense + active_moe

    def kv_bytes_per_token_layer(self, bytes_per_el: int = 2) -> int:
        if self.family == "ssm":
            return 0
        return 2 * self.kv_dim * bytes_per_el

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_heads else 0,
            head_dim=16 if self.num_heads else 0,
            d_ff=128,
            vocab_size=256,
            max_context=256,
        )
        if self.num_experts:
            kw.update(num_experts=4, experts_per_token=2, moe_d_ff=32,
                      num_shared_experts=min(self.num_shared_experts, 1),
                      expert_pad_multiple=4)
        if self.ssm_state:
            if self.family == "ssm":  # rwkv: heads*state == d_model
                kw.update(ssm_state=8, ssm_heads=8)
            else:
                kw.update(ssm_state=4, ssm_heads=4)
        if self.window:
            kw.update(window=32)
        if self.num_patches:
            kw.update(num_patches=4)
        kw.update(vocab_pad_multiple=32)
        return replace(self, **kw)


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs  # noqa: F401  (ensures registration ran)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    from repro import configs  # noqa: F401

    return tuple(sorted(_REGISTRY))


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Shapes runnable for this arch (long_500k only for sub-quadratic)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return tuple(out)
