"""RWKV6-7B "Finch" [arXiv:2404.05892] — attention-free, data-dependent decay.

Per-layer time-mix with matrix-valued recurrent state (heads x D x D) and
channel-mix FFN; constant-size state => long_500k decode applies.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,          # attention-free
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    ssm_state=64,         # head_dim of WKV state
    ssm_heads=64,         # 4096 / 64
    ssm_expand=1,
    max_context=524288,
))
