"""Llama-3-8B — the paper's §8 deployment model (S=128K, Figure 14)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    max_context=131072,
))
