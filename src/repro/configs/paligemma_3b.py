"""PaliGemma-3B [arXiv:2407.07726] — SigLIP + gemma backbone (MQA kv=1).

The SigLIP vision tower is a STUB per assignment: ``input_specs()``
provides 256 precomputed patch embeddings of width d_model which are
concatenated in front of the text tokens during prefill.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    frontend="patch",
    num_patches=256,
    max_context=8192,
))
