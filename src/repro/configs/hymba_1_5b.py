"""Hymba-1.5B [arXiv:2411.13676] — parallel attention + mamba heads per layer.

Sliding-window attention (2048) as in the paper's local layers; the fused
attn||SSM head structure is modeled as two parallel branches whose
normalized outputs are averaged.  Meta tokens are omitted (documented in
DESIGN.md §Arch-applicability).  vocab 32001 padded to 32256.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_heads=50,      # d_inner=3200 / head_dim 64
    ssm_expand=2,
    window=2048,
    max_context=524288,
))
