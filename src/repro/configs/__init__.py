"""Config registry: importing this package registers all architectures."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
    get_config,
    list_configs,
    pad_to,
)
from repro.configs import (  # noqa: F401
    starcoder2_3b,
    smollm_360m,
    tinyllama_1_1b,
    qwen3_4b,
    qwen3_moe_30b_a3b,
    qwen2_moe_a2_7b,
    hymba_1_5b,
    paligemma_3b,
    rwkv6_7b,
    musicgen_medium,
    llama2_7b,
    llama3_8b,
)

ASSIGNED_ARCHS = (
    "starcoder2-3b",
    "smollm-360m",
    "tinyllama-1.1b",
    "qwen3-4b",
    "qwen3-moe-30b-a3b",
    "qwen2-moe-a2.7b",
    "hymba-1.5b",
    "paligemma-3b",
    "rwkv6-7b",
    "musicgen-medium",
)
