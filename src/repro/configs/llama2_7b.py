"""Llama-2-7B — the paper's own analysis model (S=4096, Figures 4-13)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    max_context=4096,
))
