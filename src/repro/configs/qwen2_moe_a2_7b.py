"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 4 shared + 60 routed top-4.

60 routed experts are padded to 64 for 16-way expert parallelism; router
logits for the 4 padding experts are fixed at -inf (parity-tested).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=151936,
    num_experts=60,
    experts_per_token=4,
    num_shared_experts=4,
    max_context=8192,
))
