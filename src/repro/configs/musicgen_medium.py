"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

The EnCodec frontend is a STUB: ``input_specs()`` provides token ids in
the 2048-entry codebook (flattened delay-pattern stream) plus optional
precomputed conditioning frame embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="frames",
    max_context=32768,
))
