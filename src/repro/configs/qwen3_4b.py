"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — qk_norm, GQA kv=8, head_dim 128."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    max_context=32768,
))
