"""AdamW + LR schedules (raw-JAX, pytree-native, ZeRO-friendly).

Optimizer state is fp32 (master weights + moments) regardless of the
bf16 compute params; its sharding follows the param sharding (which is
already FSDP over ``data`` in training mode — ZeRO-1 by construction).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray            # ()
    master: Any                  # fp32 params
    mu: Any                      # fp32 first moment
    nu: Any                      # fp32 second moment


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - t)
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def init_adamw(params: Any) -> AdamWState:
    """Every leaf owns a DISTINCT buffer: ``astype(f32)`` of an
    already-fp32 param is a no-op ALIAS, which breaks donating params
    and opt state to the same step ("donate the same buffer twice")."""
    f32_copy = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros_distinct = lambda p: p.astype(jnp.float32) * 0.0
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      master=jax.tree.map(f32_copy, params),
                      mu=jax.tree.map(zeros_distinct, params),
                      nu=jax.tree.map(zeros_distinct, params))


def global_norm(tree: Any) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any) -> Tuple[Any, AdamWState, dict]:
    """Returns (new bf16 params, new state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        m = m - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                      + cfg.weight_decay * m)
        return m, mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.master)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    new = [upd(g, m, mu, nu)
           for g, m, mu, nu in zip(flat_g, flat_m, flat_mu, flat_nu)]
    master = treedef.unflatten([n[0] for n in new])
    mu = treedef.unflatten([n[1] for n in new])
    nu = treedef.unflatten([n[2] for n in new])
    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), master, params)
    new_state = AdamWState(step=step, master=master, mu=mu, nu=nu)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
