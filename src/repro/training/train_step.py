"""Train-step builder: microbatched grad accumulation + remat + AdamW.

``make_train_step(cfg, opt_cfg, microbatches=k)`` returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for jit/pjit.  The global batch is split into k microbatches scanned
sequentially (activation memory / k); gradients accumulate in fp32.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update


def make_loss_fn(cfg: ModelConfig, *, impl: str = "reference",
                 moe_impl: str = "sparse", remat: bool = True,
                 unroll: bool = False) -> Callable:
    def loss_fn(params, batch):
        return M.train_loss(cfg, params, batch, impl=impl,
                            moe_impl=moe_impl, remat=remat, unroll=unroll)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, impl: str = "reference",
                    moe_impl: str = "sparse", remat: bool = True,
                    grad_psum_axis: Optional[str] = None,
                    unroll: bool = False) -> Callable:
    loss_fn = make_loss_fn(cfg, impl=impl, moe_impl=moe_impl, remat=remat,
                           unroll=unroll)
    grad_fn = jax.value_and_grad(loss_fn)

    def split_mb(batch):
        def sp(x):
            B = x.shape[0]
            assert B % microbatches == 0, (B, microbatches)
            return x.reshape(microbatches, B // microbatches, *x.shape[1:])
        return jax.tree.map(sp, batch)

    def train_step(params, opt_state: AdamWState, batch
                   ) -> Tuple[Any, AdamWState, Dict]:
        if microbatches > 1:
            mbs = split_mb(batch)

            def acc_step(carry, mb):
                loss_sum, g_acc = carry
                loss, g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_sum + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(acc_step, (0.0, zeros), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = grad_fn(params, batch)
        if grad_psum_axis:  # shard_map/pmap data-parallel reduction
            grads = jax.lax.pmean(grads, grad_psum_axis)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
