"""Training substrate: AdamW, schedules, microbatched train step."""
from repro.training.optimizer import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    adamw_update,
    global_norm,
    init_adamw,
    lr_at,
)
from repro.training.train_step import make_loss_fn, make_train_step  # noqa: F401
