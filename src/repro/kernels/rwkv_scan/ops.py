"""Jitted wrapper for the chunked WKV6 kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_scan.rwkv_scan import wkv6_chunked


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool | None = None):
    """r,k,v,w (B,S,H,D); u (H,D) -> (y fp32, S_last (B,H,D,D))."""
    if interpret is None:
        interpret = _on_cpu()
    f32 = lambda t: t.astype(jnp.float32)
    return wkv6_chunked(f32(r), f32(k), f32(v), f32(w), f32(u),
                        chunk=chunk, interpret=interpret)
