"""Pure-jnp oracle for the WKV6 recurrence: naive per-token scan."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wkv6_reference(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   w: jnp.ndarray, u: jnp.ndarray,
                   s0: jnp.ndarray | None = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w: (B,S,H,D) fp32 (w in (0,1)); u: (H,D); s0: (B,H,D,D).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t S_{t-1} + (r_t . u . k_t) v_t
    Returns (y (B,S,H,D), S_last (B,H,D,D)).
    """
    B, S, H, D = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, D, D), jnp.float32)

    def step(Sm, xs):
        rt, kt, vt, wt = xs  # (B,H,D) each
        y = jnp.einsum("bhd,bhde->bhe", rt, Sm)
        y = y + jnp.einsum("bhd,bhd->bh", rt * u[None], kt)[..., None] * vt
        Sn = wt[..., None] * Sm + jnp.einsum("bhd,bhe->bhde", kt, vt)
        return Sn, y

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))  # (S,B,H,D)
    S_last, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), S_last
