"""Chunked WKV6 recurrence (Pallas, TPU target).

Grid = (B, H, num_chunks); the chunk axis is innermost/sequential, so the
per-(batch, head) fp32 state S (D x D) lives in VMEM scratch across chunks.
Within a chunk of Q steps the recurrence is evaluated in closed form
(GLA-style):

    y_t  = (r_t . W_{t-1}) S_0 + sum_{s<t} <r_t . W_{t-1}/W_s, k_s> v_s
           + <r_t . u, k_t> v_t
    S_Q  = diag(W_Q) S_0 + sum_s diag(W_Q / W_s) k_s^T v_s

where W_t = prod_{s<=t} w_s (per channel, cumulative within chunk).  All
contractions are (Q,D)x(D,D) / (Q,Q)x(Q,D) MXU matmuls instead of S
sequential rank-1 updates — this is the TPU adaptation of the CUDA
wkv kernel (which parallelizes over channels, not time).

VMEM per instance (Q=64, D=64): 4 inputs x 16 KB + S 16 KB + intra 16 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref,
                s_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    rq = r_ref[0, 0, 0].astype(jnp.float32)          # (Q, D)
    kq = k_ref[0, 0, 0].astype(jnp.float32)
    vq = v_ref[0, 0, 0].astype(jnp.float32)
    wq = w_ref[0, 0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # (D,)

    logw = jnp.log(wq)
    logW = jnp.cumsum(logw, axis=0)               # (Q, D)
    W = jnp.exp(logW)
    Wm1 = jnp.exp(logW - logw)                    # W_{t-1}

    S0 = s_ref[...]                                # (D, D)
    rW = rq * Wm1
    y = jax.lax.dot_general(rW, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    att = jax.lax.dot_general(rW, kq / W, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, att.shape, 0)
    si = jax.lax.broadcasted_iota(jnp.int32, att.shape, 1)
    att = jnp.where(qi > si, att, 0.0)            # strictly lower triangular
    y = y + jax.lax.dot_general(att, vq, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    diag = jnp.sum(rq * u[None, :] * kq, axis=1, keepdims=True)
    y = y + diag * vq
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    WQ = W[-1]                                     # (D,)
    S_new = WQ[:, None] * S0 + jax.lax.dot_general(
        kq * (WQ[None, :] / W), vq, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = S_new

    @pl.when(ic == nc - 1)
    def _finish():
        s_out_ref[0, 0] = S_new


def wkv6_chunked(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w (B,S,H,D); u (H,D) -> (y (B,S,H,D) fp32, S_last (B,H,D,D))."""
    B, S, H, D = r.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    def idx(b, h, ic):
        return (b, h, ic, 0)

    # reshape time into (nc, Q) so BlockSpec can slice chunks
    def chunked(t):
        return t.reshape(B, nc, Q, H, D).transpose(0, 3, 1, 2, 4)  # (B,H,nc,Q,D)

    rc, kc, vc, wc = map(chunked, (r, k, v, w))
    kernel = functools.partial(_wkv_kernel, chunk=Q)
    y, s_last = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h, ic: (b, h, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h, ic: (b, h, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h, ic: (b, h, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h, ic: (b, h, ic, 0, 0)),
            pl.BlockSpec((1, D), lambda b, h, ic: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h, ic: (b, h, ic, 0, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rc, kc, vc, wc, u)
    y = y.transpose(0, 2, 3, 1, 4).reshape(B, S, H, D)
    return y, s_last
