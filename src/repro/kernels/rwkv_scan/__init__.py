from repro.kernels.rwkv_scan import ops, ref  # noqa: F401
from repro.kernels.rwkv_scan.ops import wkv6  # noqa: F401
