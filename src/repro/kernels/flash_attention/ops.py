"""Jitted wrapper: (B,S,H,D) layout, padding, interpret-mode switch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "prefix_len",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, prefix_len: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q (B,S,H,D); k,v (B,S,Hkv,D) -> (B,S,H,D).

    Pads S to a block multiple (padded queries attend only to themselves via
    the causal mask and are cropped after).
    """
    if interpret is None:
        interpret = _on_cpu()
    B, S, H, D = q.shape
    bq = min(block_q, max(16, 1 << (S - 1).bit_length()))
    bk = min(block_k, bq)
    Sp = ((S + bq - 1) // bq) * bq
    if Sp != S:
        pad = [(0, 0), (0, Sp - S), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        prefix_len=prefix_len, block_q=bq, block_k=bk, interpret=interpret)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :S] if Sp != S else out
