"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        prefix_len: int = 0) -> jnp.ndarray:
    """q (B,S,H,D); k,v (B,S,Hkv,D) -> (B,S,H,D). fp32 softmax."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = qp >= kp
        if window:
            mask &= (qp - kp) < window
        if prefix_len:
            mask |= kp < prefix_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(B, S, H, D)
