"""Blocked causal flash attention (Pallas, TPU target).

Grid = (B, H, num_q_blocks, num_kv_blocks); the kv-block axis is the
innermost (sequential on TPU), so the fp32 running max / sum / accumulator
live in VMEM scratch and persist across kv steps.  Causal block skipping is
done with ``pl.when`` (whole kv blocks above the diagonal are never
touched, halving FLOPs and HBM traffic).  GQA is expressed in the
BlockSpec index maps (kv head = q head // group).

VMEM per instance (bq=bk=128, D=128):
  q 64 KB (fp32) + k,v 2x32 KB (bf16) + acc/m/l ~65 KB  <<  16 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, block_q: int, block_k: int, seq_len: int,
               window: int, prefix_len: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = iq * block_q                  # first q position of this block
    k_first = ik * block_k
    # visible iff causal-visible for SOME pair in the block:
    #   k_first <= q_last  and (window: q_first - k_last < window)
    run = True
    if causal:
        run = k_first <= q_first + block_q - 1
        if window:
            in_window = (q_first - (k_first + block_k - 1)) < window
            in_prefix = k_first < prefix_len
            run = run & (in_window | in_prefix)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qp = q_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = qp >= kp
            if window:
                mask &= (qp - kp) < window
            if prefix_len:
                mask |= kp < prefix_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, window: int = 0,
                         prefix_len: int = 0,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jnp.ndarray:
    """q (B,H,S,D); k,v (B,Hkv,S,D) -> (B,H,S,D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=S, window=window, prefix_len=prefix_len, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            # fp32 accumulators persisted across the kv grid axis
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
