"""Jitted wrappers for paged attention (decode + fused prefill).

``paged_decode`` / ``paged_prefill`` are the engine's entry points
(``plane="paged"``): on TPU they run the Pallas kernels
(scalar-prefetched block tables, page-granular DMA, prefill writing the
chunk's K/V straight into the pools); on CPU they lower to jit-friendly
jnp block-table gathers (``ref``) instead of interpret-mode Pallas —
the interpreter re-traces per grid instance and would dominate the
offline suite's wall time.  Both backends read the SAME pooled layout
``(num_pages, page_size, Hkv, D)`` through the same tables."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import (paged_decode_bhd,
                                                           paged_prefill_bhd)
from repro.kernels.paged_attention.ref import (paged_decode_reference,
                                               paged_prefill_reference)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def paged_decode(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                 block_tables: jnp.ndarray,
                 context_lens: jnp.ndarray) -> jnp.ndarray:
    """Backend-dispatched paged decode: q (B,H,D); pools
    (P, page, Hkv, D); block_tables (B, npages) int32; context_lens (B,)
    -> (B,H,D).  Safe to call inside an enclosing jit (the backend check
    is trace-time static)."""
    if _on_cpu():
        return paged_decode_reference(q, k_pool, v_pool,
                                      block_tables.astype(jnp.int32),
                                      context_lens.astype(jnp.int32))
    return paged_decode_attention(q, k_pool, v_pool, block_tables,
                                  context_lens, interpret=False)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                           context_lens: jnp.ndarray, *,
                           interpret: bool | None = None) -> jnp.ndarray:
    """q (B,H,D); pools (P, page, Hkv, D); block_tables (B, npages) int32;
    context_lens (B,) int32 -> (B,H,D)."""
    if interpret is None:
        interpret = _on_cpu()
    B, H, D = q.shape
    Hkv = k_pool.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    out = paged_decode_bhd(qg, k_pool, v_pool,
                           block_tables.astype(jnp.int32),
                           context_lens.astype(jnp.int32),
                           interpret=interpret)
    return out.reshape(B, H, D)


def paged_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                  block_tables: jnp.ndarray, starts: jnp.ndarray,
                  lengths: jnp.ndarray):
    """Backend-dispatched fused paged prefill: q (B,c,H,D); k/v
    (B,c,Hkv,D); pools (P, page, Hkv, D); block_tables (B, maxp) int32;
    starts/lengths (B,) -> (out (B,c,H,D), new_k_pool, new_v_pool).
    Writes the chunk's rows into the pools (padded rows drop) and
    attends causally over [own pages ++ chunk].  Safe inside an
    enclosing jit (the backend check is trace-time static)."""
    if _on_cpu():
        return paged_prefill_reference(q, k, v, k_pool, v_pool,
                                       block_tables.astype(jnp.int32),
                                       starts.astype(jnp.int32),
                                       lengths.astype(jnp.int32))
    return paged_prefill_attention(q, k, v, k_pool, v_pool, block_tables,
                                   starts, lengths, interpret=False)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                            block_tables: jnp.ndarray, starts: jnp.ndarray,
                            lengths: jnp.ndarray, *,
                            interpret: bool | None = None):
    """Pallas-kernel entry: q (B,c,H,D); k/v (B,c,Hkv,D); pools
    (P, page, Hkv, D) -> (out (B,c,H,D), new_k_pool, new_v_pool)."""
    if interpret is None:
        interpret = _on_cpu()
    B, c, H, D = q.shape
    Hkv = k_pool.shape[2]
    G = H // Hkv
    qg = (q.reshape(B, c, Hkv, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(B, Hkv, c * G, D))
    out, new_k, new_v = paged_prefill_bhd(
        qg, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        k_pool, v_pool, block_tables.astype(jnp.int32),
        starts.astype(jnp.int32), lengths.astype(jnp.int32),
        interpret=interpret)
    out = (out.reshape(B, Hkv, c, G, D).transpose(0, 2, 1, 3, 4)
           .reshape(B, c, H, D))
    return out, new_k, new_v


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def decode_attention_dense(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           context_lens: jnp.ndarray, *, page_size: int = 64,
                           interpret: bool | None = None) -> jnp.ndarray:
    """Dense-cache decode through the paged kernel: the contiguous cache
    (B, S, Hkv, D) is viewed as a page pool with identity block tables.
    q (B,H,D) -> (B,H,D)."""
    B, S, Hkv, D = k.shape
    assert S % page_size == 0, (S, page_size)
    npages = S // page_size
    k_pool = k.reshape(B * npages, page_size, Hkv, D)
    v_pool = v.reshape(B * npages, page_size, Hkv, D)
    block_tables = (jnp.arange(B)[:, None] * npages +
                    jnp.arange(npages)[None, :]).astype(jnp.int32)
    return paged_decode_attention(q, k_pool, v_pool, block_tables,
                                  context_lens, interpret=interpret)
