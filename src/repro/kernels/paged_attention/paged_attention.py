"""Paged decode attention (Pallas, TPU target) — flash-decoding over pages.

One grid instance per (batch, kv-head, page): the page index comes from the
*scalar-prefetched* block table (``PrefetchScalarGridSpec``), i.e. the
BlockSpec index_map dereferences ``block_tables[b, ip]`` — the TPU DMA
engine streams exactly the pages owned by the request, never the whole
pool.  The fp32 (acc, m, l) scratch persists across the page axis
(innermost, sequential on TPU); pages beyond ``context_len`` are skipped
with ``pl.when`` so short requests cost O(their length), which is exactly
the ``m``-linear decode cost the paper's cost model assumes.

This is the TPU-native adaptation of vLLM's CUDA PagedAttention: instead
of a warp-per-token gather, pages are DMA'd as (page_size, D) VMEM tiles
and the G=H/Hkv query heads of a kv head are batched into a single
(G, page_size) MXU matmul.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(block_tables_ref, context_lens_ref,  # scalar prefetch
               q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *,
               page_size: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = context_lens_ref[b]
    in_range = ip * page_size < ctx

    @pl.when(in_range)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, page)
        pos = ip * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < ctx, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == npages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_bhd(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                     block_tables: jnp.ndarray, context_lens: jnp.ndarray, *,
                     interpret: bool = False) -> jnp.ndarray:
    """q (B, Hkv, G, D); pools (P, page, Hkv, D) -> out (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    P, page, _, _ = k_pool.shape
    npages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_pa_kernel, page_size=page, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, npages),
        in_specs=[
            # q: all G heads of this kv head
            pl.BlockSpec((1, 1, G, D), lambda b, h, ip, bt, cl: (b, h, 0, 0)),
            # k/v page selected through the block table
            pl.BlockSpec((1, page, 1, D), lambda b, h, ip, bt, cl: (bt[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D), lambda b, h, ip, bt, cl: (bt[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ip, bt, cl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pool, v_pool)
