"""Paged attention kernels (Pallas, TPU target) — flash reduction over pages.

Two ops share the pooled ``(num_pages, page_size, Hkv, D)`` layout and
the scalar-prefetched block-table addressing:

* ``paged_decode_bhd`` — flash-decoding, one token per row.
* ``paged_prefill_bhd`` — the FUSED chunked-prefill step: per grid
  instance it (a) overlays the chunk's K/V rows onto the owned page it
  is visiting (one-hot MXU matmul, written back through
  ``input_output_aliases`` so the pools update in place), (b) streams
  that page's PRIOR rows (pos < chunk start) through the online-softmax
  reduction, and (c) folds the chunk's own rows in causally from the
  operands on the last page step.  Nothing like the old
  ``k_pool[block_tables]`` transient ``(B, max_pages*page, Hkv, D)``
  gather is ever materialized — HBM traffic is the owned pages once,
  plus one page-sized write per page the chunk lands on.

One grid instance per (batch, kv-head, page): the page index comes from the
*scalar-prefetched* block table (``PrefetchScalarGridSpec``), i.e. the
BlockSpec index_map dereferences ``block_tables[b, ip]`` — the TPU DMA
engine streams exactly the pages owned by the request, never the whole
pool.  The fp32 (acc, m, l) scratch persists across the page axis
(innermost, sequential on TPU); pages beyond ``context_len`` are skipped
with ``pl.when`` so short requests cost O(their length), which is exactly
the ``m``-linear decode cost the paper's cost model assumes.

This is the TPU-native adaptation of vLLM's CUDA PagedAttention: instead
of a warp-per-token gather, pages are DMA'd as (page_size, D) VMEM tiles
and the G=H/Hkv query heads of a kv head are batched into a single
(G, page_size) MXU matmul.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(block_tables_ref, context_lens_ref,  # scalar prefetch
               q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *,
               page_size: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = context_lens_ref[b]
    in_range = ip * page_size < ctx

    @pl.when(in_range)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, page)
        pos = ip * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < ctx, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == npages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _pp_kernel(block_tables_ref, starts_ref, lengths_ref,  # scalar prefetch
               q_ref, kc_ref, vc_ref, kp_ref, vp_ref,
               o_ref, nkp_ref, nvp_ref,
               acc_ref, m_ref, l_ref, *,
               page_size: int, chunk: int, groups: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    maxp = pl.num_programs(2)
    pg = page_size
    start = starts_ref[b]
    ln = lengths_ref[b]
    # Pages the row genuinely owns after this chunk (>= 1 so the
    # redirected index below is always a live page of THIS row).  The
    # pool index_map re-aims every garbage tail entry (ip >= np_owned)
    # at the LAST owned page: consecutive grid steps then revisit the
    # same block index, which the pipeline treats as one resident block
    # (no refetch, one copy-out) — a tail step recomputes the identical
    # overlay instead of flushing stale bytes over a fresh write.
    np_owned = jnp.maximum((start + ln + pg - 1) // pg, 1)
    ipe = jnp.minimum(ip, np_owned - 1)
    base = ipe * pg

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---- write: overlay the chunk rows that land in page `ipe` ----
    # chunk row r sits at absolute position start + r; it lands in this
    # page at slot j iff base + j == start + r (and r is a real row).
    # One-hot matmul keeps the page update branch-free on the MXU;
    # rows no chunk row maps to keep their prior content.
    kc = kc_ref[0, 0].astype(jnp.float32)               # (c, D)
    vc = vc_ref[0, 0].astype(jnp.float32)
    jidx = jax.lax.broadcasted_iota(jnp.int32, (pg, chunk), 0)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (pg, chunk), 1)
    hit = ((base + jidx == start + ridx)
           & (ridx < ln)).astype(jnp.float32)           # (pg, c)
    keep = 1.0 - jnp.sum(hit, axis=1, keepdims=True)    # (pg, 1)
    k_old = kp_ref[0, :, 0, :].astype(jnp.float32)      # (pg, D)
    v_old = vp_ref[0, :, 0, :].astype(jnp.float32)
    nkp_ref[0, :, 0, :] = (keep * k_old + hit @ kc).astype(nkp_ref.dtype)
    nvp_ref[0, :, 0, :] = (keep * v_old + hit @ vc).astype(nvp_ref.dtype)

    q2 = q_ref[0, 0].astype(jnp.float32) * scale        # (c*G, D)

    def _accum(s, vv):
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # ---- attend over prior pages: only rows written by PREVIOUS
    # chunks (pos < start) are live history; later slots of the last
    # page are stale until the overlay above lands, and the chunk's own
    # rows arrive from the kc/vc operands in the final step instead.
    @pl.when(ip * pg < start)
    def _pages():
        s = jax.lax.dot_general(q2, k_old, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos_k = ip * pg + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _accum(jnp.where(pos_k < start, s, NEG_INF), v_old)

    @pl.when(ip == maxp - 1)
    def _chunk_and_finish():
        s = jax.lax.dot_general(q2, kc, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (c*G, c)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // groups
        rj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _accum(jnp.where((rj <= qi) & (rj < ln), s, NEG_INF), vc)
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_prefill_bhd(q: jnp.ndarray, kc: jnp.ndarray, vc: jnp.ndarray,
                      k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                      block_tables: jnp.ndarray, starts: jnp.ndarray,
                      lengths: jnp.ndarray, *,
                      interpret: bool = False
                      ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused paged-prefill: gather-write-attend in ONE pass.

    q (B, Hkv, c*G, D) — the chunk's queries, head-grouped; kc/vc
    (B, Hkv, c, D) — the chunk's new K/V rows; pools (P, page, Hkv, D).
    Returns (out (B, Hkv, c*G, D), new_k_pool, new_v_pool); the pools
    are updated IN PLACE (``input_output_aliases``) — only pages the
    block tables own are touched, every other page keeps its bytes.
    """
    B, Hkv, cG, D = q.shape
    c = kc.shape[2]
    G = cG // c
    P, page, _, _ = k_pool.shape
    maxp = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    def pool_idx(b, h, ip, bt, st, ln):
        np_owned = jnp.maximum((st[b] + ln[b] + page - 1) // page, 1)
        return (bt[b, jnp.minimum(ip, np_owned - 1)], 0, h, 0)

    kernel = functools.partial(_pp_kernel, page_size=page, chunk=c,
                               groups=G, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, cG, D), lambda b, h, ip, bt, st, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, ip, bt, st, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, ip, bt, st, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), pool_idx),
            pl.BlockSpec((1, page, 1, D), pool_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cG, D), lambda b, h, ip, bt, st, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), pool_idx),
            pl.BlockSpec((1, page, 1, D), pool_idx),
        ],
        scratch_shapes=[
            pltpu.VMEM((cG, D), jnp.float32),
            pltpu.VMEM((cG, 1), jnp.float32),
            pltpu.VMEM((cG, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, Hkv, cG, D), q.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ),
        # flattened operand order: bt(0) st(1) ln(2) q(3) kc(4) vc(5)
        # k_pool(6) v_pool(7); pools alias outputs 1/2 (in-place update)
        input_output_aliases={6: 1, 7: 2},
        interpret=interpret,
    )(block_tables, starts, lengths, q, kc, vc, k_pool, v_pool)


def paged_decode_bhd(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                     block_tables: jnp.ndarray, context_lens: jnp.ndarray, *,
                     interpret: bool = False) -> jnp.ndarray:
    """q (B, Hkv, G, D); pools (P, page, Hkv, D) -> out (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    P, page, _, _ = k_pool.shape
    npages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_pa_kernel, page_size=page, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, npages),
        in_specs=[
            # q: all G heads of this kv head
            pl.BlockSpec((1, 1, G, D), lambda b, h, ip, bt, cl: (b, h, 0, 0)),
            # k/v page selected through the block table
            pl.BlockSpec((1, page, 1, D), lambda b, h, ip, bt, cl: (bt[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D), lambda b, h, ip, bt, cl: (bt[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ip, bt, cl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pool, v_pool)
