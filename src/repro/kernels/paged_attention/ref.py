"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_decode_reference(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                           context_lens: jnp.ndarray) -> jnp.ndarray:
    """q (B,H,D); pools (P, page, Hkv, D); block_tables (B, npages);
    context_lens (B,) -> out (B,H,D)."""
    B, H, D = q.shape
    page = k_pool.shape[1]
    Hkv = k_pool.shape[2]
    G = H // Hkv
    npages = block_tables.shape[1]
    S = npages * page
    k = k_pool[block_tables].reshape(B, S, Hkv, D)  # gather pages
    v = v_pool[block_tables].reshape(B, S, Hkv, D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    valid = jnp.arange(S)[None] < context_lens[:, None]  # (B, S)
    s = jnp.where(valid[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w.astype(v.dtype), v)
    return out.reshape(B, H, D)
