"""Pure-jnp oracles for paged attention (decode + fused prefill).

``paged_prefill_reference`` is ALSO the engine's CPU lowering: it is the
gather-write-attend formulation the paged plane used inline before the
fused kernel existed (PR 8), kept bit-for-bit so token-identity
contracts against the batched plane hold on the CPU backend, and so the
Pallas kernel has an oracle to parity-test against in interpret mode.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def paged_decode_reference(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                           context_lens: jnp.ndarray) -> jnp.ndarray:
    """q (B,H,D); pools (P, page, Hkv, D); block_tables (B, npages);
    context_lens (B,) -> out (B,H,D)."""
    B, H, D = q.shape
    page = k_pool.shape[1]
    Hkv = k_pool.shape[2]
    G = H // Hkv
    npages = block_tables.shape[1]
    S = npages * page
    k = k_pool[block_tables].reshape(B, S, Hkv, D)  # gather pages
    v = v_pool[block_tables].reshape(B, S, Hkv, D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    valid = jnp.arange(S)[None] < context_lens[:, None]  # (B, S)
    s = jnp.where(valid[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w.astype(v.dtype), v)
    return out.reshape(B, H, D)


def scatter_rows(pool: jnp.ndarray, dest: jnp.ndarray,
                 rows: jnp.ndarray) -> jnp.ndarray:
    """Write rows into a (P, page, Hkv, D) pool at flat token positions
    ``dest`` (OOB = drop).  rows (..., Hkv, D); dest (...,) int32."""
    P, pg, Hkv, D = pool.shape
    flat = pool.reshape(P * pg, Hkv, D)
    flat = flat.at[dest.reshape(-1)].set(
        rows.reshape(-1, Hkv, D), mode="drop")
    return flat.reshape(P, pg, Hkv, D)


def paged_prefill_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                            block_tables: jnp.ndarray, starts: jnp.ndarray,
                            lengths: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather-write-attend oracle for the fused prefill kernel.

    q (B, c, H, D); k/v (B, c, Hkv, D) — the chunk's projected rows;
    pools (P, page, Hkv, D); block_tables (B, maxp); starts/lengths (B,).
    Returns (attn out (B, c, H, D), new_k_pool, new_v_pool).

    Table slot j covers absolute positions [j*page, (j+1)*page), so the
    gathered per-row view IS position order — the chunk is written in
    place and attended causally, exactly the dense plane's
    write-then-attend (same buffer width and reduction order, so the
    math matches that plane bit-for-bit; stale rows beyond each query's
    position never enter the mask).  Padded rows (index >= length)
    route out of bounds and drop — pool bytes of other requests are
    untouchable by construction.
    """
    B, c, H, D = q.shape
    P, pg, Hkv, _ = k_pool.shape
    maxp = block_tables.shape[1]
    Smax = maxp * pg
    G = H // Hkv
    positions = starts[:, None] + jnp.arange(c)[None, :]        # (B, c)
    valid = jnp.arange(c)[None, :] < lengths[:, None]           # (B, c)

    kg = k_pool[block_tables].reshape(B, Smax, Hkv, D)
    vg = v_pool[block_tables].reshape(B, Smax, Hkv, D)
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, c))
    loc = jnp.where(valid, positions, Smax)                     # OOB drop
    kg = kg.at[rows, loc].set(k, mode="drop")
    vg = vg.at[rows, loc].set(v, mode="drop")

    mask = jnp.arange(Smax)[None, None, :] <= positions[:, :, None]
    qg = q.reshape(B, c, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kg,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(vg.dtype), vg)
    out = out.reshape(B, c, H, D)

    page_idx = jnp.take_along_axis(
        block_tables, jnp.clip(positions // pg, 0, maxp - 1), axis=1)
    dest = jnp.where(valid, page_idx * pg + positions % pg, P * pg)
    return out, scatter_rows(k_pool, dest, k), scatter_rows(v_pool, dest, v)
