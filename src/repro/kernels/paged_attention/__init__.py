from repro.kernels.paged_attention import ops, ref  # noqa: F401
from repro.kernels.paged_attention.ops import (  # noqa: F401
    decode_attention_dense,
    paged_decode_attention,
    paged_prefill_attention,
)
