"""Online I->O histogram for SRF+Hist (paper §8).

Buckets input lengths by log2; tracks a running mean of observed output
lengths per bucket.  ``predict`` falls back to the global mean, then to a
prior, for unseen buckets.
"""
from __future__ import annotations

import math
from typing import Dict


class OutputLengthHistogram:
    def __init__(self, prior: float = 256.0):
        self.prior = prior
        self.sum: Dict[int, float] = {}
        self.count: Dict[int, int] = {}
        self.global_sum = 0.0
        self.global_count = 0

    @staticmethod
    def _bucket(input_len: int) -> int:
        return max(0, int(math.log2(max(1, input_len))))

    def observe(self, input_len: int, output_len: int) -> None:
        b = self._bucket(input_len)
        self.sum[b] = self.sum.get(b, 0.0) + output_len
        self.count[b] = self.count.get(b, 0) + 1
        self.global_sum += output_len
        self.global_count += 1

    def predict(self, input_len: int) -> float:
        b = self._bucket(input_len)
        if self.count.get(b, 0) >= 3:
            return self.sum[b] / self.count[b]
        if self.global_count >= 3:
            return self.global_sum / self.global_count
        return self.prior
