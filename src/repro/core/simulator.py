"""InferMax-style simulation loop (paper Fig. 1, blue boxes).

Drives the unified ``Scheduler`` (Algorithm 1) with a ``CostModel``
instead of GPUs: each batch advances virtual time by the model's predicted
batch time.  Produces the metrics of §5.1 (latency, TTFT, TPOT, TPS),
preemption counts, and per-batch logs (memory usage, batch size) used by
every multi-batch figure (9, 11, 12, 14, App. A-D).

``PrefixTierSim`` is the virtual-time shadow of the paged engine's
two-tier prefix cache (§6 replacement policy + host demotion): it runs
the SAME ``PagedAllocator`` control plane and the same ``KVSwapStore``
host-tier bookkeeping (metadata-only — no bytes move) at the same points
of the batch loop, so demotion/promotion counts and their ``swap_time``
charges match the serving engine batch-for-batch on identical schedules
(the demotion/promotion parity test pins this).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cost_model import BatchSpec, CostModel
from repro.core.kvcache import PagedAllocator, PrefixCache, attach_prefix_run
from repro.core.policies import make_replacement_policy
from repro.core.request import Phase, Request
from repro.core.scheduler import Batch, Scheduler, SchedulerConfig


@dataclass
class BatchLog:
    t_start: float
    t_end: float
    num_prefill: int
    num_decode: int
    tokens: int
    kv_used: int
    preempted: int
    swapped_out: int = 0        # victims suspended to host this batch
    swapped_in: int = 0         # suspended requests restored this batch
    swap_s: float = 0.0         # host-link time charged (in + out)
    wall_s: float = 0.0         # measured wall time (engine only; the
    #                             simulator advances virtual time and
    #                             leaves this 0)
    pages_used: int = 0         # physical pages live in the pool after
    #                             this batch (paged engine only; counts
    #                             shared pages once — the dedup signal)


@dataclass
class SimResult:
    requests: List[Request]
    batches: List[BatchLog] = field(default_factory=list)
    num_preemptions: int = 0    # full + partial (page-level) preemptions
    num_partial_preempts: int = 0
    num_swaps: int = 0
    # prefix-cache tier counters when a PrefixTierSim shadow ran
    # (promotions/demotions/charges + the shadow allocator's stats)
    prefix_stats: Dict[str, float] = field(default_factory=dict)

    # --- aggregate metrics (§5.1) -------------------------------------- #
    @property
    def makespan(self) -> float:
        return max((b.t_end for b in self.batches), default=0.0)

    @property
    def latency(self) -> float:
        """End-to-end latency: time until the LAST request finishes."""
        return max((r.finish_time or 0.0) for r in self.requests)

    @property
    def mean_latency(self) -> float:
        ls = [r.latency() for r in self.requests if r.latency() is not None]
        return sum(ls) / len(ls) if ls else 0.0

    @property
    def mean_ttft(self) -> float:
        ts = [r.ttft() for r in self.requests if r.ttft() is not None]
        return sum(ts) / len(ts) if ts else 0.0

    @property
    def max_ttft(self) -> float:
        ts = [r.ttft() for r in self.requests if r.ttft() is not None]
        return max(ts) if ts else 0.0

    @property
    def mean_tpot(self) -> float:
        ts = [r.tpot() for r in self.requests if r.tpot() is not None]
        return sum(ts) / len(ts) if ts else 0.0

    @property
    def tps(self) -> float:
        tok = sum(r.generated for r in self.requests)
        return tok / self.makespan if self.makespan else 0.0

    @property
    def mean_batch_size(self) -> float:
        bs = [b.num_prefill + b.num_decode for b in self.batches]
        return sum(bs) / len(bs) if bs else 0.0

    @property
    def mean_kv_used(self) -> float:
        ks = [b.kv_used for b in self.batches]
        return sum(ks) / len(ks) if ks else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "latency": self.latency,
            "mean_latency": self.mean_latency,
            "mean_ttft": self.mean_ttft,
            "max_ttft": self.max_ttft,
            "mean_tpot": self.mean_tpot,
            "tps": self.tps,
            "preemptions": float(self.num_preemptions),
            "swaps": float(self.num_swaps),
            "batches": float(len(self.batches)),
            "mean_batch_size": self.mean_batch_size,
            "mean_kv_used": self.mean_kv_used,
        }


def _spec_of(batch: Batch) -> BatchSpec:
    spec = BatchSpec()
    for r, c in batch.items:
        # phase *before* processing: decode iff exactly one token to go
        # and at least one token already generated.  resident_kv prices a
        # swap-resumed request against its restored context, not m=0.
        if r.generated > 0 and r.remaining_prefill == c == 1:
            spec.decodes.append((c, r.resident_kv))
        else:
            spec.prefills.append((c, r.resident_kv))
    return spec


class PrefixTierSim:
    """Virtual-time shadow of the paged engine's two-tier prefix cache.

    Runs the engine's EXACT control plane — the same ``PagedAllocator``
    (same replacement policy, same eviction/demotion hook) and the same
    ``KVSwapStore`` host-tier bookkeeping with metadata-only entries
    (``kv=None``; ``page_nbytes`` stands in for the real snapshot size,
    which for the engine is ``2 * L * page * Hkv * D * itemsize``) — at
    the same points of the batch loop.  Requests therefore need real
    ``prompt`` token ids.  Promotions and demotions charge
    ``cost_model.swap_time`` into the batch being priced, exactly like
    the engine, so on identical schedules the two sides agree
    batch-for-batch on counts AND on virtual time.

    Pass one to :func:`simulate`; read ``stats`` / ``alloc.stats`` (or
    ``SimResult.prefix_stats``) afterwards.  Use ``host_bytes=None``
    (unbounded) unless you replicate the engine's suspend traffic in the
    same store — the byte budget there is shared with swap entries.
    """

    def __init__(self, scfg: SchedulerConfig, cost_model: CostModel, *,
                 page_nbytes: int, host_bytes: Optional[int] = None):
        from repro.serving.swap_store import KVSwapStore
        pg = scfg.page_size
        assert pg > 1, "prefix-tier shadow needs page_size > 1"
        self.pg = pg
        self.cm = cost_model
        self.demotion = bool(scfg.cache_demotion)
        self.page_nbytes = int(page_nbytes)
        self.store = KVSwapStore(capacity_bytes=host_bytes)
        self.alloc = PagedAllocator(
            max(1, -(-scfg.M // pg)), pg,
            policy=make_replacement_policy(scfg.cache_policy,
                                           cost_model=cost_model,
                                           M=scfg.M),
            on_evict=self._demote if self.demotion else None)
        self.pending_s = 0.0      # tier charges owed to the current batch
        self.stats: Dict[str, float] = dict(
            promotions=0, demotions=0, demote_drops=0,
            kv_promoted=0, kv_demoted=0, tier_swap_s=0.0)
        self._keys: Dict[int, List[int]] = {}
        self._ptoks: Dict[int, List[Tuple[int, ...]]] = {}

    def _demote(self, key: int, page: int, tokens, n_kvs: int) -> None:
        from repro.serving.swap_store import SwapStoreFullError
        if self.store.has_prefix(key):
            return
        try:
            self.store.put_prefix(key, tokens, n_kvs, None,
                                  nbytes=self.page_nbytes)
        except SwapStoreFullError:
            self.stats["demote_drops"] += 1
            return
        self.pending_s += self.cm.swap_time(self.pg)
        self.stats["demotions"] += 1
        self.stats["kv_demoted"] += self.pg

    def _chain(self, r: Request):
        keys = self._keys.get(r.rid)
        if keys is None:
            assert r.prompt is not None, \
                f"prefix-tier shadow needs real prompts (rid {r.rid})"
            keys = PrefixCache.chain_keys(r.prompt, self.pg)
            self._keys[r.rid] = keys
            self._ptoks[r.rid] = [
                tuple(r.prompt[i * self.pg:(i + 1) * self.pg])
                for i in range(len(keys))]
        return keys, self._ptoks[r.rid]

    # --- batch-loop hooks (mirror serving.engine.Engine.step) ---------- #
    def begin(self, now: float) -> None:
        self.alloc.now = now

    def preempts(self, batch: Batch) -> None:
        for r, npg, _, _ in batch.partial_preempted:
            if r.running:       # folded sheds free with the full preempt
                self.alloc.free_tail(r.rid, npg)  # repro: allow-unpriced-mutation(shadow replay of the engine shed; the scheduler already priced the preemption swap_time when it chose the victim)
        for v in batch.preempted:
            self.alloc.free(v.rid)  # repro: allow-unpriced-mutation(shadow replay of engine _release; freeing moves no bytes and the preemption was priced at victim selection)

    def swap_restores(self, swapped_in, tail_in) -> None:
        for r in swapped_in:
            self.alloc.allocate(r.rid, r.suspended_m)  # repro: allow-unpriced-mutation(shadow replay of the engine swap-in; simulate() charges swap_time for the restore in the batch price)
        for r in tail_in:
            self.alloc.allocate(r.rid, r.tail_suspended_m)  # repro: allow-unpriced-mutation(same priced restore as the full swap-in above)

    def pre_items(self, prefill_items, decode_items) -> None:
        """Claim-time control plane of the engine: prefix attach (device
        hits + host promotions), page allocation, CoW guard."""
        for r, c in prefill_items:
            skip = 0
            if r.m == 0 and not self.alloc.has(r.rid):
                skip = self._attach(r, c)
            self.alloc.allocate(r.rid, c - skip)
            pos = r.m + skip
            if pos % self.pg:
                self.alloc.ensure_private(r.rid, pos // self.pg)
        for r, _ in decode_items:
            self.alloc.allocate(r.rid, 1)
            if r.m % self.pg:
                self.alloc.ensure_private(r.rid, r.m // self.pg)

    def _attach(self, r: Request, c: int) -> int:
        cap = min(r.input_len - 1, c - 1) // self.pg
        if cap <= 0:
            return 0
        keys, ptoks = self._chain(r)
        attached, promoted = attach_prefix_run(
            self.alloc, r.rid, keys[:cap], ptoks[:cap],
            host_tier=self.store if self.demotion else None, restore=None)
        if promoted:
            self.pending_s += self.cm.swap_time(promoted)
            self.stats["promotions"] += promoted // self.pg
            self.stats["kv_promoted"] += promoted
        return attached

    def drain(self) -> float:
        """Tier charges accrued for the batch being priced."""
        s, self.pending_s = self.pending_s, 0.0
        self.stats["tier_swap_s"] += s
        return s

    def register(self, r: Request, m_new: int) -> None:
        n = min(m_new, r.input_len) // self.pg
        if n > 0 and self.alloc.has(r.rid):
            keys, ptoks = self._chain(r)
            # repro: allow-unpriced-mutation(registration moves no bytes - mirrors the engine's annotated _register_prefix; charges accrue at demotion/promotion)
            self.alloc.register_prefix(r.rid, keys[:n], ptoks[:n])

    def on_finish(self, r: Request) -> None:
        self.alloc.free(r.rid)  # repro: allow-unpriced-mutation(completion frees pages without host traffic - mirrors the engine's annotated _release)

    def result_stats(self) -> Dict[str, float]:
        return {**self.stats, **self.alloc.stats}


def simulate(scheduler: Scheduler, requests: Sequence[Request],
             cost_model: CostModel, *, max_batches: int = 2_000_000,
             record_batches: bool = True,
             prefix_sim: Optional[PrefixTierSim] = None) -> SimResult:
    """Run the schedule to completion under virtual (cost-model) time.

    Swap-preempted victims are charged ``cost_model.swap_time`` on the
    way out and again on restore (§5.4), so simulated schedules price the
    host link exactly like the serving engine's data plane does.  An
    optional ``prefix_sim`` shadow additionally models the paged
    engine's two-tier prefix cache (policy-driven reclaim, host
    demotion, promotion) and charges its host-link traffic into each
    batch's virtual time.
    """
    if scheduler.cost_model is None:
        scheduler.cost_model = cost_model   # auto preempt-mode pricing
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    now = 0.0
    result = SimResult(requests=list(requests))
    i = 0
    # charges/counts from rounds whose batch admitted no items, owed to
    # the next executed batch's log and clock
    carry_swap_s, carry_out, carry_preempted = 0.0, 0, 0

    for _ in range(max_batches):
        # admit arrivals (paper Alg. 1 line 4: fetch new requests)
        while i < len(pending) and pending[i].arrival <= now + 1e-12:
            scheduler.add_request(pending[i])
            i += 1
        if not scheduler.has_work():
            if i >= len(pending):
                break
            now = pending[i].arrival          # idle: jump to next arrival
            continue

        if prefix_sim is not None:
            prefix_sim.begin(now)       # replacement-policy clock
        batch = scheduler.get_next_batch()
        if prefix_sim is not None:
            prefix_sim.preempts(batch)
        # host-link swap-out charges accrue even when the batch admits
        # nothing (the victim's transfer happens regardless); they are
        # carried into the next executed batch's virtual time
        out_now = [v for v in batch.preempted if v.suspended]
        # swap_out_m: only the device-resident portion crosses the link
        # now (tail runs shed earlier were charged when they left)
        carry_swap_s += sum(cost_model.swap_time(v.swap_out_m)
                            for v in out_now)
        carry_out += len(out_now)
        # page-level partial preemptions: swap-mode tail runs are charged
        # per run (the Fig. 8 crossover already priced them per run)
        for _, _, n_tokens, mode in batch.partial_preempted:
            if mode == "swap":
                carry_swap_s += cost_model.swap_time(n_tokens)
                carry_out += 1
        carry_preempted += len(batch.preempted) + len(batch.partial_preempted)
        if not batch.items:
            if i < len(pending):              # blocked: wait for arrivals
                now = max(now, pending[i].arrival)
                continue
            raise RuntimeError(
                "scheduler deadlock: work remains but empty batch "
                f"(waiting={len(scheduler.waiting)}, "
                f"running={len(scheduler.running)})")

        spec = _spec_of(batch)
        # phase split by the engine's classification predicate (same
        # phase test _spec_of uses) — the shadow's claim-time hooks run
        # over these in the engine's order: prefills, then decodes
        pf_items = dc_items = None
        if prefix_sim is not None:
            dc_items = [(r, c) for r, c in batch.items
                        if r.generated > 0 and r.remaining_prefill == c == 1]
            pf_items = [(r, c) for r, c in batch.items
                        if not (r.generated > 0
                                and r.remaining_prefill == c == 1)]
        # swap-in charges for suspended requests re-admitted here, and
        # tail-run restores for partially-shed requests batched again
        swapped_in = [r for r, _ in batch.items if r.suspended]
        tail_in = [r for r, _ in batch.items if r.tail_suspended_m > 0]
        if prefix_sim is not None:
            prefix_sim.swap_restores(swapped_in, tail_in)
        swap_s = carry_swap_s + sum(cost_model.swap_time(r.suspended_m)
                                    for r in swapped_in) \
            + sum(cost_model.swap_time(r.tail_suspended_m) for r in tail_in)
        n_out, n_preempted = carry_out, carry_preempted
        carry_swap_s, carry_out, carry_preempted = 0.0, 0, 0
        for r in swapped_in:
            r.resume()
        for r in tail_in:
            r.resume_tail()
        if prefix_sim is not None:
            # claim-time control plane AFTER restore (r.m is then the
            # restored context, as the engine sees it) and BEFORE dt —
            # promotion/demotion charges belong to THIS batch
            prefix_sim.pre_items(pf_items, dc_items)
            swap_s += prefix_sim.drain()
        dt = cost_model.batch_time(spec) + swap_s
        now += dt
        pf_rids = ({r.rid for r, _ in pf_items}
                   if prefix_sim is not None else ())
        for r, c in batch.items:
            m_new = r.m + c
            r.advance(c, now)
            if prefix_sim is not None and r.rid in pf_rids:
                prefix_sim.register(r, m_new)
            if r.finished:
                scheduler.complete(r)
                if prefix_sim is not None:
                    prefix_sim.on_finish(r)
        if record_batches:
            kv_used = sum(r.m for r in scheduler.running)
            result.batches.append(BatchLog(
                t_start=now - dt, t_end=now,
                num_prefill=len(spec.prefills), num_decode=len(spec.decodes),
                tokens=spec.total_tokens, kv_used=kv_used,
                preempted=n_preempted,
                swapped_out=n_out, swapped_in=len(swapped_in) + len(tail_in),
                swap_s=swap_s))
    else:
        raise RuntimeError("simulation did not converge (max_batches hit)")

    result.num_preemptions = scheduler.num_preemptions
    result.num_partial_preempts = scheduler.num_partial_preempts
    result.num_swaps = scheduler.num_swaps
    if prefix_sim is not None:
        result.prefix_stats = prefix_sim.result_stats()
    return result


# --------------------------------------------------------------------- #
# convenience: run one named scheduler over a workload
# --------------------------------------------------------------------- #

def run_sim(scheduler_name: str, requests: Sequence[Request],
            cost_model: CostModel, *, M: int, S: int = 4096,
            replacement: Optional[str] = None, ranking: str = "arrival",
            use_histogram: bool = False,
            preempt_mode: str = "recompute") -> SimResult:
    from repro.core.scheduler import make_scheduler

    sched = make_scheduler(scheduler_name, M, S=S, replacement=replacement,
                           ranking=ranking, use_histogram=use_histogram,
                           preempt_mode=preempt_mode, cost_model=cost_model)
    return simulate(sched, requests, cost_model)


def fresh_requests(spec: Sequence[Tuple[int, int, float]]) -> List[Request]:
    """[(I, O, arrival)] -> new Request objects with sequential rids."""
    return [Request(rid=i, input_len=I, output_len=O, arrival=a)
            for i, (I, O, a) in enumerate(spec)]
