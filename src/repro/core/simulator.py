"""InferMax-style simulation loop (paper Fig. 1, blue boxes).

Drives the unified ``Scheduler`` (Algorithm 1) with a ``CostModel``
instead of GPUs: each batch advances virtual time by the model's predicted
batch time.  Produces the metrics of §5.1 (latency, TTFT, TPOT, TPS),
preemption counts, and per-batch logs (memory usage, batch size) used by
every multi-batch figure (9, 11, 12, 14, App. A-D).

``PrefixTierSim`` is the virtual-time shadow of the paged engine's
two-tier prefix cache (§6 replacement policy + host demotion): it runs
the SAME ``PagedAllocator`` control plane and the same ``KVSwapStore``
host-tier bookkeeping (metadata-only — no bytes move) at the same points
of the batch loop, so demotion/promotion counts and their ``swap_time``
charges match the serving engine batch-for-batch on identical schedules
(the demotion/promotion parity test pins this).

Fault parity — when ``SchedulerConfig.faults`` carries a
``serving.faults.FaultSpec``, the simulator mirrors the engine's
failure model without moving a byte: a ``_FaultMirror`` tracks which
host snapshots each suspended request would hold and draws the SAME
content-keyed verdicts from its own ``FaultPlan`` at the same decision
points.  Permanent store failures apply the engine's exact fallback
arithmetic (drop + recompute, no charge); a "corrupt" snapshot aborts
the iteration through a real step transaction (``serving.txn``) —
rollback, repair, retry — exactly as ``Engine.step`` does, so schedules
stay batch-for-batch identical under any fault schedule.  Transient
faults and their backoff are recorded but invisible to virtual time,
and attempt-keyed allocation faults are engine-internal by design (an
aborted attempt leaves no parity-visible state).  Results land in
``SimResult.recovery_stats``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cost_model import BatchSpec, CostModel
from repro.core.kvcache import PagedAllocator, attach_prefix_run, chain_keys
from repro.core.policies import make_replacement_policy
from repro.core.request import Phase, Request
from repro.core.scheduler import Batch, Scheduler, SchedulerConfig
from repro.core import stat_keys as SK


@dataclass
class BatchLog:
    t_start: float
    t_end: float
    num_prefill: int
    num_decode: int
    tokens: int
    kv_used: int
    preempted: int
    swapped_out: int = 0        # victims suspended to host this batch
    swapped_in: int = 0         # suspended requests restored this batch
    swap_s: float = 0.0         # host-link time charged (in + out)
    wall_s: float = 0.0         # measured wall time (engine only; the
    #                             simulator advances virtual time and
    #                             leaves this 0)
    pages_used: int = 0         # physical pages live in the pool after
    #                             this batch (paged engine only; counts
    #                             shared pages once — the dedup signal)


@dataclass
class SimResult:
    requests: List[Request]
    batches: List[BatchLog] = field(default_factory=list)
    num_preemptions: int = 0    # full + partial (page-level) preemptions
    num_partial_preempts: int = 0
    num_swaps: int = 0
    # prefix-cache tier counters when a PrefixTierSim shadow ran
    # (promotions/demotions/charges + the shadow allocator's stats)
    prefix_stats: Dict[str, float] = field(default_factory=dict)
    # fault-mirror counters when SchedulerConfig.faults was set
    # (rollbacks, integrity failures, degraded recomputes, permanent
    # store failures, transient retries/backoff, swap fallbacks)
    recovery_stats: Dict[str, float] = field(default_factory=dict)

    # --- aggregate metrics (§5.1) -------------------------------------- #
    @property
    def makespan(self) -> float:
        return max((b.t_end for b in self.batches), default=0.0)

    @property
    def latency(self) -> float:
        """End-to-end latency: time until the LAST request finishes."""
        return max((r.finish_time or 0.0) for r in self.requests)

    @property
    def mean_latency(self) -> float:
        ls = [r.latency() for r in self.requests if r.latency() is not None]
        return sum(ls) / len(ls) if ls else 0.0

    @property
    def mean_ttft(self) -> float:
        ts = [r.ttft() for r in self.requests if r.ttft() is not None]
        return sum(ts) / len(ts) if ts else 0.0

    @property
    def max_ttft(self) -> float:
        ts = [r.ttft() for r in self.requests if r.ttft() is not None]
        return max(ts) if ts else 0.0

    @property
    def mean_tpot(self) -> float:
        ts = [r.tpot() for r in self.requests if r.tpot() is not None]
        return sum(ts) / len(ts) if ts else 0.0

    @property
    def tps(self) -> float:
        tok = sum(r.generated for r in self.requests)
        return tok / self.makespan if self.makespan else 0.0

    @property
    def mean_batch_size(self) -> float:
        bs = [b.num_prefill + b.num_decode for b in self.batches]
        return sum(bs) / len(bs) if bs else 0.0

    @property
    def mean_kv_used(self) -> float:
        ks = [b.kv_used for b in self.batches]
        return sum(ks) / len(ks) if ks else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "latency": self.latency,
            "mean_latency": self.mean_latency,
            "mean_ttft": self.mean_ttft,
            "max_ttft": self.max_ttft,
            "mean_tpot": self.mean_tpot,
            "tps": self.tps,
            "preemptions": float(self.num_preemptions),
            "swaps": float(self.num_swaps),
            "batches": float(len(self.batches)),
            "mean_batch_size": self.mean_batch_size,
            "mean_kv_used": self.mean_kv_used,
        }


def _spec_of(batch: Batch) -> BatchSpec:
    spec = BatchSpec()
    for r, c in batch.items:
        # phase *before* processing: decode iff exactly one token to go
        # and at least one token already generated.  resident_kv prices a
        # swap-resumed request against its restored context, not m=0.
        if r.generated > 0 and r.remaining_prefill == c == 1:
            spec.decodes.append((c, r.resident_kv))
        else:
            spec.prefills.append((c, r.resident_kv))
    return spec


class _FaultMirror:
    """Metadata shadow of the engine's fault handling on the suspend
    path.  Tracks, per suspended rid, the page runs the engine's swap
    store would hold — ``(num_tokens, corrupt)`` pairs, a full-slot
    snapshot being a single "run" — and draws the same content-keyed
    verdicts the engine draws (``serving.faults``), so the simulator
    degrades exactly the requests the engine degrades.  The backoff
    mirror assumes ``run_with_retries``'s default ``backoff_s=0.1``,
    which is what the engine's guarded puts use."""

    def __init__(self, plan):
        self.plan = plan
        self.runs: Dict[int, List[Tuple[int, bool]]] = {}
        self.stats: Dict[str, float] = {
            SK.ROLLBACKS: 0, SK.INTEGRITY_FAILURES: 0,
            SK.DEGRADED_RECOMPUTES: 0, SK.PERMANENT_STORE_FAILURES: 0,
            SK.TRANSIENT_RETRIES: 0, SK.BACKOFF_S: 0.0,
            SK.SWAP_FALLBACKS: 0}

    def snapshot(self):
        runs = {rid: list(rs) for rid, rs in self.runs.items()}
        stats = dict(self.stats)

        def restore() -> None:
            self.runs = {rid: list(rs) for rid, rs in runs.items()}
            self.stats = dict(stats)
        return restore

    def _transients(self, kind: str, fkey: Tuple) -> None:
        k = self.plan.transient_failures(kind, *fkey)
        if k:
            self.stats[SK.TRANSIENT_RETRIES] += k
            self.stats[SK.BACKOFF_S] += sum(0.1 * 2 ** i for i in range(k))

    def suspend(self, v: Request, sched: Scheduler) -> bool:
        """Mirror the full-suspend put (engine ``_swap_out`` /
        ``_swap_out_paged``); False = permanent failure, with the
        engine's fallback arithmetic applied (drop every stored run,
        degrade to recompute, no charge)."""
        fkey = (v.rid, v.suspended_m, v.swaps)
        if self.plan.decide("perm_put", *fkey):
            self.stats[SK.PERMANENT_STORE_FAILURES] += 1
            for _ in self.runs.pop(v.rid, []):
                v.swaps -= 1
                sched.num_swaps -= 1
                self.stats[SK.SWAP_FALLBACKS] += 1
            v.drop_suspended()
            sched.num_swaps -= 1
            self.stats[SK.SWAP_FALLBACKS] += 1
            return False
        self._transients("store_put", fkey)
        corrupt = self.plan.decide("corrupt_put", *fkey)
        self.runs.setdefault(v.rid, []).append((v.suspended_m, corrupt))
        return True

    def shed(self, r: Request, n_tokens: int, sched: Scheduler) -> bool:
        """Mirror one tail-shed put (engine ``_shed_tail``); False =
        permanent failure — the failed run AND every stored run fold
        back to recompute (the tiling has a gap)."""
        fkey = (r.rid, r.m, n_tokens, r.partial_preemptions)
        if self.plan.decide("perm_run", *fkey):
            self.stats[SK.PERMANENT_STORE_FAILURES] += 1
            r.drop_tail_run(n_tokens)
            sched.num_swaps -= 1
            self.stats[SK.SWAP_FALLBACKS] += 1
            for n, _ in self.runs.pop(r.rid, []):
                r.drop_tail_run(n)
                sched.num_swaps -= 1
                self.stats[SK.SWAP_FALLBACKS] += 1
            return False
        self._transients("store_run", fkey)
        corrupt = self.plan.decide("corrupt_run", *fkey)
        self.runs.setdefault(r.rid, []).append((n_tokens, corrupt))
        return True

    def corrupt_restore(self, batch_items) -> Optional[Request]:
        """First request in batch order whose stored snapshot is
        corrupt — the engine verifies swap-ins in batch order and
        aborts on the FIRST integrity failure."""
        for r, _ in batch_items:
            if (r.suspended or r.tail_suspended_m > 0) and \
                    any(c for _, c in self.runs.get(r.rid, [])):
                return r
        return None

    def repair(self, r: Request, sched: Scheduler) -> None:
        """Post-rollback repair, the engine's ``_drop_snapshot_repair``
        / ``_drop_runs_repair`` arithmetic: drop every stored run and
        degrade ``r`` to recompute."""
        runs = self.runs.pop(r.rid, [])
        if r.suspended:                   # full suspend (claim=True)
            for _ in runs[:-1]:           # tail runs beyond the base
                r.swaps -= 1
                sched.num_swaps -= 1
            r.drop_suspended()
            sched.num_swaps -= 1
        else:                             # tail restore (claim=False)
            for n, _ in runs:
                r.drop_tail_run(n)
                sched.num_swaps -= 1

    def restored(self, r: Request) -> None:
        """A successful swap-in empties the store for this rid."""
        self.runs.pop(r.rid, None)


class PrefixTierSim:
    """Virtual-time shadow of the paged engine's two-tier prefix cache.

    Runs the engine's EXACT control plane — the same ``PagedAllocator``
    (same replacement policy, same eviction/demotion hook) and the same
    ``KVSwapStore`` host-tier bookkeeping with metadata-only entries
    (``kv=None``; ``page_nbytes`` stands in for the real snapshot size,
    which for the engine is ``2 * L * page * Hkv * D * itemsize``) — at
    the same points of the batch loop.  Requests therefore need real
    ``prompt`` token ids.  Promotions and demotions charge
    ``cost_model.swap_time`` into the batch being priced, exactly like
    the engine, so on identical schedules the two sides agree
    batch-for-batch on counts AND on virtual time.

    Pass one to :func:`simulate`; read ``stats`` / ``alloc.stats`` (or
    ``SimResult.prefix_stats``) afterwards.  Use ``host_bytes=None``
    (unbounded) unless you replicate the engine's suspend traffic in the
    same store — the byte budget there is shared with swap entries.
    """

    def __init__(self, scfg: SchedulerConfig, cost_model: CostModel, *,
                 page_nbytes: int, host_bytes: Optional[int] = None):
        from repro.serving.swap_store import KVSwapStore
        pg = scfg.page_size
        if pg <= 1:
            raise ValueError("prefix-tier shadow needs page_size > 1")
        self.pg = pg
        self.cm = cost_model
        self.demotion = bool(scfg.cache_demotion)
        self.exact = getattr(scfg, "prefix_lookup", "trie") == "exact"
        self.page_nbytes = int(page_nbytes)
        self.store = KVSwapStore(capacity_bytes=host_bytes)
        self.alloc = PagedAllocator(
            max(1, -(-scfg.M // pg)), pg,
            policy=make_replacement_policy(scfg.cache_policy,
                                           cost_model=cost_model,
                                           M=scfg.M),
            on_evict=self._demote if self.demotion else None)
        # own fault plan from the shared spec: same seed, same draws as
        # the engine's (serving.faults content-keying) — never the
        # engine's plan object, parity must not need shared state
        self.plan = None
        if getattr(scfg, "faults", None) is not None:
            from repro.serving.faults import FaultPlan
            self.plan = FaultPlan(scfg.faults)
        self.pending_s = 0.0      # tier charges owed to the current batch
        self.stats: Dict[str, float] = {
            SK.PROMOTIONS: 0, SK.DEMOTIONS: 0, SK.DEMOTE_DROPS: 0,
            SK.KV_PROMOTED: 0, SK.KV_DEMOTED: 0, SK.TIER_SWAP_S: 0.0,
            SK.PREFIX_INTEGRITY: 0, SK.TRIE_HITS: 0,
            SK.PARTIAL_HIT_TOKENS: 0}
        self._keys: Dict[int, List[int]] = {}
        self._ptoks: Dict[int, List[Tuple[int, ...]]] = {}

    def snapshot(self):
        """Restore closure over the shadow's whole state (allocator,
        registry, host tier, counters) — the fault mirror's step
        transaction adds it so aborted iterations roll the shadow back
        in lockstep with the scheduler."""
        from repro.serving.txn import snapshot_allocator, snapshot_store
        restore_alloc = snapshot_allocator(self.alloc)
        restore_store = snapshot_store(self.store)
        stats = dict(self.stats)
        pending = self.pending_s
        keys, ptoks = dict(self._keys), dict(self._ptoks)

        def restore() -> None:
            restore_alloc()
            restore_store()
            self.stats = dict(stats)
            self.pending_s = pending
            self._keys, self._ptoks = dict(keys), dict(ptoks)
        return restore

    def _demote(self, key: int, page: int, tokens, n_kvs: int) -> None:
        from repro.serving.swap_store import SwapStoreFullError
        if self.store.has_prefix(key):
            return
        if self.plan is not None and self.plan.decide("demote_fail", key):
            # mirror of the engine's dropped demotion: no entry, no
            # charge — the page recomputes on its next miss
            self.stats[SK.DEMOTE_DROPS] += 1
            return
        try:
            self.store.put_prefix(key, tokens, n_kvs, None,
                                  nbytes=self.page_nbytes)
        except SwapStoreFullError:
            self.stats[SK.DEMOTE_DROPS] += 1
            return
        self.pending_s += self.cm.swap_time(self.pg)
        self.stats[SK.DEMOTIONS] += 1
        self.stats[SK.KV_DEMOTED] += self.pg

    def _verify(self, entry) -> bool:
        """Mirror of the engine's ``_verify_prefix`` promotion gate:
        same fault-plan draws on the same entry key (the shadow's
        entries are metadata-only, so the CRC side is trivially
        clean — rot is modeled by the ``corrupt_prefix`` flag on both
        sides, never by bytes)."""
        bad = self.plan is not None and (
            self.plan.decide("corrupt_prefix", entry.key)
            or self.plan.decide("promote_fail", entry.key))
        if bad:
            self.stats[SK.PREFIX_INTEGRITY] += 1
        return not bad

    def _chain(self, r: Request):
        keys = self._keys.get(r.rid)
        if keys is None:
            if r.prompt is None:
                raise ValueError(
                    f"prefix-tier shadow needs real prompts (rid {r.rid})")
            keys = chain_keys(r.prompt, self.pg)
            self._keys[r.rid] = keys
            self._ptoks[r.rid] = [
                tuple(r.prompt[i * self.pg:(i + 1) * self.pg])
                for i in range(len(keys))]
        return keys, self._ptoks[r.rid]

    # --- batch-loop hooks (mirror serving.engine.Engine.step) ---------- #
    def begin(self, now: float) -> None:
        self.alloc.now = now

    def preempts(self, batch: Batch) -> None:
        for r, npg, _, _ in batch.partial_preempted:
            if r.running:       # folded sheds free with the full preempt
                self.alloc.free_tail(r.rid, npg)  # repro: allow-unpriced-mutation(shadow replay of the engine shed; the scheduler already priced the preemption swap_time when it chose the victim)
        for v in batch.preempted:
            self.alloc.free(v.rid)  # repro: allow-unpriced-mutation(shadow replay of engine _release; freeing moves no bytes and the preemption was priced at victim selection)

    def swap_restores(self, swapped_in, tail_in) -> None:
        for r in swapped_in:
            self.alloc.allocate(r.rid, r.suspended_m)  # repro: allow-unpriced-mutation(shadow replay of the engine swap-in; simulate() charges swap_time for the restore in the batch price)
        for r in tail_in:
            self.alloc.allocate(r.rid, r.tail_suspended_m)  # repro: allow-unpriced-mutation(same priced restore as the full swap-in above)

    def pre_items(self, prefill_items, decode_items) -> None:
        """Claim-time control plane of the engine: prefix attach (device
        hits + host promotions), page allocation, CoW guard."""
        for r, c in prefill_items:
            skip = 0
            if r.m == 0 and not self.alloc.has(r.rid):
                skip = self._attach(r, c)
            self.alloc.allocate(r.rid, c - skip)
            pos = r.m + skip
            if pos % self.pg:
                self.alloc.ensure_private(r.rid, pos // self.pg)
        for r, _ in decode_items:
            self.alloc.allocate(r.rid, 1)
            if r.m % self.pg:
                self.alloc.ensure_private(r.rid, r.m // self.pg)

    def _attach(self, r: Request, c: int) -> int:
        cap = min(r.input_len - 1, c - 1) // self.pg
        if cap <= 0:
            return 0
        keys, ptoks = self._chain(r)
        attached, promoted = attach_prefix_run(
            self.alloc, r.rid, keys[:cap], ptoks[:cap],
            host_tier=self.store if self.demotion else None, restore=None,
            verify=self._verify if self.demotion else None,
            exact=self.exact)
        if promoted:
            self.pending_s += self.cm.swap_time(promoted)
            self.stats[SK.PROMOTIONS] += promoted // self.pg
            self.stats[SK.KV_PROMOTED] += promoted
        if attached:
            # mirror of the engine's trie counters (swap_stats):
            # every non-empty attach is a trie hit; anything short of
            # the full capped chain is a PARTIAL hit (PR 9)
            self.stats[SK.TRIE_HITS] += 1
            if attached < cap * self.pg:
                self.stats[SK.PARTIAL_HIT_TOKENS] += attached
        return attached

    def drain(self) -> float:
        """Tier charges accrued for the batch being priced."""
        s, self.pending_s = self.pending_s, 0.0
        self.stats[SK.TIER_SWAP_S] += s
        return s

    def register(self, r: Request, m_new: int) -> None:
        n = min(m_new, r.input_len) // self.pg
        if n > 0 and self.alloc.has(r.rid):
            keys, ptoks = self._chain(r)
            # repro: allow-unpriced-mutation(registration moves no bytes - mirrors the engine's annotated _register_prefix; charges accrue at demotion/promotion)
            self.alloc.register_prefix(r.rid, keys[:n], ptoks[:n])

    def on_finish(self, r: Request) -> None:
        self.alloc.free(r.rid)  # repro: allow-unpriced-mutation(completion frees pages without host traffic - mirrors the engine's annotated _release)

    def result_stats(self) -> Dict[str, float]:
        return {**self.stats, **self.alloc.stats}


def simulate(scheduler: Scheduler, requests: Sequence[Request],
             cost_model: CostModel, *, max_batches: int = 2_000_000,
             record_batches: bool = True,
             prefix_sim: Optional[PrefixTierSim] = None) -> SimResult:
    """Run the schedule to completion under virtual (cost-model) time.

    Swap-preempted victims are charged ``cost_model.swap_time`` on the
    way out and again on restore (§5.4), so simulated schedules price the
    host link exactly like the serving engine's data plane does.  An
    optional ``prefix_sim`` shadow additionally models the paged
    engine's two-tier prefix cache (policy-driven reclaim, host
    demotion, promotion) and charges its host-link traffic into each
    batch's virtual time.
    """
    if scheduler.cost_model is None:
        scheduler.cost_model = cost_model   # auto preempt-mode pricing
    # fault mirror: built from the config's spec exactly like the
    # engine's plan, so both sides draw one deterministic schedule
    mirror: Optional[_FaultMirror] = None
    if getattr(scheduler.cfg, "faults", None) is not None:
        from repro.serving.faults import FaultPlan
        mirror = _FaultMirror(FaultPlan(scheduler.cfg.faults))
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    now = 0.0
    result = SimResult(requests=list(requests))
    i = 0
    # charges/counts from rounds whose batch admitted no items, owed to
    # the next executed batch's log and clock
    carry_swap_s, carry_out, carry_preempted = 0.0, 0, 0

    for _ in range(max_batches):
        # admit arrivals (paper Alg. 1 line 4: fetch new requests)
        while i < len(pending) and pending[i].arrival <= now + 1e-12:
            scheduler.add_request(pending[i])
            i += 1
        if not scheduler.has_work():
            if i >= len(pending):
                break
            now = pending[i].arrival          # idle: jump to next arrival
            continue

        # step transaction (faulty runs only): snapshot AFTER admission
        # so an integrity abort rolls back to exactly this point and the
        # retried iteration re-plans from repaired state — the engine's
        # Engine.step attempt loop, in virtual time
        txn = saved = None
        if mirror is not None:
            from repro.serving.txn import begin_step_txn
            txn = begin_step_txn(
                scheduler=scheduler,
                requests=scheduler.waiting + scheduler.running)
            txn.add(mirror.snapshot())
            if prefix_sim is not None:
                txn.add(prefix_sim.snapshot())
            saved = (now, carry_swap_s, carry_out, carry_preempted)

        if prefix_sim is not None:
            prefix_sim.begin(now)       # replacement-policy clock
        batch = scheduler.get_next_batch()
        if prefix_sim is not None:
            prefix_sim.preempts(batch)
        # page-level partial preemptions FIRST (engine order: tail runs
        # are snapshotted before any full suspend of the same victim):
        # swap-mode runs are charged per run (the Fig. 8 crossover
        # already priced them per run); only a RUNNING victim's shed
        # actually stores a run — a folded shed (victim also fully
        # preempted this round) charges but moves no data, so it draws
        # no fault verdicts
        for r, _, n_tokens, mode in batch.partial_preempted:
            if mode != "swap":
                continue
            if mirror is not None and r.running \
                    and not mirror.shed(r, n_tokens, scheduler):
                continue            # permanent failure: recompute
            carry_swap_s += cost_model.swap_time(n_tokens)
            carry_out += 1
        # host-link swap-out charges accrue even when the batch admits
        # nothing (the victim's transfer happens regardless); they are
        # carried into the next executed batch's virtual time.
        # swap_out_m: only the device-resident portion crosses the link
        # now (tail runs shed earlier were charged when they left)
        for v in batch.preempted:
            if not v.suspended:
                continue
            if mirror is not None and not mirror.suspend(v, scheduler):
                continue            # permanent failure: recompute
            carry_swap_s += cost_model.swap_time(v.swap_out_m)
            carry_out += 1
        carry_preempted += len(batch.preempted) + len(batch.partial_preempted)
        if not batch.items:
            if i < len(pending):              # blocked: wait for arrivals
                now = max(now, pending[i].arrival)
                continue
            raise RuntimeError(
                "scheduler deadlock: work remains but empty batch "
                f"(waiting={len(scheduler.waiting)}, "
                f"running={len(scheduler.running)})")

        spec = _spec_of(batch)
        # phase split by the engine's classification predicate (same
        # phase test _spec_of uses) — the shadow's claim-time hooks run
        # over these in the engine's order: prefills, then decodes
        pf_items = dc_items = None
        if prefix_sim is not None:
            dc_items = [(r, c) for r, c in batch.items
                        if r.generated > 0 and r.remaining_prefill == c == 1]
            pf_items = [(r, c) for r, c in batch.items
                        if not (r.generated > 0
                                and r.remaining_prefill == c == 1)]
        # integrity gate BEFORE the restores: the engine verifies every
        # snapshot at swap-in and aborts the attempt on the first
        # corrupt one — mirror that as rollback + repair + retry
        if mirror is not None:
            bad = mirror.corrupt_restore(batch.items)
            if bad is not None:
                txn.rollback()
                now, carry_swap_s, carry_out, carry_preempted = saved
                mirror.stats[SK.ROLLBACKS] += 1
                mirror.stats[SK.INTEGRITY_FAILURES] += 1
                mirror.stats[SK.DEGRADED_RECOMPUTES] += 1
                mirror.repair(bad, scheduler)   # on rolled-back state
                continue
        # swap-in charges for suspended requests re-admitted here, and
        # tail-run restores for partially-shed requests batched again
        swapped_in = [r for r, _ in batch.items if r.suspended]
        tail_in = [r for r, _ in batch.items if r.tail_suspended_m > 0]
        if prefix_sim is not None:
            prefix_sim.swap_restores(swapped_in, tail_in)
        swap_s = carry_swap_s + sum(cost_model.swap_time(r.suspended_m)
                                    for r in swapped_in) \
            + sum(cost_model.swap_time(r.tail_suspended_m) for r in tail_in)
        n_out, n_preempted = carry_out, carry_preempted
        carry_swap_s, carry_out, carry_preempted = 0.0, 0, 0
        for r in swapped_in:
            r.resume()
            if mirror is not None:
                mirror.restored(r)
        for r in tail_in:
            r.resume_tail()
            if mirror is not None:
                mirror.restored(r)
        if prefix_sim is not None:
            # claim-time control plane AFTER restore (r.m is then the
            # restored context, as the engine sees it) and BEFORE dt —
            # promotion/demotion charges belong to THIS batch
            prefix_sim.pre_items(pf_items, dc_items)
            swap_s += prefix_sim.drain()
        dt = cost_model.batch_time(spec) + swap_s
        now += dt
        pf_rids = ({r.rid for r, _ in pf_items}
                   if prefix_sim is not None else ())
        for r, c in batch.items:
            m_new = r.m + c
            r.advance(c, now)
            if prefix_sim is not None and r.rid in pf_rids:
                prefix_sim.register(r, m_new)
            if r.finished:
                scheduler.complete(r)
                if prefix_sim is not None:
                    prefix_sim.on_finish(r)
        if record_batches:
            kv_used = sum(r.m for r in scheduler.running)
            result.batches.append(BatchLog(
                t_start=now - dt, t_end=now,
                num_prefill=len(spec.prefills), num_decode=len(spec.decodes),
                tokens=spec.total_tokens, kv_used=kv_used,
                preempted=n_preempted,
                swapped_out=n_out, swapped_in=len(swapped_in) + len(tail_in),
                swap_s=swap_s))
    else:
        raise RuntimeError("simulation did not converge (max_batches hit)")

    result.num_preemptions = scheduler.num_preemptions
    result.num_partial_preempts = scheduler.num_partial_preempts
    result.num_swaps = scheduler.num_swaps
    if prefix_sim is not None:
        result.prefix_stats = prefix_sim.result_stats()
    if mirror is not None:
        result.recovery_stats = dict(mirror.stats)
    return result


# --------------------------------------------------------------------- #
# convenience: run one named scheduler over a workload
# --------------------------------------------------------------------- #

def run_sim(scheduler_name: str, requests: Sequence[Request],
            cost_model: CostModel, *, M: int, S: int = 4096,
            replacement: Optional[str] = None, ranking: str = "arrival",
            use_histogram: bool = False,
            preempt_mode: str = "recompute") -> SimResult:
    from repro.core.scheduler import make_scheduler

    sched = make_scheduler(scheduler_name, M, S=S, replacement=replacement,
                           ranking=ranking, use_histogram=use_histogram,
                           preempt_mode=preempt_mode, cost_model=cost_model)
    return simulate(sched, requests, cost_model)


def fresh_requests(spec: Sequence[Tuple[int, int, float]]) -> List[Request]:
    """[(I, O, arrival)] -> new Request objects with sequential rids."""
    return [Request(rid=i, input_len=I, output_len=O, arrival=a)
            for i, (I, O, a) in enumerate(spec)]
