"""Top-down SLO attainment from cost models (paper §5.3, Fig. 7).

Given a TPOT/batch-time threshold, compute the pareto frontier of
(c_prefill, m_decode) combinations whose hybrid-batch time equals the
threshold — instead of bottom-up parameter sweeping.  Works with any
monotone cost model (linear or theoretical) via bisection on m.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.cost_model import BatchSpec, CostModel


def hybrid_batch_time(model: CostModel, *, num_prefill: int, c: int,
                      num_decode: int, m: int, m_prefill: int = 0) -> float:
    spec = BatchSpec(
        prefills=[(c, m_prefill)] * num_prefill,
        decodes=[(1, m)] * num_decode,
    )
    return model.batch_time(spec)


def max_m_for_threshold(model: CostModel, *, num_prefill: int, c: int,
                        num_decode: int, threshold: float,
                        m_max: int = 1 << 20) -> Optional[int]:
    """Largest decode context m with batch time <= threshold (None if even
    m=0 violates it).  Bisection — valid because time is monotone in m."""
    if hybrid_batch_time(model, num_prefill=num_prefill, c=c,
                         num_decode=num_decode, m=0) > threshold:
        return None
    lo, hi = 0, m_max
    while lo < hi:
        mid = (lo + hi + 1) // 2
        t = hybrid_batch_time(model, num_prefill=num_prefill, c=c,
                              num_decode=num_decode, m=mid)
        if t <= threshold:
            lo = mid
        else:
            hi = mid - 1
    return lo


@dataclass
class ParetoPoint:
    c: int
    m: int
    batch_time: float


def pareto_curve(model: CostModel, *, num_prefill: int, num_decode: int,
                 threshold: float,
                 cs: Sequence[int] = (1, 16, 64, 256, 1024, 4096)
                 ) -> List[ParetoPoint]:
    """(c, m) combinations making the hybrid batch time == threshold
    (Fig. 7); any point under the curve satisfies TPOT < threshold."""
    out: List[ParetoPoint] = []
    for c in cs:
        m = max_m_for_threshold(model, num_prefill=num_prefill, c=c,
                                num_decode=num_decode, threshold=threshold)
        if m is None:
            continue
        t = hybrid_batch_time(model, num_prefill=num_prefill, c=c,
                              num_decode=num_decode, m=m)
        out.append(ParetoPoint(c=c, m=m, batch_time=t))
    return out


def balanced_intensity(head_dim: int, n_q: int, n_kv: int,
                       c: int) -> float:
    """§5.2: attention intensity FLOPs/RW -> 2/(1/H + ceil(c/H)·N_KV/(c·N_Q)).
    For prefill (large c) -> ~2/(2/H)=H; for decode (c=1) -> ~2/(1/H+N_KV/N_Q).
    """
    import math
    return 2.0 / (1.0 / head_dim
                  + math.ceil(c / head_dim) * n_kv / (c * n_q))
