"""Canonical counter-key constants for the engine<->simulator mirror.

The paper's validation methodology (and every parity test in this repo)
hinges on the serving engine and the virtual-time simulator reporting
the SAME counters for the same traffic: ``Engine.swap_stats`` /
``Engine.recovery_stats`` on one side, ``PrefixTierSim.stats`` /
``_FaultMirror.stats`` on the other.  Those dicts used to be keyed by
string literals typed independently at ~80 sites — a typo'd or
one-sided key silently created parity drift that only a runtime test on
the right workload could catch.

This module is the single source for those keys.  Both sides key their
stat dicts through these constants, and the ``stat-mirror`` static
checker (``repro.analysis.statmirror``) parses THIS file for the two
sanctioned-asymmetry sets below, then cross-checks every key written on
either side: an engine-only or sim-only key outside its allowlist is a
blocking finding before any parity test runs.

Keys are grouped by which side may write them:

* mirrored keys must be written on BOTH sides (engine dict and its
  simulator shadow);
* ``ENGINE_ONLY_KEYS`` are measured wall-clock or engine-internal
  counters the simulator cannot see by construction;
* ``SIM_ONLY_KEYS`` is virtual time the engine accounts elsewhere.
"""
from __future__ import annotations

# --------------------------------------------------------------------- #
# swap traffic (engine swap_stats; no simulator shadow by design — the
# simulator prices swaps into virtual time but does not count transfers
# it never performs; BatchLog.swapped_out/in carry the parity signal)
# --------------------------------------------------------------------- #
SWAP_OUTS = "swap_outs"
SWAP_INS = "swap_ins"
KV_OUT = "kv_out"
KV_IN = "kv_in"
DRAINS_ON_SWAPIN = "drains_on_swapin"
WALL_OUT_S = "wall_out_s"
WALL_IN_S = "wall_in_s"

# --------------------------------------------------------------------- #
# prefix-tier traffic (engine swap_stats <-> PrefixTierSim.stats)
# --------------------------------------------------------------------- #
PROMOTIONS = "promotions"
DEMOTIONS = "demotions"
DEMOTE_DROPS = "demote_drops"
KV_PROMOTED = "kv_promoted"
KV_DEMOTED = "kv_demoted"
PREFIX_INTEGRITY = "prefix_integrity"
TRIE_HITS = "trie_hits"
PARTIAL_HIT_TOKENS = "partial_hit_tokens"
WALL_PROMOTE_S = "wall_promote_s"      # engine wall measurement
WALL_DEMOTE_S = "wall_demote_s"        # engine wall measurement
TIER_SWAP_S = "tier_swap_s"            # sim virtual time (engine folds
#                                        the same charge into batch dt)

# --------------------------------------------------------------------- #
# fault handling (engine swap_stats/recovery_stats <-> _FaultMirror)
# --------------------------------------------------------------------- #
PERMANENT_STORE_FAILURES = "permanent_store_failures"
TRANSIENT_RETRIES = "transient_retries"
BACKOFF_S = "backoff_s"
SWAP_FALLBACKS = "swap_fallbacks"
ROLLBACKS = "rollbacks"
INTEGRITY_FAILURES = "integrity_failures"
DEGRADED_RECOMPUTES = "degraded_recomputes"
ALLOC_FAULTS = "alloc_faults"          # attempt-keyed, engine-internal
STRAGGLER_REQUEUES = "straggler_requeues"  # wall-triggered, engine-only
WALL_ABORTED_S = "wall_aborted_s"      # engine wall measurement

# --------------------------------------------------------------------- #
# wall-clock phase attribution of the pooled step (engine phase_stats;
# pure measurement, no simulator analogue)
# --------------------------------------------------------------------- #
ATTACH_S = "attach_s"
PREFILL_S = "prefill_s"
UPLOAD_S = "upload_s"

# --------------------------------------------------------------------- #
# PagedAllocator.stats — the control plane is the SAME class on both
# sides (the shadow runs a real allocator), so these cannot drift; the
# constants exist so call sites stay typo-proof
# --------------------------------------------------------------------- #
PREFIX_HITS = "prefix_hits"
PREFIX_SHARED_TOKENS = "prefix_shared_tokens"
COW_COPIES = "cow_copies"
RECLAIMED = "reclaimed"
RECLAIM_SKIPPED = "reclaim_skipped"

# --------------------------------------------------------------------- #
# sanctioned asymmetries — parsed by ``repro.analysis.statmirror``.
# Every entry documents WHY the other side cannot mirror it; a key
# written on one side only and absent here is parity drift.
# --------------------------------------------------------------------- #

#: measured wall-clock or engine-internal counters: the simulator moves
#: no bytes (wall_*), never retries an attempt (alloc_faults — aborted
#: attempts leave no parity-visible state), and has no real clock to
#: blow a straggler deadline (straggler_requeues, wall_aborted_s).
#: swap transfer counts ride BatchLog.swapped_out/in on the sim side.
ENGINE_ONLY_KEYS = frozenset({
    SWAP_OUTS, SWAP_INS, KV_OUT, KV_IN, DRAINS_ON_SWAPIN,
    WALL_OUT_S, WALL_IN_S, WALL_PROMOTE_S, WALL_DEMOTE_S,
    ALLOC_FAULTS, STRAGGLER_REQUEUES, WALL_ABORTED_S,
})

#: the tier shadow accumulates its swap_time charges under one key; the
#: engine folds the identical charges into the batch dt via
#: ``_tier_swap_s`` (a scalar, not a stats key) — parity compares the
#: resulting BatchLog.swap_s, not this counter.
SIM_ONLY_KEYS = frozenset({TIER_SWAP_S})

#: BatchLog fields only the engine populates: measured wall time and
#: physical pool occupancy (the simulator advances virtual time and
#: owns no pools).  Parsed by ``statmirror`` alongside the key sets.
ENGINE_ONLY_BATCHLOG_FIELDS = frozenset({"wall_s", "pages_used"})
