"""Cost models for batch times (paper §4, Table 3, Eqs. 1-3).

Two models, one interface (``batch_time(BatchSpec) -> seconds``):

* ``TheoreticalCostModel`` — the paper's roofline form
  ``max(FLOPs/GPU_FLOPS, RW/GPU_bandwidth)`` per operator (Eq. 3),
  with the FlashAttention FLOPs/RW of Eqs. 1-2, plus a *collective* term
  (``comm_bytes / link_bw``) absent from the single-GPU paper — on a TPU
  pod, TP all-reduces are first-class costs.
* ``LinearCostModel`` — per-operator linear models over the Table-3
  variables, fitted with least squares against profiled labels
  (``fit_linear_model``).  Monotone by construction (coefficients clipped
  at 0), so it composes into the SLO pareto (§5.3) and the CSP objective
  (§7) exactly as the paper argues.

A ``BatchSpec`` is phase-split: ``prefills`` / ``decodes`` are lists of
``(c, m)`` per request (c = tokens to process now, m = KVs already cached).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig

# --------------------------------------------------------------------- #
# hardware
# --------------------------------------------------------------------- #


@dataclass(frozen=True)
class HardwareConfig:
    name: str
    flops: float          # peak FLOP/s (bf16)
    hbm_bw: float         # bytes/s per chip
    hbm_cap: float        # bytes per chip
    link_bw: float        # bytes/s per interconnect link (ICI / NVLink)
    host_bw: float        # bytes/s host<->device (the swap path, §5.4)
    tp: int = 1           # tensor-parallel degree
    dp: int = 1           # data-parallel degree (for aggregate rooflines)
    bytes_per_el: int = 2  # bf16

    @property
    def chips(self) -> int:
        return self.tp * self.dp

    def with_tp(self, tp: int) -> "HardwareConfig":
        return replace(self, tp=tp)


HARDWARE = {
    # GPU presets reproduce the paper's own numbers (Figs. 4-12).
    "a100": HardwareConfig("a100", 312e12, 2.039e12, 80e9, 300e9, 32e9),
    "h100": HardwareConfig("h100", 989e12, 3.352e12, 80e9, 450e9, 64e9),
    # TPU v5e — the production target of this repo (roofline constants
    # from the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link).
    "tpu_v5e": HardwareConfig("tpu_v5e", 197e12, 819e9, 16e9, 50e9, 32e9),
}


def get_hardware(name: str) -> HardwareConfig:
    return HARDWARE[name]


# --------------------------------------------------------------------- #
# batch spec
# --------------------------------------------------------------------- #


@dataclass
class BatchSpec:
    """Phase-split (c, m) pairs for one batch."""

    prefills: List[Tuple[int, int]] = field(default_factory=list)
    decodes: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return (sum(c for c, _ in self.prefills)
                + sum(c for c, _ in self.decodes))

    @property
    def num_requests(self) -> int:
        return len(self.prefills) + len(self.decodes)

    def __bool__(self) -> bool:
        return bool(self.prefills or self.decodes)


# --------------------------------------------------------------------- #
# per-operator FLOPs / RW / comm  (Table 3)
# --------------------------------------------------------------------- #

OPS = ("qkv_proj", "attn_prefill", "attn_decode", "o_proj", "mlp",
       "all_reduce", "others", "head")


def attention_flops_rw(c: int, m: int, cfg: ModelConfig, tp: int,
                       bytes_per_el: int) -> Tuple[float, float]:
    """Paper Eqs. 1-2 for ONE request (B=1), heads sharded over tp.

    FLOPs = 4 c (c+m) H N_Q ;
    RW    = 2 c H N_Q + 2 c (c+m) N_Q + 2 ceil(c/H) (c+m) H N_KV
    (H = head dim; the ceil(c/H) term is the FlashAttention KV re-read per
    query tile).  Sliding-window archs clip the attended span to window.
    """
    H = cfg.head_dim_
    nq = max(1, cfg.num_heads // tp) if cfg.num_heads else 0
    nkv = max(1, cfg.num_kv_heads // tp) if cfg.num_kv_heads else 0
    if nq == 0:
        return 0.0, 0.0
    span = c + m
    if cfg.window:
        span = min(span, cfg.window + c)
    flops = 4.0 * c * span * H * nq
    rw_el = (2.0 * c * H * nq
             + 2.0 * c * span * nq
             + 2.0 * math.ceil(c / H) * span * H * nkv)
    return flops, rw_el * bytes_per_el


def ssm_flops_rw(c: int, cfg: ModelConfig, tp: int,
                 bytes_per_el: int) -> Tuple[float, float]:
    """Recurrent branch (rwkv6 / hymba SSM): state-size-linear in c."""
    if cfg.family == "ssm":          # rwkv: H heads x (D x D) state
        H, D = cfg.ssm_heads, cfg.ssm_state
        state_el = H * D * D / tp
        proj_el = cfg.d_model * cfg.d_model / tp  # r/k/v/g/o projections x5
        flops = c * (2 * 5 * proj_el * tp / tp + 4 * state_el)
        rw = bytes_per_el * (5 * proj_el + c * (2 * state_el + 4 * cfg.d_model))
        return flops, rw
    if cfg.ssm_state:                # hymba mamba branch
        di, N = cfg.d_inner, cfg.ssm_state
        flops = c * (2 * 2 * cfg.d_model * di + 4 * di * N + 2 * di * cfg.d_model) / tp
        rw = bytes_per_el * (3 * cfg.d_model * di / tp
                             + c * (di * N / tp + 4 * cfg.d_model))
        return flops, rw
    return 0.0, 0.0


def op_costs(cfg: ModelConfig, hw: HardwareConfig,
             spec: BatchSpec) -> Dict[str, Tuple[float, float, float]]:
    """Per-operator (FLOPs, RW bytes, comm bytes) for the WHOLE model
    (all layers + LM head), per chip, under TP = hw.tp."""
    tp, bpe = hw.tp, hw.bytes_per_el
    d, L = cfg.d_model, cfg.num_layers
    T = spec.total_tokens
    out: Dict[str, Tuple[float, float, float]] = {}

    has_attn = cfg.num_heads > 0
    qd, kvd = cfg.q_dim, cfg.kv_dim

    # --- qkv / o projections (skip for attention-free archs) ----------- #
    if has_attn:
        w_qkv = d * (qd + 2 * kvd) / tp
        fl = 2.0 * T * w_qkv
        rw = bpe * (w_qkv + T * d + T * (qd + 2 * kvd) / tp)
        out["qkv_proj"] = (L * fl, L * rw, 0.0)
        w_o = qd * d / tp
        fl = 2.0 * T * w_o
        rw = bpe * (w_o + T * qd / tp + T * d)
        out["o_proj"] = (L * fl, L * rw, 0.0)
    else:
        out["qkv_proj"] = (0.0, 0.0, 0.0)
        out["o_proj"] = (0.0, 0.0, 0.0)

    # --- attention (phase-split, per request; Eqs. 1-2) ---------------- #
    for key, items in (("attn_prefill", spec.prefills),
                       ("attn_decode", spec.decodes)):
        fl = rw = 0.0
        for c, m in items:
            if has_attn:
                f, r = attention_flops_rw(c, m, cfg, tp, bpe)
            else:
                f, r = ssm_flops_rw(c, cfg, tp, bpe)
            fl += f
            rw += r
        out[key] = (L * fl, L * rw, 0.0)

    # hybrid archs run BOTH attention and the SSM branch per layer
    if cfg.family == "hybrid":
        fl = rw = 0.0
        for c, _ in spec.prefills + spec.decodes:
            f, r = ssm_flops_rw(c, cfg, tp, bpe)
            fl += f
            rw += r
        f0, r0, _ = out["attn_prefill"]
        out["attn_prefill"] = (f0 + L * fl, r0 + L * rw, 0.0)

    # --- MLP / MoE ------------------------------------------------------ #
    if cfg.num_experts:
        k, ff = cfg.experts_per_token, cfg.moe_d_ff
        e_local = max(1, cfg.padded_experts // tp)
        fl = 2.0 * T * k * 3 * d * ff          # active-expert FLOPs
        fl += cfg.num_shared_experts * 2.0 * T * 3 * d * ff
        fl += 2.0 * T * d * cfg.padded_experts  # router
        fl /= tp
        # weight read: at most all local experts, at most the touched ones
        touched = min(e_local, T * k)
        w_bytes = bpe * (touched + cfg.num_shared_experts) * 3 * d * ff
        rw = w_bytes + bpe * (T * d * 2 + T * k * d / tp)
        out["mlp"] = (L * fl, L * rw, 0.0)
    elif cfg.family == "ssm":
        # rwkv channel-mix: r gate + k/v matmuls
        w = (d * d + 2 * d * cfg.d_ff) / tp
        fl = 2.0 * T * w
        rw = bpe * (w + 2 * T * d + T * cfg.d_ff / tp)
        out["mlp"] = (L * fl, L * rw, 0.0)
    else:
        w = 3.0 * d * cfg.d_ff / tp
        fl = 2.0 * T * w
        rw = bpe * (w + 2 * T * d + T * cfg.d_ff / tp)
        out["mlp"] = (L * fl, L * rw, 0.0)

    # --- TP all-reduce (2 per layer: after attention, after MLP) -------- #
    comm = 0.0
    if tp > 1:
        comm = L * 2.0 * T * d * bpe * 2.0 * (tp - 1) / tp
    out["all_reduce"] = (0.0, 0.0, comm)

    # --- everything else (norms, rope, residuals, sampling) ------------- #
    out["others"] = (L * 10.0 * T * d, L * 6.0 * T * d * bpe, 0.0)

    # --- LM head: only token-emitting positions produce logits ---------- #
    n_logits = len(spec.decodes) + len(spec.prefills)
    w_head = d * cfg.padded_vocab / tp
    fl = 2.0 * n_logits * w_head
    rw = bpe * (w_head + n_logits * (d + cfg.padded_vocab / tp))
    out["head"] = (fl, rw, 0.0)
    return out


# --------------------------------------------------------------------- #
# models
# --------------------------------------------------------------------- #


class CostModel:
    """Interface: batch_time(spec) in seconds, plus the §5.4 preemption
    cost hooks (recompute vs swap) that schedulers and simulators use to
    price a victim's restoration path."""

    def batch_time(self, spec: BatchSpec) -> float:  # pragma: no cover
        raise NotImplementedError

    def op_times(self, spec: BatchSpec) -> Dict[str, float]:  # pragma: no cover
        raise NotImplementedError

    # --- preemption-cost hooks (§5.4 / Fig. 8) ------------------------- #
    def recompute_time(self, n_kvs: int, context: int = 0) -> float:
        """Refill recompute: one prefill of N tokens (§3 refill — the
        cost a discard-preempted request pays on re-admission).
        ``context`` prices a page-level TAIL run: the shed tokens are
        re-prefilled attending over the kept prefix, so a tail
        recompute is costlier per token than a from-scratch refill —
        exactly the asymmetry the per-run swap-vs-recompute crossover
        must see."""
        return self.batch_time(BatchSpec(prefills=[(n_kvs, context)]))

    def kv_projection_time(self, n_kvs: int) -> float:
        """Activation-cached K/V-projection-only rebuild (Fig. 8's
        'recompute' curve).  Models without an operator-level view cannot
        price it separately; default to the realizable full refill."""
        return self.recompute_time(n_kvs)

    def swap_time(self, n_kvs: int) -> float:
        """Host-link transfer time for N KVs (§5.4).  0.0 means 'not
        modeled' — callers (e.g. ``preempt_mode="auto"``) treat that as
        swap-cost-unknown and fall back to recompute."""
        return 0.0


class TheoreticalCostModel(CostModel):
    """Paper Eq. 3 per operator: max(FLOPs/FLOPS, RW/BW) + comm/link_bw.

    ``efficiency`` de-rates peak FLOPS/BW to account for the measured gap
    between theory and practice (Fig. 5-6: attention sits well below the
    roofline); calibrate_efficiency() fits these from profiled samples.
    """

    def __init__(self, cfg: ModelConfig, hw: HardwareConfig, *,
                 flops_eff: float = 1.0, bw_eff: float = 1.0,
                 attn_bw_eff: Optional[float] = None,
                 overhead: float = 0.0):
        self.cfg = cfg
        self.hw = hw
        self.flops_eff = flops_eff
        self.bw_eff = bw_eff
        # Fig. 6: attention under-utilizes bandwidth far more than matmuls
        self.attn_bw_eff = attn_bw_eff if attn_bw_eff is not None else bw_eff
        self.overhead = overhead  # fixed per-batch launch cost (s)

    def op_times(self, spec: BatchSpec) -> Dict[str, float]:
        hw = self.hw
        times: Dict[str, float] = {}
        for op, (fl, rw, comm) in op_costs(self.cfg, hw, spec).items():
            bw_eff = (self.attn_bw_eff if op.startswith("attn")
                      else self.bw_eff)
            t = max(fl / (hw.flops * self.flops_eff),
                    rw / (hw.hbm_bw * bw_eff))
            if comm:
                t = max(t, comm / hw.link_bw)  # overlapped with compute
            times[op] = t
        return times

    def batch_time(self, spec: BatchSpec) -> float:
        if not spec:
            return 0.0
        return sum(self.op_times(spec).values()) + self.overhead

    # --- roofline helpers (§5.2 / Fig. 6) ------------------------------ #
    def batch_terms(self, spec: BatchSpec) -> Dict[str, float]:
        """Aggregate (compute, memory, collective) seconds for the batch."""
        fl = rw = comm = 0.0
        for f, r, c in op_costs(self.cfg, self.hw, spec).values():
            fl += f
            rw += r
            comm += c
        return {
            "compute_s": fl / self.hw.flops,
            "memory_s": rw / self.hw.hbm_bw,
            "collective_s": comm / self.hw.link_bw,
            "flops": fl, "bytes": rw, "comm_bytes": comm,
        }

    def kv_projection_time(self, n_kvs: int) -> float:
        """Activation-cached KV rebuild: only the K/V projections are
        recomputed (the paper's Fig. 8 / §6 'recomputation' — its
        measured t_recom/N in [3.3e-6, 1.3e-3] s is only physically
        possible if layer inputs are cached and the full forward is NOT
        replayed).  Weight-load bias makes per-KV cost FALL with N."""
        L, d, bpe = self.cfg.num_layers, self.cfg.d_model, self.hw.bytes_per_el
        kvd = max(self.cfg.kv_dim, 1)
        flops = L * 2.0 * n_kvs * d * 2 * kvd
        rw = bpe * L * (2 * d * kvd          # K,V projection weights
                        + n_kvs * (d + 2 * kvd))
        return max(flops / (self.hw.flops * self.flops_eff),
                   rw / (self.hw.hbm_bw * self.bw_eff))

    def swap_time(self, n_kvs: int) -> float:
        """Optimal swap-in time for N KVs over the host link (§5.4)."""
        per_tok = self.cfg.kv_bytes_per_token_layer(self.hw.bytes_per_el)
        return n_kvs * per_tok * self.cfg.num_layers / self.hw.host_bw


# --------------------------------------------------------------------- #
# linear (fitted) model — paper §4 "train linear cost models"
# --------------------------------------------------------------------- #

#: feature extractors per operator group (Table 3 variables, all linear)
def _features_nonattn(spec: BatchSpec) -> np.ndarray:
    T = spec.total_tokens
    return np.array([T, 1.0])


def _features_attn_prefill(spec: BatchSpec) -> np.ndarray:
    c2 = sum(float(c) * (c + m) for c, m in spec.prefills)  # ~ c^2 + cm
    c1 = sum(float(c) for c, _ in spec.prefills)
    return np.array([c2, c1, 1.0])


def _features_attn_decode(spec: BatchSpec) -> np.ndarray:
    m1 = sum(float(c + m) for c, m in spec.decodes)  # KVs read
    b = float(len(spec.decodes))
    return np.array([m1, b, 1.0])


def _features_head(spec: BatchSpec) -> np.ndarray:
    return np.array([float(spec.num_requests), 1.0])


FEATURES = {
    "nonattn": _features_nonattn,
    "attn_prefill": _features_attn_prefill,
    "attn_decode": _features_attn_decode,
    "head": _features_head,
}


class LinearCostModel(CostModel):
    """Sum of per-group linear models.  coef[group] @ features(spec)."""

    def __init__(self, coef: Dict[str, np.ndarray]):
        self.coef = {k: np.asarray(v, dtype=np.float64) for k, v in coef.items()}

    def op_times(self, spec: BatchSpec) -> Dict[str, float]:
        return {g: float(np.maximum(self.coef[g], 0.0) @ f(spec))
                for g, f in FEATURES.items()}

    def batch_time(self, spec: BatchSpec) -> float:
        if not spec:
            return 0.0
        return sum(self.op_times(spec).values())

    # persistence ------------------------------------------------------- #
    def to_dict(self) -> Dict[str, list]:
        return {k: v.tolist() for k, v in self.coef.items()}

    @classmethod
    def from_dict(cls, d: Dict[str, Sequence[float]]) -> "LinearCostModel":
        return cls({k: np.asarray(v) for k, v in d.items()})


def fit_linear_model(samples: Sequence[Tuple[BatchSpec, Dict[str, float]]]
                     ) -> LinearCostModel:
    """Least-squares fit per group.  ``samples`` = (spec, group->seconds).

    On real hardware the labels come from profiling (paper step 3); in this
    repo's CPU environment they come from ``profile_synthetic`` (theoretical
    model + measured CPU perturbation) — the *fit machinery* is identical.
    """
    coef: Dict[str, np.ndarray] = {}
    for g, feat in FEATURES.items():
        X = np.stack([feat(s) for s, _ in samples])
        y = np.array([lab[g] for _, lab in samples])
        w, *_ = np.linalg.lstsq(X, y, rcond=None)
        coef[g] = np.maximum(w, 0.0)  # monotonicity (paper: preferable)
    return LinearCostModel(coef)


def group_labels_from_theory(model: TheoreticalCostModel,
                             spec: BatchSpec) -> Dict[str, float]:
    """Collapse the theoretical per-op times into the 4 fitted groups."""
    t = model.op_times(spec)
    return {
        "nonattn": t["qkv_proj"] + t["o_proj"] + t["mlp"] + t["others"]
                   + t["all_reduce"],
        "attn_prefill": t["attn_prefill"],
        "attn_decode": t["attn_decode"],
        "head": t["head"],
    }


def profile_synthetic(cfg: ModelConfig, hw: HardwareConfig, *,
                      seed: int = 0, n: int = 200,
                      noise: float = 0.03,
                      flops_eff: float = 0.6, bw_eff: float = 0.75,
                      attn_bw_eff: float = 0.25
                      ) -> List[Tuple[BatchSpec, Dict[str, float]]]:
    """Generate calibration samples over diverse (c, m, B) — paper §4.

    Labels are theoretical times de-rated by measured-style efficiency
    factors + multiplicative noise, standing in for GPU profiling runs.
    """
    rng = np.random.default_rng(seed)
    truth = TheoreticalCostModel(cfg, hw, flops_eff=flops_eff,
                                 bw_eff=bw_eff, attn_bw_eff=attn_bw_eff)
    samples = []
    for _ in range(n):
        kind = rng.integers(0, 3)
        spec = BatchSpec()
        if kind in (0, 2):  # prefill
            b = int(rng.integers(1, 9))
            for _ in range(b):
                c = int(2 ** rng.uniform(0, 12))
                m = int(2 ** rng.uniform(0, 12)) if rng.random() < 0.5 else 0
                spec.prefills.append((c, m))
        if kind in (1, 2):  # decode
            b = int(rng.integers(1, 129))
            for _ in range(b):
                spec.decodes.append((1, int(2 ** rng.uniform(0, 13))))
        lab = group_labels_from_theory(truth, spec)
        lab = {k: v * float(rng.lognormal(0.0, noise)) for k, v in lab.items()}
        samples.append((spec, lab))
    return samples


def calibrated_cost_model(cfg: ModelConfig, hw: HardwareConfig, *,
                          seed: int = 0) -> LinearCostModel:
    """End-to-end: synthetic profile -> linear fit (the deployed model)."""
    return fit_linear_model(profile_synthetic(cfg, hw, seed=seed))
