"""Gray & Putzolu's five-minute rule applied to KV caches (paper §6).

Break-even interval for keeping the N KVs of a completed request resident
in the KV cache rather than recomputing them on the next access:

    interval(N) = t_recom(N) / N * M            (Eq. 5)

where t_recom(N) is the time to recompute N KVs (one prefill of c = N) and
M the KV-cache capacity in tokens.  The price terms cancel because both
sides are measured in GPU-seconds.  Because t_recom(N)/N *falls* with N
(the fixed weight-load cost amortizes), longer requests have SHORTER
break-even intervals: evict long requests' KVs sooner.

``mode`` selects the regeneration path the interval prices:

* ``"kv_projection"`` — the paper's Fig. 8 measurement: layer inputs
  cached, only K/V projections replayed.
* ``"full"``          — refill-style full forward (the §3 preemption
  cost).
* ``"swap"``          — host-link transfer instead of recompute (§5.4 /
  §6 remark: the interval spectrum broadens with alternatives).  The
  per-KV swap cost is depth-independent, so swap-based intervals are
  FLAT across N — the natural price for a replacement pass over a HOST
  tier whose entries are restored by swap-in (a ROADMAP follow-up).
  The DEVICE-tier ``BreakEvenPolicy`` keeps recompute-based pricing
  even with a demotion tier below it: Eq. 5's long-prefixes-evict-
  sooner ranking is the §6 contribution under test, and a dropped or
  full host tier still regenerates by recompute.

Whatever the mode, ``interval_swap`` also reports the swap-based
interval so tables can show the whole spectrum side by side.

These intervals are not just analytics: ``policies.BreakEvenPolicy``
scores cached-prefix registry entries with them, turning Eq. 5 into the
page pool's live replacement policy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.core.cost_model import CostModel


@dataclass
class BreakEven:
    n_kvs: int
    t_recom: float        # seconds to regenerate N KVs (mode-priced)
    per_kv: float         # t_recom / N
    interval: float       # break-even residency (seconds)
    interval_swap: float  # same, if regeneration is a host swap-in


def break_even_interval(model: CostModel, n_kvs: int,
                        M: int, *, mode: str = "kv_projection") -> BreakEven:
    """Eq. 5 for one request length.  ``mode`` picks the regeneration
    cost (see module docstring); unknown modes and non-positive
    ``n_kvs`` raise ``ValueError``."""
    if n_kvs <= 0:
        raise ValueError(f"n_kvs must be positive, got {n_kvs}")
    ts = model.swap_time(n_kvs)
    if mode == "kv_projection":
        t = model.kv_projection_time(n_kvs)
    elif mode == "full":
        t = model.recompute_time(n_kvs)
    elif mode == "swap":
        t = ts
    else:
        raise ValueError(f"unknown break-even mode {mode!r}")
    return BreakEven(n_kvs=n_kvs, t_recom=t, per_kv=t / n_kvs,
                     interval=t / n_kvs * M,
                     interval_swap=ts / n_kvs * M)


def break_even_table(model: CostModel, M: int,
                     ns: Sequence[int] = (1, 8, 64, 512, 4096, 32768),
                     *, mode: str = "kv_projection") -> List[BreakEven]:
    return [break_even_interval(model, n, M, mode=mode) for n in ns]
