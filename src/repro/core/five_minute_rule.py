"""Gray & Putzolu's five-minute rule applied to KV caches (paper §6).

Break-even interval for keeping the N KVs of a completed request resident
in the KV cache rather than recomputing them on the next access:

    interval(N) = t_recom(N) / N * M            (Eq. 5)

where t_recom(N) is the time to recompute N KVs (one prefill of c = N) and
M the KV-cache capacity in tokens.  The price terms cancel because both
sides are measured in GPU-seconds.  Because t_recom(N)/N *falls* with N
(the fixed weight-load cost amortizes), longer requests have SHORTER
break-even intervals: evict long requests' KVs sooner.

``swap`` variant uses the host-link transfer time instead of recompute
(§5.4 / §6 remark: the interval spectrum broadens with alternatives).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.core.cost_model import TheoreticalCostModel


@dataclass
class BreakEven:
    n_kvs: int
    t_recom: float        # seconds to recompute N KVs
    per_kv: float         # t_recom / N
    interval: float       # break-even residency (seconds)
    interval_swap: float  # same, if regeneration is a host swap-in


def break_even_interval(model: TheoreticalCostModel, n_kvs: int,
                        M: int, *, mode: str = "kv_projection") -> BreakEven:
    """mode='kv_projection' (the paper's Fig. 8 measurement: layer inputs
    cached, only K/V projections replayed) or 'full' (refill-style full
    forward — the §3 preemption cost)."""
    if mode == "kv_projection":
        t = model.kv_projection_time(n_kvs)
    else:
        t = model.recompute_time(n_kvs)
    ts = model.swap_time(n_kvs)
    return BreakEven(n_kvs=n_kvs, t_recom=t, per_kv=t / n_kvs,
                     interval=t / n_kvs * M,
                     interval_swap=ts / n_kvs * M)


def break_even_table(model: TheoreticalCostModel, M: int,
                     ns: Sequence[int] = (1, 8, 64, 512, 4096, 32768),
                     *, mode: str = "kv_projection") -> List[BreakEven]:
    return [break_even_interval(model, n, M, mode=mode) for n in ns]
