"""Paper core: scheduling, cache management, cost models, CSP, 5-min rule."""
from repro.core.cost_model import (  # noqa: F401
    HARDWARE,
    BatchSpec,
    CostModel,
    HardwareConfig,
    LinearCostModel,
    TheoreticalCostModel,
    calibrated_cost_model,
    fit_linear_model,
    get_hardware,
    profile_synthetic,
)
from repro.core.csp import (  # noqa: F401
    CSPResult,
    exists_schedule_below,
    solve_optimal_schedule,
)
from repro.core.five_minute_rule import break_even_interval, break_even_table  # noqa: F401
from repro.core.histogram import OutputLengthHistogram  # noqa: F401
from repro.core.kvcache import (  # noqa: F401
    OutOfPagesError,
    PagedAllocator,
    PrefixCache,
    RadixPrefixRegistry,
    attach_prefix_run,
    chain_keys,
)
from repro.core.policies import (  # noqa: F401
    BeladyOraclePolicy,
    BreakEvenPolicy,
    LRUPolicy,
    ReplacementPolicy,
    belady_future_from_requests,
    group_requests,
    make_replacement_policy,
    select_victim,
)
from repro.core.request import Phase, Request  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    Batch,
    Scheduler,
    SchedulerConfig,
    make_scheduler,
)
from repro.core.simulator import (  # noqa: F401
    PrefixTierSim,
    SimResult,
    fresh_requests,
    run_sim,
    simulate,
)
from repro.core.slo import pareto_curve  # noqa: F401
