"""Internal-state invariants that survive ``python -O``.

A plain ``assert`` vanishes under ``-O``; PR 5 already converted one
such latent bug in ``PrefixCache.insert``.  Invariant guards on the
control plane (allocator tables, store byte accounting, request state
machines) are correctness checks the recovery subsystem depends on —
the chaos suite rolls state back after injected faults and then *runs*
these checks — so they must be real exceptions.

``InvariantError`` subclasses ``AssertionError`` on purpose: callers
(and the existing tests) that treat a violated invariant as an
assertion failure keep working, but the check is always armed.
"""
from __future__ import annotations


class InvariantError(AssertionError):
    """A control-plane invariant was violated (always armed, even -O)."""


def invariant(cond: object, detail: object = None) -> None:
    """Raise :class:`InvariantError` unless ``cond`` is truthy.

    ``detail`` may be any object (it is ``repr``-ed lazily into the
    message) — typically the offending state, mirroring what the old
    ``assert cond, detail`` forms carried.
    """
    if not cond:
        raise InvariantError(detail if detail is not None
                             else "invariant violated")
