"""Cache-insertion priorities and cache-replacement policies — for
REQUESTS (preemption victims) and for the PAGE POOL's prefix registry.

Insertion (GROUPREQUESTS, Table 2):
  * ``prefill_first`` — vLLM: {R_w, R_r}
  * ``decode_first``  — Sarathi/ORCA: {R_r^d, R_r^p, R_w}
Within each group requests are ordered by a ranking key:
  * ``arrival`` (FCFS, default), ``input`` (Rank_I), ``output`` (Rank_O —
    hypothetical: reads r.output_len).

Request replacement (victim selection on memory pressure):
  * ``nrf`` — newest request first (vLLM/Sarathi default)
  * ``srf`` — shortest request first: preempt the request with the fewest
    cached tokens m (the paper's contribution, §8)
  * ``lrf`` — longest request first (ablation / anti-policy)
  * ``pf``  — preemption-free: never select a victim (callers must reserve
    peak memory up front)

Page-pool replacement (``ReplacementPolicy``, the §6 five-minute-rule
contribution): when the free list runs short the ``PagedAllocator``
reclaims cached-prefix registry entries in the order a pluggable policy
ranks them:

  * ``lru``          — least-recently-used entry first (the pre-policy
    hard-wired behaviour; hit-rate-blind under skewed popularity)
  * ``break_even``   — Gray/Putzolu Eq. 5 applied per entry: score each
    cached prefix page by observed idle time over its break-even
    residency interval ``break_even_interval(model, n_kvs, M)``.  The
    interval FALLS with chain depth (weight-load amortizes), so at equal
    idle time LONG prefixes evict sooner — exactly the paper's
    prediction — while frequently-hit short prefixes survive scans that
    flush LRU.
  * ``belady-oracle``— evict the entry whose next access lies farthest
    in the future (offline ablation; needs the workload's future access
    times, e.g. ``belady_future_from_requests``).
"""
from __future__ import annotations

import bisect
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

from repro.core.request import Phase, Request

# --------------------------------------------------------------------- #
# insertion
# --------------------------------------------------------------------- #


def ranking_key(ranking: str):
    if ranking == "arrival":
        return lambda r: (r.arrival, r.rid)
    if ranking == "input":
        return lambda r: (r.input_len, r.arrival, r.rid)
    if ranking == "output":  # hypothetical
        return lambda r: (r.output_len, r.arrival, r.rid)
    raise ValueError(f"unknown ranking {ranking!r}")


def group_requests(waiting: Sequence[Request], running: Sequence[Request], *,
                   priority: str, ranking: str = "arrival") -> List[Request]:
    """Return all candidates in global priority order (paper step 1)."""
    key = ranking_key(ranking)
    w = sorted(waiting, key=key)
    if priority == "prefill_first":
        r = sorted(running, key=key)
        return w + r
    if priority == "decode_first":
        rd = sorted((r for r in running if r.phase == Phase.DECODE), key=key)
        rp = sorted((r for r in running if r.phase == Phase.PREFILL), key=key)
        return rd + rp + w
    raise ValueError(f"unknown priority {priority!r}")


# --------------------------------------------------------------------- #
# replacement
# --------------------------------------------------------------------- #


def select_victim(policy: str, candidates: Sequence[Request]
                  ) -> Optional[Request]:
    """Choose which running request to preempt (paper step 4)."""
    if not candidates or policy == "pf":
        return None
    if policy == "nrf":
        return max(candidates, key=lambda r: (r.arrival, r.rid))
    if policy == "srf":
        return min(candidates, key=lambda r: (r.m, -r.arrival, -r.rid))
    if policy == "lrf":
        return max(candidates, key=lambda r: (r.m, r.arrival, r.rid))
    raise ValueError(f"unknown replacement policy {policy!r}")


# --------------------------------------------------------------------- #
# page-pool replacement (§6 five-minute rule on the prefix registry)
# --------------------------------------------------------------------- #


class ReplacementPolicy:
    """Eviction ranking over cached-prefix registry entries — since the
    radix-trie registry (PR 9), one entry per TRIE NODE, keyed by the
    node's first chain key and scored with the node's END-depth
    ``n_kvs``.

    The ``RadixPrefixRegistry`` feeds every insert/hit/remove through
    the policy, plus :meth:`record_resize` when a node's run grows
    (incremental registration, merges) or shrinks (tail eviction,
    splits) without being touched by a request — depth changes must
    reprice Eq. 5 without counterfeiting recency.  ``eviction_order``
    returns ALL tracked keys, most-evictable first; with ``leaf_of``
    given, current leaves sort before interior nodes (an interior
    eviction would strand live descendants — the registry's sweep
    re-walks as leaves fall, so parents surface in a later pass).
    Drivers skip entries whose pages a live block table still maps
    (evicting those frees nothing).  Higher :meth:`rank` = evict
    earlier; ties break on insertion order, then key, so the order is
    fully deterministic.
    """

    name = "base"

    def __init__(self) -> None:
        self._seq: Dict[int, int] = {}   # key -> insertion sequence no.
        self._n = 0

    def record_insert(self, key: int, n_kvs: int, now: float) -> None:
        self._n += 1
        self._seq[key] = self._n

    def record_hit(self, key: int, now: float) -> None:
        pass

    def record_remove(self, key: int) -> None:
        self._seq.pop(key, None)

    def record_resize(self, key: int, n_kvs: int) -> None:
        """A node's run changed length: update depth-derived state
        WITHOUT refreshing recency (LRU and Belady carry none)."""
        pass

    def rank(self, key: int, now: float) -> float:  # pragma: no cover
        raise NotImplementedError

    def eviction_order(self, now: float,
                       leaf_of: Optional[Callable[[int], bool]] = None
                       ) -> List[int]:
        keys = sorted(self._seq,
                      key=lambda k: (-self.rank(k, now), self._seq[k], k))
        if leaf_of is None:
            return keys
        leaves = [k for k in keys if leaf_of(k)]
        return leaves + [k for k in keys if not leaf_of(k)]

    def __len__(self) -> int:
        return len(self._seq)


class LRUPolicy(ReplacementPolicy):
    """Least-recently-used: insert and hit both refresh recency."""

    name = "lru"

    def record_hit(self, key: int, now: float) -> None:
        self._n += 1
        self._seq[key] = self._n

    def rank(self, key: int, now: float) -> float:
        return -float(self._seq[key])


class BreakEvenPolicy(ReplacementPolicy):
    """Five-minute-rule replacement (paper §6, Eq. 5).

    Each entry carries its chain depth ``n_kvs`` (the prefix length the
    page terminates, in tokens).  Break-even residency
    ``B(n) = t_regen(n)/n * M`` falls with ``n`` — regenerating a long
    prefix is cheap PER KV because the weight-load cost amortizes — so
    the score ``idle / B(n)`` evicts the entry whose expected
    regeneration cost per freed page is lowest: long cold prefixes go
    first, frequently-hit short prefixes stay resident well past an LRU
    horizon.  ``mode`` selects which regeneration path prices ``B``
    (``kv_projection`` — Fig. 8's activation-cached rebuild — ``full``,
    or ``swap`` when a host demotion tier makes the swap-in the actual
    regeneration cost).
    """

    name = "break_even"

    def __init__(self, cost_model, M: int, *,
                 mode: str = "kv_projection") -> None:
        super().__init__()
        if cost_model is None or M <= 0:
            raise ValueError(f"break_even needs a cost model and M > 0, "
                             f"got {(cost_model, M)}")
        self.cost_model = cost_model
        self.M = M
        self.mode = mode
        self._meta: Dict[int, Tuple[int, float]] = {}  # key -> (n, last)
        self._intervals: Dict[int, float] = {}

    def _interval(self, n_kvs: int) -> float:
        iv = self._intervals.get(n_kvs)
        if iv is None:
            from repro.core.five_minute_rule import break_even_interval
            iv = break_even_interval(self.cost_model, n_kvs, self.M,
                                     mode=self.mode).interval
            iv = max(iv, 1e-12)        # swap-unmodeled cost models -> 0
            self._intervals[n_kvs] = iv
        return iv

    def record_insert(self, key: int, n_kvs: int, now: float) -> None:
        super().record_insert(key, n_kvs, now)
        self._meta[key] = (max(int(n_kvs), 1), now)

    def record_hit(self, key: int, now: float) -> None:
        n, _ = self._meta[key]
        self._meta[key] = (n, now)

    def record_resize(self, key: int, n_kvs: int) -> None:
        # node-depth-aware n_kvs: a tail eviction/split shrinks the
        # node's end depth, a merge/extension grows it — reprice Eq. 5
        # at the new depth but keep the observed last-hit time
        _, last = self._meta[key]
        self._meta[key] = (max(int(n_kvs), 1), last)

    def record_remove(self, key: int) -> None:
        super().record_remove(key)
        self._meta.pop(key, None)

    def rank(self, key: int, now: float) -> float:
        n, last = self._meta[key]
        return max(now - last, 0.0) / self._interval(n)


class BeladyOraclePolicy(ReplacementPolicy):
    """Offline MIN/OPT ablation: evict the entry whose NEXT access lies
    farthest in the future (never-again entries first).  ``future`` maps
    each chain key to its access times; entries with no future entry are
    treated as never accessed again."""

    name = "belady"

    def __init__(self, future: Optional[Dict[int, Sequence[float]]] = None
                 ) -> None:
        super().__init__()
        self.future: Dict[int, List[float]] = {
            k: sorted(v) for k, v in (future or {}).items()}

    def rank(self, key: int, now: float) -> float:
        times = self.future.get(key)
        if times:
            i = bisect.bisect_right(times, now)
            if i < len(times):
                return times[i]
        return float("inf")


def make_replacement_policy(name: str, *, cost_model=None, M: int = 0,
                            mode: str = "kv_projection",
                            future: Optional[Dict[int, Sequence[float]]]
                            = None) -> ReplacementPolicy:
    """Factory for the page-pool policies (``SchedulerConfig.
    cache_policy`` names land here)."""
    key = name.lower().replace("-", "_")
    if key == "lru":
        return LRUPolicy()
    if key == "break_even":
        if cost_model is None or M <= 0:
            raise ValueError(
                "break_even replacement needs a cost model and M > 0")
        return BreakEvenPolicy(cost_model, M, mode=mode)
    if key in ("belady", "belady_oracle"):
        return BeladyOraclePolicy(future)
    raise ValueError(f"unknown cache replacement policy {name!r}")


def belady_future_from_requests(requests: Iterable[Request],
                                page_size: int
                                ) -> Dict[int, List[float]]:
    """Chain-key -> sorted arrival times over a known offline workload —
    the oracle's future-access table (requests need real prompts).
    Trie nodes are keyed by their FIRST chain key, so per-page futures
    index node entries directly (the oracle sees the node's head)."""
    from repro.core.kvcache import chain_keys

    future: Dict[int, List[float]] = {}
    for r in requests:
        if r.prompt is None:
            continue
        for key in chain_keys(r.prompt, page_size):
            future.setdefault(key, []).append(r.arrival)
    return {k: sorted(v) for k, v in future.items()}
