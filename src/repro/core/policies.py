"""Cache-insertion priorities and cache-replacement (preemption) policies.

Insertion (GROUPREQUESTS, Table 2):
  * ``prefill_first`` — vLLM: {R_w, R_r}
  * ``decode_first``  — Sarathi/ORCA: {R_r^d, R_r^p, R_w}
Within each group requests are ordered by a ranking key:
  * ``arrival`` (FCFS, default), ``input`` (Rank_I), ``output`` (Rank_O —
    hypothetical: reads r.output_len).

Replacement (victim selection on memory pressure):
  * ``nrf`` — newest request first (vLLM/Sarathi default)
  * ``srf`` — shortest request first: preempt the request with the fewest
    cached tokens m (the paper's contribution, §8)
  * ``lrf`` — longest request first (ablation / anti-policy)
  * ``pf``  — preemption-free: never select a victim (callers must reserve
    peak memory up front)
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

from repro.core.request import Phase, Request

# --------------------------------------------------------------------- #
# insertion
# --------------------------------------------------------------------- #


def ranking_key(ranking: str):
    if ranking == "arrival":
        return lambda r: (r.arrival, r.rid)
    if ranking == "input":
        return lambda r: (r.input_len, r.arrival, r.rid)
    if ranking == "output":  # hypothetical
        return lambda r: (r.output_len, r.arrival, r.rid)
    raise ValueError(f"unknown ranking {ranking!r}")


def group_requests(waiting: Sequence[Request], running: Sequence[Request], *,
                   priority: str, ranking: str = "arrival") -> List[Request]:
    """Return all candidates in global priority order (paper step 1)."""
    key = ranking_key(ranking)
    w = sorted(waiting, key=key)
    if priority == "prefill_first":
        r = sorted(running, key=key)
        return w + r
    if priority == "decode_first":
        rd = sorted((r for r in running if r.phase == Phase.DECODE), key=key)
        rp = sorted((r for r in running if r.phase == Phase.PREFILL), key=key)
        return rd + rp + w
    raise ValueError(f"unknown priority {priority!r}")


# --------------------------------------------------------------------- #
# replacement
# --------------------------------------------------------------------- #


def select_victim(policy: str, candidates: Sequence[Request]
                  ) -> Optional[Request]:
    """Choose which running request to preempt (paper step 4)."""
    if not candidates or policy == "pf":
        return None
    if policy == "nrf":
        return max(candidates, key=lambda r: (r.arrival, r.rid))
    if policy == "srf":
        return min(candidates, key=lambda r: (r.m, -r.arrival, -r.rid))
    if policy == "lrf":
        return max(candidates, key=lambda r: (r.m, r.arrival, r.rid))
    raise ValueError(f"unknown replacement policy {policy!r}")
