"""Optimal scheduling as a constraint-satisfaction/optimization problem
(paper §7) — solved EXACTLY by uniform-cost search.

The paper encodes variables (s, m, c, g, e) per (request, batch) with
Big-M linearization and hands the MILP to Gurobi.  Gurobi is unavailable
offline, so this module solves the *same* constraint system by Dijkstra
over schedule states:

  state   = multiset of per-request (I, O, m, g)      [identical requests
            are interchangeable -> symmetry-reduced]
  edge    = one batch: per request an action from
            {skip, evict, run(c)} with c in {full remaining,
            crop-to-C-budget, crop-to-M-room}   [the paper's constraint
            (7) allows ANY c <= s - m; restricting to these break points
            preserves optimality for monotone cost models because any
            other chunk is dominated by one of them — a partial chunk
            neither generates a token nor frees memory earlier]
  cost    = cost_model.batch_time(batch)              [monotone, so
            Dijkstra's first settlement of the goal state is optimal]

Constraints enforced on every edge (paper's Termination, Memory
Management, Tokens-to-Process, Token Generation, Batch constraints):
  sum(c) <= C;  sum(m') <= M;  m' = 0 if evicted else m + c;
  g' = g + 1 iff c == (I + g) - m  (all remaining tokens processed);
  request finished when g == O (its KVs leave the cache: peak m = I+O-1).

Used by Fig. 13 (preemption can be optimal) and by "does a schedule
>= 10% better exist?" queries (``exists_schedule_below``).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.cost_model import BatchSpec, CostModel
from repro.core.invariants import invariant

# per-request key: (I, O, m, g)
ReqState = Tuple[int, int, int, int]
State = Tuple[ReqState, ...]

# an action applied to the request at a given state-index
#   ("run", c) | ("evict",) | ("skip",)
Action = Tuple


@dataclass
class CSPResult:
    optimal_time: float
    schedule: List[List[Tuple[ReqState, Action]]]
    num_batches: int
    num_preemptions: int
    states_expanded: int
    feasible: bool = True


def _initial_state(requests: Sequence[Tuple[int, int]]) -> State:
    return tuple(sorted((I, O, 0, 0) for I, O in requests))


def _is_goal(state: State) -> bool:
    return all(g >= O for (_, O, _, g) in state)


def _spec_of_actions(state: State, actions: Sequence[Action]) -> BatchSpec:
    spec = BatchSpec()
    for (I, O, m, g), act in zip(state, actions):
        if act[0] != "run":
            continue
        c = act[1]
        s = I + g
        if g > 0 and c == 1 and m == s - 1:
            spec.decodes.append((c, m))
        else:
            spec.prefills.append((c, m))
    return spec


def _apply(state: State, actions: Sequence[Action]) -> State:
    out = []
    for (I, O, m, g), act in zip(state, actions):
        if g >= O:                      # finished — stays finished
            out.append((I, O, 0, g))
            continue
        if act[0] == "evict":
            out.append((I, O, 0, g))
            continue
        if act[0] == "run":
            c = act[1]
            s = I + g
            m2 = m + c
            invariant(m2 <= s, (state, actions))
            if m2 == s:                 # token generated
                g2 = g + 1
                m2 = 0 if g2 >= O else m2   # completion frees memory
                out.append((I, O, m2, g2))
            else:
                out.append((I, O, m2, g))
            continue
        out.append((I, O, m, g))        # skip
    return tuple(sorted(out))


def _enumerate_batches(state: State, M: int, C: int,
                       max_actions_per_state: int = 200_000
                       ) -> List[Tuple[Action, ...]]:
    """All feasible per-request action tuples for one batch."""
    n = len(state)
    results: List[Tuple[Action, ...]] = []

    def rec(i: int, budget_c: int, mem_after: int, acc: List[Action]):
        if len(results) >= max_actions_per_state:
            return
        if i == n:
            # at least one request must run (empty batches are pointless)
            if any(a[0] == "run" for a in acc):
                results.append(tuple(acc))
            return
        I, O, m, g = state[i]
        if g >= O:                       # finished
            rec(i + 1, budget_c, mem_after, acc + [("skip",)])
            return
        remaining = (I + g) - m          # tokens still to process
        # candidate c values: full remaining, crop to batch budget,
        # crop to memory room (chunked prefill break points)
        mem_room = M - mem_after - m     # extra tokens this req may cache
        for c in {remaining, min(remaining, budget_c),
                  min(remaining, mem_room)}:
            if c <= 0 or c > budget_c:
                continue
            # memory after processing: m + c (cleared on completion)
            m2 = m + c
            if m2 > M - mem_after:
                continue
            gen = (m2 == I + g)
            freed = gen and (g + 1 >= O)
            hold = 0 if freed else m2
            rec(i + 1, budget_c - c, mem_after + hold, acc + [("run", c)])
        # skip (keep memory)
        if mem_after + m <= M:
            rec(i + 1, budget_c, mem_after + m, acc + [("skip",)])
        # evict (free memory) — only meaningful if it holds any
        if m > 0:
            rec(i + 1, budget_c, mem_after, acc + [("evict",)])

    rec(0, C, 0, [])
    return results


def solve_optimal_schedule(requests: Sequence[Tuple[int, int]], *,
                           M: int, C: int, cost_model: CostModel,
                           batch_time_bound: Optional[float] = None,
                           latency_bound: Optional[float] = None,
                           max_expansions: int = 2_000_000) -> CSPResult:
    """Uniform-cost search for the minimum-latency schedule.

    requests: [(I, O)] — offline (all arrive at t=0), as in Fig. 13.
    """
    start = _initial_state(requests)
    dist: Dict[State, float] = {start: 0.0}
    parent: Dict[State, Tuple[State, Tuple[Action, ...]]] = {}
    pq: List[Tuple[float, int, State]] = [(0.0, 0, start)]
    tie = itertools.count(1)
    expanded = 0

    goal: Optional[State] = None
    while pq:
        d, _, state = heapq.heappop(pq)
        if d > dist.get(state, float("inf")) + 1e-15:
            continue
        if latency_bound is not None and d > latency_bound:
            continue
        if _is_goal(state):
            goal = state
            break
        expanded += 1
        if expanded > max_expansions:
            raise RuntimeError("CSP search exceeded max_expansions")
        for actions in _enumerate_batches(state, M, C):
            spec = _spec_of_actions(state, actions)
            dt = cost_model.batch_time(spec)
            if batch_time_bound is not None and dt > batch_time_bound:
                continue
            nxt = _apply(state, actions)
            nd = d + dt
            if nd < dist.get(nxt, float("inf")) - 1e-15:
                dist[nxt] = nd
                parent[nxt] = (state, actions)
                heapq.heappush(pq, (nd, next(tie), nxt))

    if goal is None:
        return CSPResult(float("inf"), [], 0, 0, expanded, feasible=False)

    # reconstruct
    schedule: List[List[Tuple[ReqState, Action]]] = []
    preemptions = 0
    cur = goal
    while cur in parent:
        prev, actions = parent[cur]
        step = list(zip(prev, actions))
        preemptions += sum(1 for _, a in step if a[0] == "evict")
        schedule.append(step)
        cur = prev
    schedule.reverse()
    return CSPResult(optimal_time=dist[goal], schedule=schedule,
                     num_batches=len(schedule),
                     num_preemptions=preemptions,
                     states_expanded=expanded)


def exists_schedule_below(requests: Sequence[Tuple[int, int]], *, M: int,
                          C: int, cost_model: CostModel,
                          bound: float) -> bool:
    """Paper §7: 'validate whether a better schedule exists that could
    reduce the latency of current schedules by 10%' — existence query."""
    res = solve_optimal_schedule(requests, M=M, C=C, cost_model=cost_model,
                                 latency_bound=bound)
    return res.feasible and res.optimal_time < bound


def schedule_uses_preemption(result: CSPResult) -> bool:
    return result.num_preemptions > 0
