"""Inference request state machine (paper §3, Table 1).

Bookkeeping invariants (checked by property tests):
  * ``m``          — processed tokens currently held in the KV cache
  * ``generated``  — output tokens produced so far
  * target context = I + generated  (refill reprocesses generated tokens)
  * a token is generated exactly when m reaches I + generated
  * peak KV usage  = I + O - 1  (the O-th token is never cached)
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"   # running, still processing prompt (or refill)
    DECODE = "decode"     # running, generating
    FINISHED = "finished"


@dataclass
class Request:
    rid: int
    input_len: int                     # I
    output_len: int                    # O — ground truth; ONLY hypothetical
    #                                    schedulers / the simulator read it.
    arrival: float = 0.0
    prompt: Optional[List[int]] = None  # real token ids (engine mode)

    # --- dynamic state ---
    m: int = 0
    generated: int = 0
    running: bool = False
    preemptions: int = 0
    # --- metrics ---
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    # --- SRF+Hist bookkeeping ---
    predicted_output: Optional[float] = None

    # ------------------------------------------------------------------ #
    @property
    def target_context(self) -> int:
        """Tokens that must be in cache before the next token can emerge."""
        return self.input_len + self.generated

    @property
    def remaining_prefill(self) -> int:
        return max(0, self.target_context - self.m)

    @property
    def phase(self) -> Phase:
        if self.finished:
            return Phase.FINISHED
        if not self.running:
            return Phase.WAITING
        # decode = only the last generated token remains to process
        if self.generated > 0 and self.remaining_prefill <= 1:
            return Phase.DECODE
        return Phase.PREFILL

    @property
    def finished(self) -> bool:
        return self.generated >= self.output_len

    @property
    def peak_kv(self) -> int:
        return self.input_len + self.output_len - 1

    # ------------------------------------------------------------------ #
    def advance(self, c: int, now: float) -> bool:
        """Process c tokens; returns True if a token was generated."""
        assert self.running and c >= 1, (self.rid, self.running, c)
        assert self.m + c <= self.target_context, "over-processing"
        self.m += c
        if self.m == self.target_context:
            # prefill completed, or decode step -> one new token
            self.generated += 1
            self.token_times.append(now)
            if self.first_token_time is None:
                self.first_token_time = now
            if self.finished:
                self.finish_time = now
                self.running = False
                self.m = 0
            return True
        return False

    def preempt(self) -> int:
        """Evict all KVs; back to waiting. Returns tokens released."""
        released = self.m
        self.m = 0
        self.running = False
        self.preemptions += 1
        return released

    # --- metrics helpers ------------------------------------------------ #
    def latency(self) -> Optional[float]:
        return None if self.finish_time is None else self.finish_time - self.arrival

    def ttft(self) -> Optional[float]:
        return (None if self.first_token_time is None
                else self.first_token_time - self.arrival)

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or len(self.token_times) < 2:
            return None
        return ((self.token_times[-1] - self.token_times[0])
                / (len(self.token_times) - 1))
