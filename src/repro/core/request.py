"""Inference request state machine (paper §3, Table 1).

Bookkeeping invariants (checked by property tests):
  * ``m``          — processed tokens currently held in the KV cache
  * ``generated``  — output tokens produced so far
  * target context = I + generated  (refill reprocesses generated tokens)
  * a token is generated exactly when m reaches I + generated
  * peak KV usage  = I + O - 1  (the O-th token is never cached)
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"   # running, still processing prompt (or refill)
    DECODE = "decode"     # running, generating
    FINISHED = "finished"


@dataclass
class Request:
    rid: int
    input_len: int                     # I
    output_len: int                    # O — ground truth; ONLY hypothetical
    #                                    schedulers / the simulator read it.
    arrival: float = 0.0
    prompt: Optional[List[int]] = None  # real token ids (engine mode)

    # --- dynamic state ---
    m: int = 0
    generated: int = 0
    running: bool = False
    preemptions: int = 0
    # --- swap/suspend state (§5.4) ---
    # A swap-preempted request keeps its KVs in HOST memory instead of
    # discarding them: ``suspended_m`` KVs are held by the swap store and
    # restored on re-admission, so no refill prefill is needed.
    suspended: bool = False
    suspended_m: int = 0
    swaps: int = 0
    # --- metrics ---
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    # --- SRF+Hist bookkeeping ---
    predicted_output: Optional[float] = None

    # ------------------------------------------------------------------ #
    @property
    def target_context(self) -> int:
        """Tokens that must be in cache before the next token can emerge."""
        return self.input_len + self.generated

    @property
    def resident_kv(self) -> int:
        """KVs this request will hold on-device once (re)admitted, before
        processing: swapped-out KVs count — they are restored, not
        recomputed — so schedulers reserve for them and drivers skip the
        refill."""
        return self.suspended_m if self.suspended else self.m

    @property
    def remaining_prefill(self) -> int:
        return max(0, self.target_context - self.resident_kv)

    @property
    def phase(self) -> Phase:
        if self.finished:
            return Phase.FINISHED
        if not self.running:
            return Phase.WAITING
        # decode = only the last generated token remains to process
        if self.generated > 0 and self.remaining_prefill <= 1:
            return Phase.DECODE
        return Phase.PREFILL

    @property
    def finished(self) -> bool:
        return self.generated >= self.output_len

    @property
    def peak_kv(self) -> int:
        return self.input_len + self.output_len - 1

    # ------------------------------------------------------------------ #
    def advance(self, c: int, now: float) -> bool:
        """Process c tokens; returns True if a token was generated."""
        assert self.running and c >= 1, (self.rid, self.running, c)
        assert self.m + c <= self.target_context, "over-processing"
        self.m += c
        if self.m == self.target_context:
            # prefill completed, or decode step -> one new token
            self.generated += 1
            self.token_times.append(now)
            if self.first_token_time is None:
                self.first_token_time = now
            if self.finished:
                self.finish_time = now
                self.running = False
                self.m = 0
            return True
        return False

    def preempt(self, mode: str = "recompute") -> int:
        """Evict all device KVs; back to waiting. Returns tokens released.

        ``mode="swap"`` marks the KVs as suspended to host memory (§5.4):
        the driver must snapshot them before reusing the slot and restore
        them via :meth:`resume` on re-admission.  ``mode="recompute"``
        discards them (the §3 refill pays a full re-prefill).  A request
        with no cached KVs has nothing to swap and falls back to discard.
        """
        assert mode in ("recompute", "swap"), mode
        released = self.m
        if mode == "swap" and self.m > 0:
            self.suspended = True
            self.suspended_m = self.m
            self.swaps += 1
        else:
            self.suspended = False
            self.suspended_m = 0
        self.m = 0
        self.running = False
        self.preemptions += 1
        return released

    def drop_suspended(self) -> None:
        """The driver could not keep the snapshot (host store full): this
        preemption falls back to discard-and-recompute — the request pays
        the full §3 refill on re-admission after all."""
        assert self.suspended, self.rid
        self.suspended = False
        self.suspended_m = 0
        self.swaps -= 1

    def resume(self) -> int:
        """Swap-in: the driver restored ``suspended_m`` KVs to the device.
        Returns the number of restored tokens."""
        assert self.suspended, self.rid
        restored = self.suspended_m
        self.m = restored
        self.suspended = False
        self.suspended_m = 0
        return restored

    # --- metrics helpers ------------------------------------------------ #
    def latency(self) -> Optional[float]:
        return None if self.finish_time is None else self.finish_time - self.arrival

    def ttft(self) -> Optional[float]:
        return (None if self.first_token_time is None
                else self.first_token_time - self.arrival)

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or len(self.token_times) < 2:
            return None
        return ((self.token_times[-1] - self.token_times[0])
                / (len(self.token_times) - 1))
