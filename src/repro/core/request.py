"""Inference request state machine (paper §3, Table 1).

Bookkeeping invariants (checked by property tests):
  * ``m``          — processed tokens currently held in the KV cache
  * ``generated``  — output tokens produced so far
  * target context = I + generated  (refill reprocesses generated tokens)
  * a token is generated exactly when m reaches I + generated
  * peak KV usage  = I + O - 1  (the O-th token is never cached)
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.invariants import invariant


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"   # running, still processing prompt (or refill)
    DECODE = "decode"     # running, generating
    FINISHED = "finished"


@dataclass
class Request:
    rid: int
    input_len: int                     # I
    output_len: int                    # O — ground truth; ONLY hypothetical
    #                                    schedulers / the simulator read it.
    arrival: float = 0.0
    prompt: Optional[List[int]] = None  # real token ids (engine mode)

    # --- dynamic state ---
    m: int = 0
    generated: int = 0
    running: bool = False
    preemptions: int = 0
    # --- swap/suspend state (§5.4) ---
    # A swap-preempted request keeps its KVs in HOST memory instead of
    # discarding them: ``suspended_m`` KVs are held by the swap store and
    # restored on re-admission, so no refill prefill is needed.
    suspended: bool = False
    suspended_m: int = 0
    swaps: int = 0
    # --- page-level partial preemption (§8 at sub-request granularity) ---
    # Under memory pressure a paged scheduler may shed only the victim's
    # TAIL pages instead of the whole request: ``tail_suspended_m`` tail
    # tokens live in the host store (page runs) and are restored before
    # the request's next compute step; a recompute-mode shed simply
    # lowers ``m`` to the kept page boundary and the tokens rejoin
    # ``remaining_prefill``.
    tail_suspended_m: int = 0
    partial_preemptions: int = 0
    # tokens that must cross the host link for the CURRENT full suspend
    # (the device-resident portion only: tail runs shed earlier were
    # already charged when they left) — drivers price swap-out with this,
    # and swap-in with ``suspended_m`` (everything comes back).
    swap_out_m: int = 0
    # --- metrics ---
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    # --- SRF+Hist bookkeeping ---
    predicted_output: Optional[float] = None

    # ------------------------------------------------------------------ #
    @property
    def target_context(self) -> int:
        """Tokens that must be in cache before the next token can emerge."""
        return self.input_len + self.generated

    @property
    def resident_kv(self) -> int:
        """KVs this request will hold on-device once (re)admitted, before
        processing: swapped-out KVs count — they are restored, not
        recomputed — so schedulers reserve for them and drivers skip the
        refill.  Suspended TAIL pages count too: they come back on-device
        before the request's next compute step."""
        if self.suspended:
            return self.suspended_m
        return self.m + self.tail_suspended_m

    @property
    def device_kv(self) -> int:
        """KVs physically on-device RIGHT NOW (idle reservation): a
        tail-suspended request holds only its kept prefix until the
        driver restores the tail at its next batch."""
        return 0 if self.suspended else self.m

    @property
    def remaining_prefill(self) -> int:
        return max(0, self.target_context - self.resident_kv)

    @property
    def phase(self) -> Phase:
        if self.finished:
            return Phase.FINISHED
        if not self.running:
            return Phase.WAITING
        # decode = only the last generated token remains to process
        if self.generated > 0 and self.remaining_prefill <= 1:
            return Phase.DECODE
        return Phase.PREFILL

    @property
    def finished(self) -> bool:
        return self.generated >= self.output_len

    @property
    def peak_kv(self) -> int:
        return self.input_len + self.output_len - 1

    # ------------------------------------------------------------------ #
    def advance(self, c: int, now: float) -> bool:
        """Process c tokens; returns True if a token was generated."""
        invariant(self.running and c >= 1, (self.rid, self.running, c))
        invariant(self.m + c <= self.target_context, "over-processing")
        self.m += c
        if self.m == self.target_context:
            # prefill completed, or decode step -> one new token
            self.generated += 1
            self.token_times.append(now)
            if self.first_token_time is None:
                self.first_token_time = now
            if self.finished:
                self.finish_time = now
                self.running = False
                self.m = 0
            return True
        return False

    def preempt(self, mode: str = "recompute") -> int:
        """Evict all device KVs; back to waiting. Returns tokens released.

        ``mode="swap"`` marks the KVs as suspended to host memory (§5.4):
        the driver must snapshot them before reusing the slot and restore
        them via :meth:`resume` on re-admission.  ``mode="recompute"``
        discards them (the §3 refill pays a full re-prefill).  A request
        with no cached KVs has nothing to swap and falls back to discard.

        Pending tail runs fold into the full suspend: a swap-mode full
        preemption keeps them in the host store (``suspended_m`` covers
        device + tail tokens); a recompute-mode one discards everything
        (the driver must drop the stored runs).
        """
        if mode not in ("recompute", "swap"):
            raise ValueError(f"preempt mode={mode!r}")
        released = self.m
        if mode == "swap" and self.m + self.tail_suspended_m > 0:
            self.suspended = True
            self.suspended_m = self.m + self.tail_suspended_m
            self.swap_out_m = self.m
            self.swaps += 1
        else:
            self.suspended = False
            self.suspended_m = 0
            self.swap_out_m = 0
        self.tail_suspended_m = 0
        self.m = 0
        self.running = False
        self.preemptions += 1
        return released

    # --- page-level partial preemption ---------------------------------- #
    def partial_preempt(self, n_tokens: int, mode: str = "recompute") -> int:
        """Shed ``n_tokens`` TAIL tokens (whole pages) under memory
        pressure; the request KEEPS its slot and stays running.
        ``mode="swap"`` sends the run to host memory (restored before the
        next compute step); ``mode="recompute"`` re-prefills the tokens
        later.  Returns the tokens shed."""
        if mode not in ("recompute", "swap"):
            raise ValueError(f"partial_preempt mode={mode!r}")
        invariant(self.running and 0 < n_tokens <= self.m,
                  (self.rid, self.running, n_tokens, self.m))
        self.m -= n_tokens
        self.partial_preemptions += 1
        if mode == "swap":
            self.tail_suspended_m += n_tokens
            self.swaps += 1
        return n_tokens

    def resume_tail(self) -> int:
        """Tail swap-in: the driver restored the suspended tail pages.
        Returns the number of restored tokens."""
        invariant(self.tail_suspended_m > 0, self.rid)
        restored = self.tail_suspended_m
        self.m += restored
        self.tail_suspended_m = 0
        return restored

    def drop_tail_run(self, n_tokens: int) -> None:
        """The driver could not keep a tail run (host store full): those
        tokens fall back to recompute via ``remaining_prefill``."""
        invariant(0 < n_tokens <= self.tail_suspended_m,
                  (self.rid, n_tokens, self.tail_suspended_m))
        self.tail_suspended_m -= n_tokens
        self.swaps -= 1

    def drop_suspended(self) -> None:
        """The driver could not keep the snapshot (host store full): this
        preemption falls back to discard-and-recompute — the request pays
        the full §3 refill on re-admission after all."""
        invariant(self.suspended, self.rid)
        self.suspended = False
        self.suspended_m = 0
        self.swaps -= 1

    def resume(self) -> int:
        """Swap-in: the driver restored ``suspended_m`` KVs to the device.
        Returns the number of restored tokens."""
        invariant(self.suspended, self.rid)
        restored = self.suspended_m
        self.m = restored
        self.suspended = False
        self.suspended_m = 0
        return restored

    # --- metrics helpers ------------------------------------------------ #
    def latency(self) -> Optional[float]:
        return None if self.finish_time is None else self.finish_time - self.arrival

    def ttft(self) -> Optional[float]:
        return (None if self.first_token_time is None
                else self.first_token_time - self.arrival)

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or len(self.token_times) < 2:
            return None
        return ((self.token_times[-1] - self.token_times[0])
                / (len(self.token_times) - 1))
