"""Unified scheduler — the paper's Algorithm 1.

One loop captures ORCA / vLLM / Sarathi / preemption-free variants plus the
SRF family, via four orthogonal knobs:

  priority     prefill_first | decode_first          (GROUPREQUESTS, step 1)
  hybrid       mixed prefill+decode batches?         (CHECKHYBRIDBATCHING, 2)
  chunked      crop prefill c to the token budget?   (CANALLOCATE, step 3)
  replacement  nrf | srf | lrf | pf                  (PREEMPT..., step 4)
  reserve      input | peak | context                (Table 2 "initial KV
               reserve": r.I, r.I+r.O-1 [hypothetical], or S [ORCA])

``get_next_batch`` is pure control logic over Request objects; the
simulator (cost-model time) and the serving engine (real JAX execution)
both drive it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Any, Dict, List, Optional, Sequence,
                    Tuple)

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.cost_model import CostModel

from repro.core.histogram import OutputLengthHistogram
from repro.core.invariants import invariant
from repro.core.policies import group_requests, ranking_key, select_victim
from repro.core.request import Phase, Request


@dataclass
class SchedulerConfig:
    M: int                       # KV cache size (tokens)
    C: int                       # token limit per batch
    S: int = 4096                # model context size (ORCA reservation)
    priority: str = "prefill_first"
    replacement: str = "nrf"     # nrf | srf | lrf | pf
    reserve: str = "input"       # input | peak | context
    hybrid: bool = False
    chunked: bool = False
    ranking: str = "arrival"     # arrival | input | output
    max_batch_requests: int = 0  # 0 = unbounded
    use_histogram: bool = False  # SRF+Hist admission gate
    # Real inference systems (vLLM v0.6.x) never evict running requests to
    # admit NEW prefills — preemption triggers only when a *running*
    # request cannot grow.  The paper's literal Algorithm-1 allows
    # admission-preemption; keep it as an opt-in knob.
    admission_can_preempt: bool = False
    max_running: int = 0         # concurrent-request cap (engine slots)
    # What happens to a victim's KVs (§5.4 recompute-vs-swap):
    #   recompute — discard; re-admission pays a full refill prefill (§3)
    #   swap      — suspend to host memory; re-admission restores them
    #               over the host link (no refill)
    #   auto      — per-victim Fig. 8 decision: swap iff the cost model's
    #               swap_time(m) undercuts its cheapest recompute path
    preempt_mode: str = "recompute"
    # Allocator granularity.  With page_size > 1 every reservation is
    # rounded UP to whole pages and the capacity is the allocator's
    # page-rounded ceil(M/page)*page, so the control plane's sum(m) <= M
    # agrees with the PagedAllocator page-for-page: OutOfPagesError is
    # unreachable on admitted schedules (internal fragmentation is
    # charged, never discovered).
    page_size: int = 1
    # Page-level partial preemption (§8 SRF pushed to sub-request
    # granularity): on memory pressure shed only the victim's TAIL pages
    # — the Fig. 8 crossover decides swap-vs-recompute per page run.
    # Requires a paged data plane (the engine enforces plane="paged").
    partial_preempt: bool = False
    # Page-pool cache replacement (§6 five-minute rule): which
    # ``policies.ReplacementPolicy`` the prefix registry evicts by —
    # "lru" | "break_even" | "belady-oracle" (offline ablation).
    # Declared HERE so control plane (simulator shadow charging) and
    # data plane (engine allocator) read one source and agree on which
    # tier every prefix lands in.
    cache_policy: str = "lru"
    # Host demotion tier: evicted prefix pages are demoted into the
    # KVSwapStore instead of discarded, and a registry hit on a
    # host-resident prefix promotes it back through the swap path,
    # charged ``cost_model.swap_time`` (virtual AND wall time).
    cache_demotion: bool = False
    # Prefix-registry lookup mode (PR 9 radix trie):
    #   trie  — radix longest-prefix walk; PARTIAL hits attach the
    #           longest cached run even when the full prompt misses
    #   exact — all-or-nothing device-only ablation (the pre-trie
    #           chained-hash behaviour): any shortfall attaches nothing
    prefix_lookup: str = "trie"
    # Deterministic fault injection (a ``serving.faults.FaultSpec``;
    # typed Any to keep core/ import-free of serving/).  Declared here
    # like page_size so the engine AND the simulator build their fault
    # plans from one source and observe the same fault schedule —
    # that is what keeps parity byte-exact under injected faults.
    faults: Optional[Any] = None


@dataclass
class Batch:
    items: List[Tuple[Request, int]] = field(default_factory=list)
    preempted: List[Request] = field(default_factory=list)
    # page-level partial preemptions decided while building this batch:
    # (victim, pages shed, tokens shed, "swap" | "recompute").  The
    # victim KEEPS its slot and stays running; the driver must free /
    # snapshot exactly those tail pages.
    partial_preempted: List[Tuple[Request, int, int, str]] = \
        field(default_factory=list)

    @property
    def requests(self) -> List[Request]:
        return [r for r, _ in self.items]

    @property
    def total_tokens(self) -> int:
        return sum(c for _, c in self.items)

    def phase_items(self, phase: Phase):
        return [(r, c) for r, c in self.items if r.phase == phase]

    def __len__(self) -> int:
        return len(self.items)


class Scheduler:
    """Algorithm 1.  Owns the waiting/running queues."""

    def __init__(self, cfg: SchedulerConfig,
                 cost_model: Optional["CostModel"] = None):
        if cfg.preempt_mode not in ("recompute", "swap", "auto"):
            raise ValueError(f"preempt_mode={cfg.preempt_mode!r}")
        self.cfg = cfg
        # prices the swap-vs-recompute decision for preempt_mode="auto";
        # drivers (simulator / engine) inject theirs if unset
        self.cost_model = cost_model
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.histogram = OutputLengthHistogram() if cfg.use_histogram else None
        # stats
        self.num_preemptions = 0
        self.num_partial_preempts = 0
        self.num_swaps = 0
        self.num_batches = 0

    # ------------------------------------------------------------------ #
    def add_request(self, r: Request) -> None:
        self.waiting.append(r)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # --- memory accounting ------------------------------------------- #
    def _round_pages(self, tokens: int) -> int:
        """Round an occupancy UP to whole allocator pages (page_size=1 is
        the identity: token-exact accounting)."""
        pg = self.cfg.page_size
        if pg <= 1 or tokens <= 0:
            return max(tokens, 0)
        return -(-tokens // pg) * pg

    @property
    def M_eff(self) -> int:
        """Capacity in page-rounded tokens: ceil(M/page)*page — exactly
        ``PagedAllocator.tokens_capacity()`` for the allocator the engine
        builds, so feasibility here IS feasibility there."""
        return max(self._round_pages(self.cfg.M), self.cfg.page_size)

    def _reservation(self, r: Request, c: int = 0) -> int:
        """Page-rounded tokens of KV cache this request holds after
        processing c more.  Uses ``resident_kv``: a suspended
        (swapped-out) candidate's host KVs — full snapshot or tail page
        runs — come back on-device at restore, so they must be reserved
        for any batch that processes the request.  Idle (c=0) running
        requests reserve only what is physically on-device
        (``device_kv``): a shed tail costs nothing until restored."""
        occupied = r.device_kv if c == 0 else r.resident_kv + c
        if self.cfg.reserve == "input":
            return self._round_pages(occupied)
        if self.cfg.reserve == "peak":
            return self._round_pages(max(r.peak_kv, occupied))
        if self.cfg.reserve == "context":
            return self._round_pages(self.cfg.S)
        raise ValueError(self.cfg.reserve)

    # ------------------------------------------------------------------ #
    def get_next_batch(self) -> Batch:
        cfg = self.cfg
        batch = Batch()
        batch_tokens = 0
        batch_phase: Optional[Phase] = None
        protected = set()   # rids already in this batch — not preemptible
        preempted_now = set()

        candidates = group_requests(self.waiting, self.running,
                                    priority=cfg.priority, ranking=cfg.ranking)
        order = {r.rid: i for i, r in enumerate(candidates)}
        # incremental memory accounting: base reservation of all running
        # requests + extra reserved by items planned into this batch
        mem = sum(self._reservation(r, 0) for r in self.running)
        admitted_waiting: List[Request] = []

        for cand in candidates:
            if cand.rid in protected or cand.rid in preempted_now or cand.finished:
                continue
            if cfg.max_batch_requests and len(batch) >= cfg.max_batch_requests:
                break
            phase = (Phase.PREFILL if not cand.running else cand.phase)

            # -- slot cap (engine concurrency limit) ----------------------
            if (cfg.max_running and not cand.running
                    and len(self.running) >= cfg.max_running):
                continue

            # -- step 2: CHECKHYBRIDBATCHING ------------------------------
            if not cfg.hybrid and batch_phase is not None and phase != batch_phase:
                continue

            # -- SRF+Hist admission gate (insertion-time deferral) --------
            if (self.histogram is not None and not cand.running
                    and self._hist_defer(cand)):
                continue

            # -- step 3: CANALLOCATE --------------------------------------
            budget = cfg.C - batch_tokens
            if budget <= 0:
                break
            need = cand.remaining_prefill if phase == Phase.PREFILL else 1
            if phase == Phase.DECODE:
                c = 1
            elif cfg.chunked:
                c = min(need, budget)
            else:
                c = need
            if c <= 0 or c > budget:
                continue

            # memory delta of admitting cand with c tokens
            delta = (self._reservation(cand, c)
                     - (self._reservation(cand, 0) if cand.running else 0))

            # -- step 4: preempt lower-priority requests on memory pressure
            admitted = True
            can_preempt_others = cand.running or cfg.admission_can_preempt
            while mem + delta > self.M_eff:
                victims = ([r for r in self.running
                            if r.rid not in protected and r.rid != cand.rid
                            and order.get(r.rid, 1 << 30) > order[cand.rid]]
                           if can_preempt_others else [])
                victim = select_victim(cfg.replacement, victims)
                if (victim is not None and cfg.partial_preempt
                        and cfg.reserve == "input"):
                    # page-level partial preemption: shed only the tail
                    # pages needed to close the deficit; full preemption
                    # only when the whole victim must go.  Only the
                    # "input" reserve prices a request by its CURRENT
                    # occupancy, so only there does shedding k pages
                    # credit k*page_size back — under "peak"/"context"
                    # the reservation is m-independent and a partial
                    # shed frees nothing the accounting can see.
                    shed = self._partial_preempt(
                        victim, deficit=mem + delta - self.M_eff)
                    if shed is not None:
                        npages, n_tokens, mode = shed
                        # a shed victim is no longer admittable this
                        # round (it stays running and CAN be shed again
                        # for a later candidate — runs stack)
                        preempted_now.add(victim.rid)
                        batch.partial_preempted.append(
                            (victim, npages, n_tokens, mode))
                        mem -= npages * cfg.page_size
                        continue
                if victim is None:
                    if cand.running and cfg.replacement != "pf":
                        mem -= self._reservation(cand, 0)
                        self._preempt(cand)       # self-preemption
                        preempted_now.add(cand.rid)
                        batch.preempted.append(cand)
                    admitted = False
                    break
                mem -= self._reservation(victim, 0)
                self._preempt(victim)
                preempted_now.add(victim.rid)
                batch.preempted.append(victim)
            if not admitted:
                continue

            # -- admit ----------------------------------------------------
            if not cand.running:
                cand.running = True
                self.running.append(cand)
                admitted_waiting.append(cand)
            mem += delta
            batch.items.append((cand, c))
            batch_tokens += c
            protected.add(cand.rid)
            if batch_phase is None:
                batch_phase = phase

        if admitted_waiting:
            admitted_ids = {r.rid for r in admitted_waiting}
            self.waiting = [r for r in self.waiting if r.rid not in admitted_ids]
        self.num_batches += 1 if batch.items else 0
        return batch

    # ------------------------------------------------------------------ #
    def _hist_defer(self, cand: Request) -> bool:
        """SRF+Hist: defer admission if the predicted peak demand of
        running + cand would exceed M (avoids future preemptions)."""
        invariant(self.histogram is not None)
        pred_o = self.histogram.predict(cand.input_len)
        cand.predicted_output = pred_o
        # the candidate's demand is capped at S exactly like every running
        # request's below — a long-input candidate can never demand more
        # than one context window; page-rounded like every reservation
        demand = self._round_pages(
            min(cand.input_len + pred_o - 1, self.cfg.S))
        for r in self.running:
            ro = (r.predicted_output if r.predicted_output is not None
                  else self.histogram.predict(r.input_len))
            demand += self._round_pages(
                min(r.input_len + ro - 1, self.cfg.S))
        return demand > self.M_eff

    def _preempt(self, victim: Request) -> None:
        if victim.tail_suspended_m > 0:
            # tail runs already sit in the host store: a recompute-mode
            # full preemption would discard paid-for transfers and leave
            # swap counters/charges describing transfers that never
            # stuck — once any run is host-resident the suspend must
            # stay a swap (the store-full fallback is the driver's)
            mode = "swap"
        else:
            mode = self._mode_for(victim.m)
        victim.preempt(mode=mode)
        self.num_preemptions += 1
        if victim.suspended:
            self.num_swaps += 1
        if victim in self.running:
            self.running.remove(victim)
        self.waiting.append(victim)

    def _partial_preempt(self, victim: Request,
                         deficit: int) -> Optional[Tuple[int, int, str]]:
        """Shed only the tail pages of ``victim`` needed to close
        ``deficit`` tokens of memory pressure.  Returns (pages shed,
        tokens shed, mode) — or None when the whole victim must go
        (caller falls through to full preemption).  The kept prefix is
        whole pages, so the new boundary is page-aligned; the Fig. 8
        crossover prices THIS RUN (its token count, recompute priced
        against the kept context)."""
        pg = self.cfg.page_size
        np_v = -(-victim.m // pg) if victim.m > 0 else 0   # device pages
        k = min(-(-deficit // pg), np_v)
        if k <= 0 or k >= np_v:
            return None            # nothing to shed, or full preemption
        kept = (np_v - k) * pg
        n_tokens = victim.m - kept
        if victim.tail_suspended_m > 0:
            # runs already in the host store sit ABOVE this one: a
            # recompute-mode shed below them would leave a gap in the
            # stored tiling that no restore can bridge — contiguity
            # forces swap once any run is host-resident (auto is the
            # only mode that could mix; pure recompute never stores)
            mode = "swap"
        else:
            mode = self._mode_for(n_tokens, context=kept)
        victim.partial_preempt(n_tokens, mode=mode)
        self.num_preemptions += 1
        self.num_partial_preempts += 1
        if mode == "swap":
            self.num_swaps += 1
        return k, n_tokens, mode

    def _mode_for(self, n_tokens: int, context: int = 0) -> str:
        """Fig. 8 crossover for ``preempt_mode="auto"``: swap ``n_tokens``
        KVs iff the host-link transfer undercuts the cheapest
        recomputation path the cost model offers (K/V-projection rebuild
        or refill prefill — priced against ``context`` kept KVs for a
        tail run).  Without a cost model — or one that does not price
        swaps — auto degrades to recompute."""
        mode = self.cfg.preempt_mode
        if mode != "auto":
            return mode
        cm = self.cost_model
        if cm is None or n_tokens <= 0:
            return "recompute"
        t_swap = cm.swap_time(n_tokens)
        if t_swap <= 0.0:
            return "recompute"
        t_rec = min(cm.kv_projection_time(n_tokens),
                    cm.recompute_time(n_tokens, context=context))
        return "swap" if t_swap < t_rec else "recompute"

    # ------------------------------------------------------------------ #
    def complete(self, r: Request) -> None:
        """Called by the driver after r.advance() finished the request."""
        if r in self.running:
            self.running.remove(r)
        if self.histogram is not None:
            self.histogram.observe(r.input_len, r.output_len)


# --------------------------------------------------------------------- #
# factory for the paper's named schedulers (Tables 2 & 4)
# --------------------------------------------------------------------- #

def make_scheduler(name: str, M: int, *, S: int = 4096,
                   replacement: Optional[str] = None,
                   ranking: str = "arrival",
                   use_histogram: bool = False,
                   preempt_mode: str = "recompute",
                   page_size: int = 1,
                   partial_preempt: bool = False,
                   cache_policy: str = "lru",
                   cache_demotion: bool = False,
                   prefix_lookup: str = "trie",
                   cost_model: Optional["CostModel"] = None) -> Scheduler:
    name = name.lower()
    presets = {
        "vllm": dict(C=S, priority="prefill_first", hybrid=False, chunked=False),
        "vllm_hy": dict(C=S, priority="prefill_first", hybrid=True, chunked=False),
        "sarathi": dict(C=512, priority="decode_first", hybrid=True, chunked=True),
        "sarathi_cs": dict(C=S, priority="decode_first", hybrid=True, chunked=True),
        "sarathi_nocp": dict(C=S, priority="decode_first", hybrid=True, chunked=False),
        "sarathi_nohy": dict(C=S, priority="decode_first", hybrid=False, chunked=False),
        "orca": dict(C=S, priority="decode_first", hybrid=True, chunked=False),
    }
    base = name.removesuffix("_pf")
    if base not in presets:
        raise ValueError(f"unknown scheduler {name!r}")
    kw = dict(presets[base])
    reserve = "input"
    repl = replacement or "nrf"
    if base == "orca":
        reserve = "context"
        repl = replacement or "pf"
    if name.endswith("_pf"):
        reserve, repl = "peak", "pf"   # hypothetical *pf variants
    cfg = SchedulerConfig(M=M, S=S, reserve=reserve, replacement=repl,
                          ranking=ranking, use_histogram=use_histogram,
                          preempt_mode=preempt_mode, page_size=page_size,
                          partial_preempt=partial_preempt,
                          cache_policy=cache_policy,
                          cache_demotion=cache_demotion,
                          prefix_lookup=prefix_lookup, **kw)
    return Scheduler(cfg, cost_model=cost_model)
