"""Paged KV-cache block allocator (control plane).

vLLM-style paging adapted to the TPU data plane: the *allocator* is pure
Python bookkeeping (free list + per-request block tables); the *pools*
are JAX arrays ``(num_pages, page_size, Hkv, D)`` per layer owned by the
serving engine.  The allocator enforces exactly the ``sum(m) <= M``
constraint the scheduler reasons about, at page granularity.

Replacement policy is NOT here — preemption victims are chosen by
``repro.core.policies``; the engine then calls ``free(rid)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class BlockTable:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0  # valid tokens across those pages


class PagedAllocator:
    def __init__(self, num_pages: int, page_size: int):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._tables: Dict[int, BlockTable] = {}

    # ------------------------------------------------------------------ #
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def tokens_capacity(self) -> int:
        return self.num_pages * self.page_size

    def free_tokens(self) -> int:
        return self.free_pages * self.page_size

    def table(self, rid: int) -> BlockTable:
        return self._tables[rid]

    def has(self, rid: int) -> bool:
        return rid in self._tables

    def pages_needed(self, rid: int, new_tokens: int) -> int:
        cur = self._tables.get(rid)
        have = len(cur.pages) * self.page_size - cur.num_tokens if cur else 0
        need_tokens = max(0, new_tokens - have)
        return (need_tokens + self.page_size - 1) // self.page_size

    # ------------------------------------------------------------------ #
    def allocate(self, rid: int, new_tokens: int) -> List[int]:
        """Extend rid's table by new_tokens; returns newly granted pages."""
        need = self.pages_needed(rid, new_tokens)
        if need > len(self._free):
            raise OutOfPagesError(
                f"rid={rid} needs {need} pages, {len(self._free)} free")
        tbl = self._tables.setdefault(rid, BlockTable())
        granted = [self._free.pop() for _ in range(need)]
        tbl.pages.extend(granted)
        tbl.num_tokens += new_tokens
        return granted

    def free(self, rid: int) -> int:
        """Release all pages of rid (preemption/completion). Returns count."""
        tbl = self._tables.pop(rid, None)
        if tbl is None:
            return 0
        self._free.extend(reversed(tbl.pages))
        return len(tbl.pages)

    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        held = [p for t in self._tables.values() for p in t.pages]
        all_pages = held + self._free
        assert len(all_pages) == self.num_pages, "page leak"
        assert len(set(all_pages)) == self.num_pages, "double allocation"
        for rid, t in self._tables.items():
            cap = len(t.pages) * self.page_size
            assert 0 <= t.num_tokens <= cap, (rid, t.num_tokens, cap)
