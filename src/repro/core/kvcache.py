"""Paged KV-cache block allocator (control plane) + radix-trie prefix
registry.

vLLM-style paging, now REAL: under ``EngineConfig.plane="paged"`` the
serving engine stores attention KV in shared per-layer page pools
``(num_pages, page_size, Hkv, D)`` and this allocator's block tables ARE
the physical page map those pools are indexed with (the Pallas paged
decode kernel dereferences them via scalar prefetch).  The allocator
enforces exactly the page-rounded ``sum(m) <= M`` constraint the
scheduler reasons about — control plane and data plane agree
page-for-page by construction.

Beyond plain bookkeeping it owns the two mechanisms contiguous slots
could never express:

* **Refcounted pages + copy-on-write** — a physical page may appear in
  several block tables (shared-prefix reuse) and/or be pinned by the
  prefix registry.  Writers must call :meth:`ensure_private` first; it
  transparently remaps a shared page to a fresh private one (the caller
  copies the pool contents).
* **Partial free** — :meth:`free_tail` releases only a request's tail
  pages (page-level partial preemption, the §8 replacement idea pushed
  to sub-request granularity).

**The registry is a token-level radix trie** (:class:`RadixPrefixRegistry`,
SGLang/Mooncake-style).  Each trie node owns a page-aligned RUN of
pages — per page a chained content digest (:func:`chain_keys`), the
page's token ids, and its chain depth ``n_kvs``.  ``lookup_run`` walks
root-to-leaf in O(L), re-verifying token ids at every node, and returns
the LONGEST matching prefix: a request sharing only a system prompt or
the first turns of a conversation reuses exactly those pages (partial
hit), where the old exact-chain registry would have reused nothing.  A
digest collision is verified away and degrades to a miss — never to
another prompt's KV (the token-identical contract).  When a query
diverges inside a node's run, the node is SPLIT at the (page-aligned)
divergence point, so hot front runs and cold tails get separate
replacement entries; when an eviction leaves a parent with a single
child, the pair is MERGED back into one run (path compression).

Every registered page holds a +1 pin so completed requests leave their
prompt pages behind as a cached prefix tree.  Pinned-only pages are
RECLAIMABLE: when the free list runs short, :meth:`PagedAllocator._take`
walks trie NODES in the eviction order of a PLUGGABLE
``policies.ReplacementPolicy`` (``lru``, ``break_even`` — the §6
five-minute rule, Eq. 5 break-even residency vs observed idle scored
with the node's END depth ``n_kvs``, so deep cold tails rank first — or
``belady-oracle`` for offline ablation).  The order is LEAF-FIRST and
pages evict from each node's TAIL: an evicted interior node can never
strand live descendants, and device residency stays prefix-closed along
every chain.  Nodes with a still-table-mapped tail page are SKIPPED
(evicting them frees nothing) and counted in
``stats["reclaim_skipped"]``; cached prefixes therefore never reduce the
capacity the scheduler may promise — ``OutOfPagesError`` stays
unreachable on admitted schedules.

Per-node refcounts are DERIVED, not stored: a node's refcount is the
number of live block-table mappings over its pages, read through the
allocator's page refcounts (``node_refs``).  One source of truth means
splits, merges, and transaction rollbacks can never desynchronize
lease bookkeeping from physical reality.

Eviction feeds an optional ``on_evict`` hook BEFORE each page returns to
the free list: drivers use it to DEMOTE the evicted KV to a host tier
(``serving.swap_store.PrefixPageEntry``).  A node's tail run demotes as
consecutive page-granular entries, each CRC-sealed; a later trie miss
that hits the host tier PROMOTES pages back through
:meth:`promote_prefix` (one fresh page, re-pinned, re-inserted at its
trie position) — :func:`attach_prefix_run` implements that two-tier
lookup for both the serving engine (real pool copies) and the
simulator's virtual-time shadow, so every KV access resolves along the
Fig. 8 spectrum: GPU-resident < host swap-in < recompute.

Replacement policy for REQUESTS is still not here — preemption victims
are chosen by ``repro.core.policies``; the engine then calls
``free(rid)`` / ``free_tail(rid, k)``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterator, List, Optional,
                    Sequence, Set, Tuple)

from repro.core.invariants import invariant
from repro.core.policies import LRUPolicy, ReplacementPolicy
from repro.core import stat_keys as SK


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class BlockTable:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0  # valid tokens across those pages


def chain_keys(tokens: Sequence[int], page_size: int) -> List[int]:
    """Chained content digests for every FULL page of ``tokens``.

    Key ``i`` is a blake2b digest over (key ``i-1``, the token ids of
    page ``i``), so key ``i`` identifies the whole prefix through page
    ``i`` — and, unlike the builtin ``hash`` chain it replaced, the
    value is STABLE across processes and ``PYTHONHASHSEED`` settings
    (a prerequisite for ever persisting the prefix store, and for
    reproducible fault-plan draws keyed on these values)."""
    keys: List[int] = []
    prev = b""
    for i in range(len(tokens) // page_size):
        page = tokens[i * page_size:(i + 1) * page_size]
        h = hashlib.blake2b(prev, digest_size=8)
        h.update(b",".join(b"%d" % int(t) for t in page))
        prev = h.digest()
        keys.append(int.from_bytes(prev, "big"))
    return keys


class _TrieNode:
    """One radix-trie node: a page-aligned run of registered pages.

    Parallel lists (one slot per owned page): ``keys`` (chained content
    digests), ``pages`` (physical page ids), ``tokens`` (that page's
    token ids, for collision re-verification), ``nkvs`` (chain depth in
    tokens at that page — the Eq. 5 ``n_kvs`` input).  ``children`` maps
    a child's FIRST chain key to the child node; the node's own id is
    its first chain key (stable under tail shrink)."""

    __slots__ = ("parent", "children", "keys", "pages", "tokens", "nkvs")

    def __init__(self, parent: Optional["_TrieNode"]) -> None:
        self.parent = parent
        self.children: Dict[int, "_TrieNode"] = {}
        self.keys: List[int] = []
        self.pages: List[int] = []
        self.tokens: List[Tuple[int, ...]] = []
        self.nkvs: List[int] = []

    @property
    def node_id(self) -> int:
        return self.keys[0]

    def __repr__(self) -> str:  # debugging aid only
        return (f"_TrieNode(pages={self.pages}, "
                f"children={len(self.children)})")


class RadixPrefixRegistry:
    """Radix trie mapping chained page digests -> physical pages, with a
    pluggable node-level replacement policy.

    Structure: the root owns no pages; every other node owns a non-empty
    page run.  A node's policy entry is keyed by its ``node_id`` (first
    chain key) and scored with its END-depth ``n_kvs`` — the §6
    break-even policy therefore prices a node by the regeneration cost
    of its deepest page, which falls with depth, so long cold tails
    evict first.  Per-node refcounts are derived from the owning
    allocator's page refcounts via the ``live`` callable
    (:meth:`node_refs`); the registry itself never stores a lease.

    Key operations:

    * :meth:`lookup_run` — longest-prefix match in O(L) with token-id
      re-verification at every node; splits a partially-matched node at
      the page-aligned divergence point (``num_splits``).
    * :meth:`insert` — place one page after ``prev_key`` (its chain
      predecessor): extends the predecessor's leaf run in place, or
      starts a new child node (splitting the predecessor's node when
      the insertion point is mid-run).
    * :meth:`evict_tail` — pop a LEAF node's last page; an emptied node
      is unlinked, and a parent left with a single child is merged back
      into one run (``num_merges``).
    * :meth:`snapshot_state` / :meth:`restore_state` — structural
      deep-copy for step-transaction rollback (``serving.txn``); node
      refcounts need no snapshot because they are derived.

    Digest collisions: ``get``/``lookup_run`` compare the stored token
    ids and treat any mismatch as a MISS — a collision must never map
    another prompt's KV pages into a request.
    """

    def __init__(self, policy: Optional[ReplacementPolicy] = None,
                 live: Optional[Callable[[int], int]] = None) -> None:
        self.policy = policy if policy is not None else LRUPolicy()
        # page -> total refcount in the owning allocator (pin + tables);
        # standalone registries default to pin-only (no table mappings)
        self._live = live if live is not None else (lambda page: 1)
        self.root = _TrieNode(None)
        self._index: Dict[int, _TrieNode] = {}   # every key -> owning node
        self._count = 0                          # registered pages
        self.num_splits = 0
        self.num_merges = 0

    # --- size / membership --------------------------------------------- #
    def __len__(self) -> int:
        """Number of registered PAGES (not nodes)."""
        return self._count

    def __contains__(self, key: int) -> bool:
        return key in self._index

    @property
    def num_nodes(self) -> int:
        return len(set(map(id, self._index.values())))

    def nodes(self) -> Iterator[_TrieNode]:
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def node(self, node_id: int) -> Optional[_TrieNode]:
        """The node whose FIRST page is keyed ``node_id`` (None if the
        key is unregistered or mid-run — e.g. after a merge)."""
        n = self._index.get(node_id)
        return n if n is not None and n.keys[0] == node_id else None

    @property
    def pages(self) -> List[int]:
        return [p for n in self.nodes() for p in n.pages]

    def node_refs(self, node: _TrieNode) -> int:
        """Derived per-node refcount: live block-table mappings over the
        node's pages (each registered page carries exactly one pin, so
        anything beyond it is a table mapping)."""
        return sum(max(self._live(p) - 1, 0) for p in node.pages)

    # --- point lookups -------------------------------------------------- #
    def _locate(self, key: int) -> Tuple[_TrieNode, int]:
        node = self._index[key]
        return node, node.keys.index(key)

    def get(self, key: int, tokens: Optional[Sequence[int]] = None,
            now: float = 0.0) -> Optional[int]:
        node = self._index.get(key)
        if node is None:
            return None
        off = node.keys.index(key)
        if tokens is not None and tuple(tokens) != node.tokens[off]:
            return None                 # digest collision: NOT a match
        self.policy.record_hit(node.node_id, now)
        return node.pages[off]

    def entry(self, key: int) -> Tuple[int, Tuple[int, ...], int]:
        """(page, tokens, n_kvs) of a registered key."""
        node, off = self._locate(key)
        return node.pages[off], node.tokens[off], node.nkvs[off]

    # --- trie mutation -------------------------------------------------- #
    def _split(self, node: _TrieNode, keep: int, now: float) -> _TrieNode:
        """Split ``node`` after its first ``keep`` pages; the tail run
        becomes the single child of the (shrunk) front.  Returns the
        tail node.  Page-aligned by construction — runs only ever hold
        whole pages.  The tail's policy entry starts at ``now`` (splits
        happen on an active lookup/insert, so the path is warm)."""
        invariant(0 < keep < len(node.pages), (keep, len(node.pages)))
        tail = _TrieNode(node)
        tail.keys = node.keys[keep:]
        tail.pages = node.pages[keep:]
        tail.tokens = node.tokens[keep:]
        tail.nkvs = node.nkvs[keep:]
        del node.keys[keep:], node.pages[keep:]
        del node.tokens[keep:], node.nkvs[keep:]
        tail.children = node.children
        for child in tail.children.values():
            child.parent = tail
        node.children = {tail.node_id: tail}
        for k in tail.keys:
            self._index[k] = tail
        self.policy.record_resize(node.node_id, node.nkvs[-1])
        self.policy.record_insert(tail.node_id, tail.nkvs[-1], now)
        self.num_splits += 1
        return tail

    def _merge_single_child(self, parent: _TrieNode) -> None:
        """Path compression: absorb a lone child's run into ``parent``
        (triggered when an eviction unlinks a sibling).  The merged node
        keeps the parent's policy recency — the colder tail still evicts
        first, page by page, so the approximation never strands a hot
        front behind a cold merge partner."""
        if parent is self.root or len(parent.children) != 1:
            return
        (child,) = parent.children.values()
        self.policy.record_remove(child.node_id)
        parent.keys.extend(child.keys)
        parent.pages.extend(child.pages)
        parent.tokens.extend(child.tokens)
        parent.nkvs.extend(child.nkvs)
        parent.children = child.children
        for grand in parent.children.values():
            grand.parent = parent
        for k in child.keys:
            self._index[k] = parent
        self.policy.record_resize(parent.node_id, parent.nkvs[-1])
        self.num_merges += 1

    def insert(self, key: int, page: int, tokens: Sequence[int] = (),
               n_kvs: int = 0, now: float = 0.0,
               prev_key: Optional[int] = None) -> None:
        """Register one page under chain key ``key``, positioned right
        after ``prev_key`` in the trie (``None`` = first page of a
        prompt, i.e. a child of the root).  Extends the predecessor's
        run in place when it is a leaf tail, else starts a new child
        node (splitting the predecessor's node first when ``prev_key``
        sits mid-run)."""
        if key in self._index:
            # a silent re-register would leak the old page's +1 pin (and
            # under ``python -O`` a bare assert would not even fire)
            raise ValueError(
                f"prefix key {key} already registered "
                f"(page {self.entry(key)[0]})")
        if prev_key is None:
            parent = self.root
        else:
            if prev_key not in self._index:
                raise ValueError(f"prev_key {prev_key} is not registered")
            parent, off = self._locate(prev_key)
            if off != len(parent.keys) - 1:
                self._split(parent, off + 1, now)   # prev becomes the tail
        if parent is not self.root and not parent.children:
            # leaf tail: grow the run in place (per-grant incremental
            # registration lands here chunk after chunk)
            parent.keys.append(key)
            parent.pages.append(page)
            parent.tokens.append(tuple(tokens))
            parent.nkvs.append(int(n_kvs))
            self._index[key] = parent
            self.policy.record_resize(parent.node_id, int(n_kvs))
            self.policy.record_hit(parent.node_id, now)
        else:
            node = _TrieNode(parent)
            node.keys = [key]
            node.pages = [page]
            node.tokens = [tuple(tokens)]
            node.nkvs = [int(n_kvs)]
            parent.children[key] = node
            self._index[key] = node
            self.policy.record_insert(key, int(n_kvs), now)
        self._count += 1

    def lookup_run(self, keys: Sequence[int],
                   page_tokens: Optional[Sequence[Sequence[int]]] = None,
                   now: float = 0.0) -> List[int]:
        """Longest-prefix match: physical pages for the longest chain of
        ``keys`` resolvable from the root, O(L) with token re-
        verification at every node.  A key miss, or a token mismatch on
        a digest collision, ends the run (collision-is-a-miss).  A
        partial node match splits the node at the divergence point so
        the matched region is whole nodes — the hot front and the cold
        tail then age independently under the replacement policy."""
        pages: List[int] = []
        node = self.root
        i = 0
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                break
            m = 0
            while m < len(child.pages) and i + m < len(keys):
                if child.keys[m] != keys[i + m]:
                    break
                if page_tokens is not None and \
                        tuple(page_tokens[i + m]) != child.tokens[m]:
                    break               # collision: verified away, a miss
                m += 1
            if m == 0:
                break
            full = m == len(child.pages)
            if not full:
                self._split(child, m, now)   # child keeps the matched front
            pages.extend(child.pages)
            self.policy.record_hit(child.node_id, now)
            i += m
            if not full:
                break                        # diverged inside the run
            node = child
        return pages

    def evict_tail(self, node: _TrieNode
                   ) -> Tuple[int, int, Tuple[int, ...], int]:
        """Pop the LAST page of a LEAF node (deepest first keeps device
        residency prefix-closed along every chain).  Returns ``(key,
        page, tokens, n_kvs)`` for the caller's demotion hook.  An
        emptied node is unlinked from its parent; a parent left with a
        single child merges back into one run."""
        invariant(not node.children,
                  "evict_tail on an interior node would strand children")
        invariant(node.pages, "evict_tail on an empty node")
        node_id = node.node_id
        key = node.keys.pop()
        page = node.pages.pop()
        tokens = node.tokens.pop()
        n_kvs = node.nkvs.pop()
        del self._index[key]
        self._count -= 1
        if node.pages:
            self.policy.record_resize(node_id, node.nkvs[-1])
        else:
            self.policy.record_remove(node_id)
            parent = node.parent
            del parent.children[node_id]
            node.parent = None
            self._merge_single_child(parent)
        return key, page, tokens, n_kvs

    def eviction_order(self, now: float = 0.0) -> List[int]:
        """Node ids, most-evictable first per the installed policy,
        LEAF-FIRST: interior nodes sort after every current leaf, so an
        eviction sweep never reaches a node that still has descendants
        until those descendants are gone."""
        def is_leaf(node_id: int) -> bool:
            n = self.node(node_id)
            return n is not None and not n.children
        return self.policy.eviction_order(now, leaf_of=is_leaf)

    # --- transactions ---------------------------------------------------- #
    def snapshot_state(self) -> Any:
        """Structural deep-copy (nodes, runs, counters) for step-txn
        rollback.  The policy is snapshotted separately by the txn
        (``txn.copy_state``); derived node refcounts need nothing."""
        flat: List[Tuple[int, List[int], List[int],
                         List[Tuple[int, ...]], List[int]]] = []

        def walk(n: _TrieNode, parent_idx: int) -> None:
            idx = len(flat)
            flat.append((parent_idx, list(n.keys), list(n.pages),
                         list(n.tokens), list(n.nkvs)))
            for child in n.children.values():
                walk(child, idx)

        walk(self.root, -1)
        return flat, self._count, self.num_splits, self.num_merges

    def restore_state(self, state: Any) -> None:
        flat, count, splits, merges = state
        nodes: List[_TrieNode] = []
        for parent_idx, keys, pages, tokens, nkvs in flat:
            parent = nodes[parent_idx] if parent_idx >= 0 else None
            n = _TrieNode(parent)
            n.keys, n.pages = list(keys), list(pages)
            n.tokens, n.nkvs = list(tokens), list(nkvs)
            if parent is not None:
                parent.children[n.node_id] = n
            nodes.append(n)
        self.root = nodes[0]
        self._index = {k: n for n in nodes for k in n.keys}
        self._count = count
        self.num_splits, self.num_merges = splits, merges

    # --- invariants ------------------------------------------------------ #
    def check_invariants(self) -> None:
        invariant(not self.root.keys and self.root.parent is None,
                  "root must own no pages")
        seen_pages: Set[int] = set()
        npages = 0
        node_ids: Set[int] = set()
        for n in self.nodes():
            invariant(n.keys, "non-root trie node with empty run")
            invariant(len(n.keys) == len(n.pages) == len(n.tokens)
                      == len(n.nkvs), "ragged node run")
            node_ids.add(n.node_id)
            npages += len(n.pages)
            for k in n.keys:
                invariant(self._index.get(k) is n,
                          f"index out of sync for key {k}")
            for p in n.pages:
                invariant(p not in seen_pages, f"page {p} in two nodes")
                seen_pages.add(p)
            for ck, child in n.children.items():
                invariant(child.parent is n and child.keys
                          and child.keys[0] == ck,
                          "child linkage broken")
        for ck, child in self.root.children.items():
            invariant(child.parent is self.root and child.keys
                      and child.keys[0] == ck, "root child linkage broken")
        invariant(npages == self._count == len(self._index),
                  (npages, self._count, len(self._index)))
        invariant(node_ids == set(self.policy._seq),
                  "policy metadata out of sync with trie nodes")

    # legacy name: the digest chain is shared with schedulers/benchmarks
    chain_keys = staticmethod(chain_keys)


# The chained-hash ``PrefixCache`` grew into the radix trie; the old
# name stays importable for callers that only need ``chain_keys`` or
# the point API (``get``/``insert``/``entry``).
PrefixCache = RadixPrefixRegistry


class PagedAllocator:
    def __init__(self, num_pages: int, page_size: int, *,
                 policy: Optional[ReplacementPolicy] = None,
                 on_evict: Optional[
                     Callable[[int, int, Tuple[int, ...], int], None]]
                 = None):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(f"num_pages={num_pages}, page_size={page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._tables: Dict[int, BlockTable] = {}
        self._refs: Dict[int, int] = {}     # page -> refcount (tables + pin)
        self._pinned: Set[int] = set()      # pages pinned by the registry
        self.prefix_cache = RadixPrefixRegistry(
            policy, live=lambda page: self._refs.get(page, 0))
        # demotion hook: called as (key, page, page tokens, chain depth)
        # BEFORE an evicted page returns to the free list, while its
        # pool contents are still intact — drivers snapshot it to the
        # host tier here
        self.on_evict = on_evict
        # virtual-time clock the replacement policy scores against;
        # drivers (engine / simulator shadow) keep it current
        self.now = 0.0
        # bumped on every block-table mutation — lets the engine cache
        # its device-side block-table upload across decode steps and
        # invalidate it without tracking call sites by hand
        self.version = 0
        # rids whose PAGE LIST changed since the last consume_dirty():
        # the delta companion to ``version`` — a version bump tells the
        # engine its device tables are stale, the dirty set tells it
        # WHICH host rows to rewrite before the one refresh upload
        self.dirty: Set[int] = set()
        # fault-injection hook: called as fault_hook(need) before pages
        # are taken — a seeded FaultPlan raises a transient FaultError
        # here to model device allocation failures (serving.faults)
        self.fault_hook: Optional[Callable[[int], None]] = None
        self.stats: Dict[str, int] = {
            SK.PREFIX_HITS: 0, SK.PREFIX_SHARED_TOKENS: 0,
            SK.COW_COPIES: 0, SK.RECLAIMED: 0, SK.RECLAIM_SKIPPED: 0}

    # ------------------------------------------------------------------ #
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Physical pages holding live data (tables and/or registry)."""
        return self.num_pages - len(self._free)

    @property
    def table_pages(self) -> int:
        """Pages referenced by at least one block table (excludes pages
        alive only as registry-cached prefixes)."""
        return len({p for t in self._tables.values() for p in t.pages})

    def tokens_capacity(self) -> int:
        return self.num_pages * self.page_size

    def free_tokens(self) -> int:
        return self.free_pages * self.page_size

    def table(self, rid: int) -> BlockTable:
        return self._tables[rid]

    def has(self, rid: int) -> bool:
        return rid in self._tables

    def consume_dirty(self) -> Set[int]:
        """Return-and-clear the rids whose page lists changed since the
        last call — the engine rewrites exactly those host block-table
        rows before its one refresh upload (a freed rid may appear; the
        caller skips rids with no slot)."""
        dirty, self.dirty = self.dirty, set()
        return dirty

    def pages_needed(self, rid: int, new_tokens: int) -> int:
        if new_tokens <= 0:
            return 0
        cur = self._tables.get(rid)
        have = len(cur.pages) * self.page_size - cur.num_tokens if cur else 0
        need_tokens = max(0, new_tokens - have)
        return (need_tokens + self.page_size - 1) // self.page_size

    # --- refcount plumbing --------------------------------------------- #
    def _decref(self, page: int) -> None:
        self._refs[page] -= 1
        invariant(self._refs[page] >= 0, page)
        if self._refs[page] == 0:
            del self._refs[page]
            self._free.append(page)

    def _take(self, need: int) -> List[int]:
        """Pop ``need`` free pages, reclaiming trie nodes in the
        replacement policy's eviction order when the free list runs
        short — cached prefixes never block a request the scheduler
        admitted.

        The sweep is LEAF-FIRST (``RadixPrefixRegistry.eviction_order``)
        and evicts each node's pages TAIL-FIRST, so an interior node is
        never dismantled while descendants still chain through it and
        device residency stays prefix-closed.  A node whose tail page a
        live block table still maps is SKIPPED where it stands — the pin
        drop would free nothing — and counted in
        ``stats["reclaim_skipped"]``.  The outer loop re-walks the order
        while it makes progress: evicting a whole leaf exposes its
        parent as the next candidate.  Each genuinely evicted page is
        offered to ``on_evict`` (host demotion) before it returns to the
        free list, and only those count as ``reclaimed``."""
        if self.fault_hook is not None and need > 0:
            self.fault_hook(need)
        reg = self.prefix_cache
        if len(self._free) < need and len(reg):
            progress = True
            while len(self._free) < need and progress:
                progress = False
                for node_id in reg.eviction_order(self.now):
                    if len(self._free) >= need:
                        break
                    node = reg.node(node_id)
                    if node is None or node.children:
                        continue       # merged away mid-sweep / interior
                    blocked = False
                    while node.pages and len(self._free) < need:
                        page = node.pages[-1]
                        if self._refs[page] > 1:  # pin + live table map(s)
                            blocked = True
                            break
                        key, page, tokens, n_kvs = reg.evict_tail(node)
                        self._pinned.discard(page)
                        if self.on_evict is not None:
                            self.on_evict(key, page, tokens, n_kvs)
                        self._decref(page)        # pin was the only ref
                        self.stats[SK.RECLAIMED] += 1
                        progress = True
                    if blocked:
                        self.stats[SK.RECLAIM_SKIPPED] += 1
        if need > len(self._free):
            raise OutOfPagesError(
                f"need {need} pages, {len(self._free)} free "
                f"({len(self.prefix_cache)} cached prefix pages left, "
                f"none evictable)")
        granted = [self._free.pop() for _ in range(need)]
        for p in granted:
            invariant(p not in self._refs, p)
            self._refs[p] = 1
        return granted

    # ------------------------------------------------------------------ #
    def allocate(self, rid: int, new_tokens: int) -> List[int]:
        """Extend rid's table by new_tokens; returns newly granted pages.
        A zero-token grant is a NO-OP (no phantom empty table)."""
        if new_tokens <= 0:
            return []
        need = self.pages_needed(rid, new_tokens)
        if need:
            # version tracks the PAGE LISTS only: an in-page append
            # (decode filling its current page) must not invalidate the
            # engine's cached device block tables
            self.version += 1
            self.dirty.add(rid)
        granted = self._take(need)
        tbl = self._tables.setdefault(rid, BlockTable())
        tbl.pages.extend(granted)
        tbl.num_tokens += new_tokens
        return granted

    def share(self, rid: int, pages: Sequence[int], num_tokens: int) -> None:
        """Map existing (registry-held) pages as the PREFIX of rid's
        table — shared-prefix reuse.  Only full pages are shareable and
        the table must be empty (prefix attach happens at first claim)."""
        invariant(rid not in self._tables, rid)
        invariant(num_tokens == len(pages) * self.page_size,
                  (num_tokens, len(pages), self.page_size))
        for p in pages:
            invariant(self._refs.get(p, 0) > 0, f"page {p} is not live")
            self._refs[p] += 1
        self.version += 1
        self.dirty.add(rid)
        self._tables[rid] = BlockTable(list(pages), num_tokens)
        self.stats[SK.PREFIX_HITS] += 1
        self.stats[SK.PREFIX_SHARED_TOKENS] += num_tokens

    def extend_shared(self, rid: int, page: int, num_tokens: int) -> None:
        """Append ONE live (registry-held) page to the tail of rid's
        table — the host-promotion path of a prefix attach extends the
        run page by page.  The table must be whole full pages so far."""
        tbl = self._tables[rid]
        invariant(num_tokens == self.page_size, num_tokens)
        invariant(tbl.num_tokens == len(tbl.pages) * self.page_size,
                  (rid, tbl.num_tokens, len(tbl.pages)))
        invariant(self._refs.get(page, 0) > 0, f"page {page} is not live")
        self._refs[page] += 1
        self.version += 1
        self.dirty.add(rid)
        tbl.pages.append(page)
        tbl.num_tokens += num_tokens
        self.stats[SK.PREFIX_SHARED_TOKENS] += num_tokens

    def ensure_private(self, rid: int,
                       page_index: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write guard: before WRITING into table page
        ``page_index``, remap it to a fresh private page if it is shared
        (refcount > 1) or registry-pinned.  Returns ``(old, new)`` when a
        copy is needed (the caller must copy pool contents old -> new),
        else None."""
        tbl = self._tables[rid]
        page = tbl.pages[page_index]
        if self._refs[page] == 1 and page not in self._pinned:
            return None
        self.version += 1
        self.dirty.add(rid)
        new = self._take(1)[0]
        tbl.pages[page_index] = new
        self._decref(page)
        self.stats[SK.COW_COPIES] += 1
        return page, new

    def free(self, rid: int) -> int:
        """Release all pages of rid (preemption/completion). Returns count.
        Registry-pinned pages stay alive as cached prefixes."""
        tbl = self._tables.pop(rid, None)
        if tbl is None:
            return 0
        self.version += 1
        self.dirty.add(rid)
        for p in reversed(tbl.pages):
            self._decref(p)
        return len(tbl.pages)

    def free_tail(self, rid: int, npages: int) -> int:
        """Release only the LAST ``npages`` pages of rid's table
        (page-level partial preemption).  Returns the tokens removed;
        the kept pages are full, so the new boundary is page-aligned."""
        tbl = self._tables[rid]
        invariant(0 < npages <= len(tbl.pages),
                  (rid, npages, len(tbl.pages)))
        self.version += 1
        self.dirty.add(rid)
        removed = tbl.pages[-npages:]
        del tbl.pages[-npages:]
        kept_cap = len(tbl.pages) * self.page_size
        tokens_removed = tbl.num_tokens - min(tbl.num_tokens, kept_cap)
        tbl.num_tokens = min(tbl.num_tokens, kept_cap)
        for p in reversed(removed):
            self._decref(p)
        if not tbl.pages:
            del self._tables[rid]
        return tokens_removed

    # --- radix-trie prefix registry ------------------------------------ #
    def lookup_prefix(self, keys: Sequence[int],
                      page_tokens: Optional[Sequence[Sequence[int]]] = None
                      ) -> List[int]:
        """Physical pages for the LONGEST matching prefix of ``keys``
        (trie walk from the root; a key miss — or a token-verification
        failure on a digest collision — ends the run).  ``page_tokens[i]``
        are the token ids of page ``i``, compared against each node's
        stored tokens when given."""
        return self.prefix_cache.lookup_run(keys, page_tokens,
                                            now=self.now)

    def register_prefix(self, rid: int, keys: Sequence[int],
                        page_tokens: Sequence[Sequence[int]] = ()
                        ) -> int:
        """Publish rid's first ``len(keys)`` table pages into the trie
        under their chained content keys (pin +1 each), storing each
        page's token ids for collision verification and its chain depth
        ``n_kvs`` for the break-even policy.  Keys already registered —
        including rid's own attached shared prefix — are skipped and
        anchor the chain, so successive per-grant calls EXTEND the same
        node run chunk after chunk.  Returns the number of newly
        registered pages."""
        tbl = self._tables[rid]
        n = min(len(keys), len(tbl.pages))
        registered = 0
        prev: Optional[int] = None
        for i in range(n):
            key, page = keys[i], tbl.pages[i]
            if key in self.prefix_cache:
                prev = key
                continue
            if page in self._pinned:
                # the page is registered under a DIFFERENT key: the
                # chain position of everything deeper is unknowable
                break
            toks = page_tokens[i] if i < len(page_tokens) else ()
            self.prefix_cache.insert(key, page, toks,
                                     n_kvs=(i + 1) * self.page_size,
                                     now=self.now, prev_key=prev)
            self._pinned.add(page)
            self._refs[page] += 1
            registered += 1
            prev = key
        return registered

    def promote_prefix(self, key: int, tokens: Sequence[int],
                       n_kvs: int, prev_key: Optional[int] = None) -> int:
        """Re-admit a host-demoted prefix page: take one page (this may
        itself reclaim/demote colder nodes) and insert it into the trie
        right after ``prev_key`` — its chain predecessor, which the
        attach loop guarantees is resident and table-mapped, so the
        take's own reclaim can never evict the run being rebuilt.  The
        caller writes the host snapshot into the returned page and
        charges the swap-in."""
        page = self._take(1)[0]
        # _take set refs[page] = 1 — here that single ref IS the pin
        self.prefix_cache.insert(key, page, tokens, n_kvs=n_kvs,
                                 now=self.now, prev_key=prev_key)
        self._pinned.add(page)
        return page

    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        held = sorted(self._refs)
        all_pages = held + self._free
        invariant(len(all_pages) == self.num_pages, "page leak")
        invariant(len(set(all_pages)) == self.num_pages,
                  "double allocation")
        # refcount == table memberships + registry pin, everywhere
        counts: Dict[int, int] = {}
        for rid, t in self._tables.items():
            invariant(t.pages, f"rid {rid}: empty block table")
            cap = len(t.pages) * self.page_size
            invariant(0 < t.num_tokens <= cap, (rid, t.num_tokens, cap))
            for p in t.pages:
                counts[p] = counts.get(p, 0) + 1
        for p in self._pinned:
            counts[p] = counts.get(p, 0) + 1
        invariant(counts == self._refs, (counts, self._refs))
        invariant(self._pinned == set(self.prefix_cache.pages),
                  (self._pinned, self.prefix_cache.pages))
        self.prefix_cache.check_invariants()


# --------------------------------------------------------------------- #
# two-tier prefix attach (device trie, then host demotion tier)
# --------------------------------------------------------------------- #


def attach_prefix_run(alloc: PagedAllocator, rid: int,
                      keys: Sequence[int],
                      page_tokens: Sequence[Sequence[int]],
                      host_tier: Any = None,
                      restore: Optional[Callable[[int, Any], None]] = None,
                      verify: Optional[Callable[[Any], bool]] = None,
                      exact: bool = False) -> Tuple[int, int]:
    """Map the longest matching run of cached prefix pages starting at
    page 0 into rid's (empty) block table: first a DEVICE trie walk
    (``lookup_prefix`` — partial hits included), then — when
    ``host_tier`` is given — a page-by-page extension against
    host-demoted ``PrefixPageEntry`` snapshots, which are PROMOTED back:
    one fresh page taken (possibly demoting colder nodes), re-inserted
    into the trie after its chain predecessor, and filled via
    ``restore(page, entry.kv)``.  Device pages are table-mapped (and so
    refcount-protected) before any promotion runs, and each promoted
    page is mapped before the next key is resolved — a promotion's own
    reclaim can never evict pages of the run being built.  The two
    phases are equivalent to a per-key interleave because eviction is
    tail-first along every chain: device residency is prefix-closed, so
    no deeper key can be device-resident once one key has missed.

    ``verify(entry)`` — when given — gates every host promotion: a
    False verdict (CRC mismatch, injected promote fault) DROPS the
    demoted entry and ends the run there, so a rotten host snapshot
    degrades to a trie miss (recompute) instead of restoring wrong KV.
    The engine passes ``swap_store.verify_entry`` composed with its
    fault plan; the simulator mirrors the same plan draws.

    ``exact=True`` is the pre-trie ablation mode
    (``prefix_lookup="exact"``): the attach is all-or-nothing — unless
    EVERY queried key resolves on the device, nothing attaches and no
    host promotion is attempted.  Benchmarks use it to isolate what
    partial-prefix matching buys.

    Returns ``(attached_tokens, promoted_tokens)``; the caller charges
    ``swap_time(promoted_tokens)`` — the Fig. 8 host-link price of the
    promotions.  Shared by the serving engine (real pool copies) and the
    simulator's virtual-time shadow (``restore=None``).
    """
    pg = alloc.page_size
    pages = alloc.lookup_prefix(keys, page_tokens)
    if exact and len(pages) < len(keys):
        return 0, 0
    attached = promoted = 0
    for page in pages:
        if attached == 0:
            alloc.share(rid, [page], pg)  # repro: allow-unpriced-mutation(sharing maps an existing device page - no bytes move; attached tokens are returned for the caller's prefix_stats)
        else:
            alloc.extend_shared(rid, page, pg)  # repro: allow-unpriced-mutation(same zero-copy mapping as the share above)
        attached += pg
    i = len(pages)
    while host_tier is not None and not exact and i < len(keys):
        key, toks = keys[i], page_tokens[i]
        if key in alloc.prefix_cache:
            # the trie walk stopped BEFORE this key, so a registered
            # entry here holds DIFFERENT tokens (a digest collision) —
            # promoting the host copy would try to re-insert the key; a
            # collision must degrade to a miss, never an error (and
            # never another prompt's KV)
            break
        entry = host_tier.peek_prefix(key, toks)
        if entry is None:
            break
        if verify is not None and not verify(entry):
            # integrity failure: drop the rotten snapshot and stop the
            # run — the pages it would have covered recompute
            host_tier.discard_prefix(key)  # repro: allow-unpriced-mutation(dropping a corrupt entry moves no bytes; the caller counts it in its integrity stats)
            break
        try:
            # repro: allow-unpriced-mutation(priced by the caller - promoted tokens are returned and charged swap_time into the batch, parity-tested engine vs simulator)
            page = alloc.promote_prefix(key, entry.tokens, entry.n_kvs,
                                        prev_key=keys[i - 1] if i else None)
        except OutOfPagesError:
            break                   # nothing evictable: stop the run
        host_tier.pop_prefix(key)  # repro: allow-unpriced-mutation(the promotion above carries the charge; the pop only hands the entry over)
        if restore is not None:
            restore(page, entry.kv)
        if attached == 0:
            alloc.share(rid, [page], pg)  # repro: allow-unpriced-mutation(sharing maps an existing device page - no bytes move; attached tokens are returned for the caller's prefix_stats)
        else:
            alloc.extend_shared(rid, page, pg)  # repro: allow-unpriced-mutation(same zero-copy mapping as the share above)
        attached += pg
        promoted += pg
        i += 1
    return attached, promoted
