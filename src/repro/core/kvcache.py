"""Paged KV-cache block allocator (control plane) + shared-prefix page
registry.

vLLM-style paging, now REAL: under ``EngineConfig.plane="paged"`` the
serving engine stores attention KV in shared per-layer page pools
``(num_pages, page_size, Hkv, D)`` and this allocator's block tables ARE
the physical page map those pools are indexed with (the Pallas paged
decode kernel dereferences them via scalar prefetch).  The allocator
enforces exactly the page-rounded ``sum(m) <= M`` constraint the
scheduler reasons about — control plane and data plane agree
page-for-page by construction.

Beyond plain bookkeeping it owns the two mechanisms contiguous slots
could never express:

* **Refcounted pages + copy-on-write** — a physical page may appear in
  several block tables (shared-prefix reuse) and/or be pinned by the
  ``PrefixCache`` registry.  Writers must call :meth:`ensure_private`
  first; it transparently remaps a shared page to a fresh private one
  (the caller copies the pool contents).
* **Partial free** — :meth:`free_tail` releases only a request's tail
  pages (page-level partial preemption, the §8 replacement idea pushed
  to sub-request granularity).

The ``PrefixCache`` maps chained page-content hashes to physical pages
and holds a +1 pin on each registered page so completed requests leave
their prompt pages behind as a prefix cache.  Pinned-only pages are
RECLAIMABLE: when the free list runs short, :meth:`PagedAllocator._take`
evicts registry entries in LRU order (a DBMS-style replacement policy on
the page pool itself), so cached prefixes never reduce the capacity the
scheduler may promise to requests — ``OutOfPagesError`` stays
unreachable on admitted schedules.

Replacement policy for REQUESTS is still not here — preemption victims
are chosen by ``repro.core.policies``; the engine then calls
``free(rid)`` / ``free_tail(rid, k)``.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class BlockTable:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0  # valid tokens across those pages


class PrefixCache:
    """Chained-hash -> physical page registry with LRU ordering.

    Key ``i`` is a hash over (key ``i-1``, the token ids of page ``i``),
    so a hit on key ``i`` certifies the whole prefix up to and including
    page ``i`` matches.  Each entry also stores the page's OWN token ids
    and ``get`` re-verifies them: Python's 64-bit hash can collide, and
    a collision served unverified would silently map another prompt's
    KV pages into the request — the one failure mode the token-identical
    contract cannot tolerate.  Lookup/insert refresh LRU recency; the
    allocator evicts from the LRU end when it needs pages back.
    """

    def __init__(self) -> None:
        # key -> (page, that page's token ids)
        self._map: "OrderedDict[int, Tuple[int, Tuple[int, ...]]]" = \
            OrderedDict()

    def __len__(self) -> int:
        return len(self._map)

    def __contains__(self, key: int) -> bool:
        return key in self._map

    def get(self, key: int,
            tokens: Optional[Sequence[int]] = None) -> Optional[int]:
        entry = self._map.get(key)
        if entry is None:
            return None
        page, page_tokens = entry
        if tokens is not None and tuple(tokens) != page_tokens:
            return None                 # hash collision: NOT a match
        self._map.move_to_end(key)
        return page

    def insert(self, key: int, page: int,
               tokens: Sequence[int] = ()) -> None:
        assert key not in self._map, key
        self._map[key] = (page, tuple(tokens))

    def pop_lru(self) -> Tuple[int, int]:
        key, (page, _) = next(iter(self._map.items()))
        del self._map[key]
        return key, page

    @property
    def pages(self) -> List[int]:
        return [page for page, _ in self._map.values()]

    @staticmethod
    def chain_keys(tokens: Sequence[int], page_size: int) -> List[int]:
        """Chained content hashes for every FULL page of ``tokens``."""
        keys: List[int] = []
        prev = 0
        for i in range(len(tokens) // page_size):
            prev = hash((prev, tuple(tokens[i * page_size:(i + 1) * page_size])))
            keys.append(prev)
        return keys


class PagedAllocator:
    def __init__(self, num_pages: int, page_size: int):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._tables: Dict[int, BlockTable] = {}
        self._refs: Dict[int, int] = {}     # page -> refcount (tables + pin)
        self._pinned: Set[int] = set()      # pages pinned by the registry
        self.prefix_cache = PrefixCache()
        # bumped on every block-table mutation — lets the engine cache
        # its device-side block-table upload across decode steps and
        # invalidate it without tracking call sites by hand
        self.version = 0
        self.stats: Dict[str, int] = dict(
            prefix_hits=0, prefix_shared_tokens=0, cow_copies=0,
            reclaimed=0)

    # ------------------------------------------------------------------ #
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Physical pages holding live data (tables and/or registry)."""
        return self.num_pages - len(self._free)

    @property
    def table_pages(self) -> int:
        """Pages referenced by at least one block table (excludes pages
        alive only as registry-cached prefixes)."""
        return len({p for t in self._tables.values() for p in t.pages})

    def tokens_capacity(self) -> int:
        return self.num_pages * self.page_size

    def free_tokens(self) -> int:
        return self.free_pages * self.page_size

    def table(self, rid: int) -> BlockTable:
        return self._tables[rid]

    def has(self, rid: int) -> bool:
        return rid in self._tables

    def pages_needed(self, rid: int, new_tokens: int) -> int:
        if new_tokens <= 0:
            return 0
        cur = self._tables.get(rid)
        have = len(cur.pages) * self.page_size - cur.num_tokens if cur else 0
        need_tokens = max(0, new_tokens - have)
        return (need_tokens + self.page_size - 1) // self.page_size

    # --- refcount plumbing --------------------------------------------- #
    def _decref(self, page: int) -> None:
        self._refs[page] -= 1
        assert self._refs[page] >= 0, page
        if self._refs[page] == 0:
            del self._refs[page]
            self._free.append(page)

    def _take(self, need: int) -> List[int]:
        """Pop ``need`` free pages, reclaiming LRU registry entries when
        the free list runs short — cached prefixes never block a request
        the scheduler admitted."""
        while len(self._free) < need and len(self.prefix_cache):
            _, page = self.prefix_cache.pop_lru()
            self._pinned.discard(page)
            self._decref(page)          # frees iff no table still maps it
            self.stats["reclaimed"] += 1
        if need > len(self._free):
            raise OutOfPagesError(
                f"need {need} pages, {len(self._free)} free "
                f"({len(self.prefix_cache)} cached prefixes left)")
        granted = [self._free.pop() for _ in range(need)]
        for p in granted:
            assert p not in self._refs, p
            self._refs[p] = 1
        return granted

    # ------------------------------------------------------------------ #
    def allocate(self, rid: int, new_tokens: int) -> List[int]:
        """Extend rid's table by new_tokens; returns newly granted pages.
        A zero-token grant is a NO-OP (no phantom empty table)."""
        if new_tokens <= 0:
            return []
        need = self.pages_needed(rid, new_tokens)
        if need:
            # version tracks the PAGE LISTS only: an in-page append
            # (decode filling its current page) must not invalidate the
            # engine's cached device block tables
            self.version += 1
        granted = self._take(need)
        tbl = self._tables.setdefault(rid, BlockTable())
        tbl.pages.extend(granted)
        tbl.num_tokens += new_tokens
        return granted

    def share(self, rid: int, pages: Sequence[int], num_tokens: int) -> None:
        """Map existing (registry-held) pages as the PREFIX of rid's
        table — shared-prefix reuse.  Only full pages are shareable and
        the table must be empty (prefix attach happens at first claim)."""
        assert rid not in self._tables, rid
        assert num_tokens == len(pages) * self.page_size, \
            (num_tokens, len(pages), self.page_size)
        for p in pages:
            assert self._refs.get(p, 0) > 0, f"page {p} is not live"
            self._refs[p] += 1
        self.version += 1
        self._tables[rid] = BlockTable(list(pages), num_tokens)
        self.stats["prefix_hits"] += 1
        self.stats["prefix_shared_tokens"] += num_tokens

    def ensure_private(self, rid: int,
                       page_index: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write guard: before WRITING into table page
        ``page_index``, remap it to a fresh private page if it is shared
        (refcount > 1) or registry-pinned.  Returns ``(old, new)`` when a
        copy is needed (the caller must copy pool contents old -> new),
        else None."""
        tbl = self._tables[rid]
        page = tbl.pages[page_index]
        if self._refs[page] == 1 and page not in self._pinned:
            return None
        self.version += 1
        new = self._take(1)[0]
        tbl.pages[page_index] = new
        self._decref(page)
        self.stats["cow_copies"] += 1
        return page, new

    def free(self, rid: int) -> int:
        """Release all pages of rid (preemption/completion). Returns count.
        Registry-pinned pages stay alive as cached prefixes."""
        tbl = self._tables.pop(rid, None)
        if tbl is None:
            return 0
        self.version += 1
        for p in reversed(tbl.pages):
            self._decref(p)
        return len(tbl.pages)

    def free_tail(self, rid: int, npages: int) -> int:
        """Release only the LAST ``npages`` pages of rid's table
        (page-level partial preemption).  Returns the tokens removed;
        the kept pages are full, so the new boundary is page-aligned."""
        tbl = self._tables[rid]
        assert 0 < npages <= len(tbl.pages), (rid, npages, len(tbl.pages))
        self.version += 1
        removed = tbl.pages[-npages:]
        del tbl.pages[-npages:]
        kept_cap = len(tbl.pages) * self.page_size
        tokens_removed = tbl.num_tokens - min(tbl.num_tokens, kept_cap)
        tbl.num_tokens = min(tbl.num_tokens, kept_cap)
        for p in reversed(removed):
            self._decref(p)
        if not tbl.pages:
            del self._tables[rid]
        return tokens_removed

    # --- shared-prefix registry ---------------------------------------- #
    def lookup_prefix(self, keys: Sequence[int],
                      page_tokens: Optional[Sequence[Sequence[int]]] = None
                      ) -> List[int]:
        """Physical pages for the LONGEST consecutive run of key hits
        starting at page 0 (a miss — or a token-verification failure on
        a hash collision — breaks the chain).  ``page_tokens[i]`` are
        the token ids of page ``i``, compared against the registry
        entry's stored tokens when given."""
        pages: List[int] = []
        for i, key in enumerate(keys):
            toks = page_tokens[i] if page_tokens is not None else None
            page = self.prefix_cache.get(key, toks)
            if page is None:
                break
            pages.append(page)
        return pages

    def register_prefix(self, rid: int, keys: Sequence[int],
                        page_tokens: Sequence[Sequence[int]] = ()
                        ) -> int:
        """Publish rid's first ``len(keys)`` table pages under their
        chained content keys (pin +1 each), storing each page's token
        ids for collision verification at lookup.  Pages whose key is
        already cached — including rid's own shared prefix — are
        skipped.  Returns the number of newly registered pages."""
        tbl = self._tables[rid]
        n = min(len(keys), len(tbl.pages))
        registered = 0
        for i in range(n):
            key, page = keys[i], tbl.pages[i]
            if key in self.prefix_cache or page in self._pinned:
                continue
            toks = page_tokens[i] if i < len(page_tokens) else ()
            self.prefix_cache.insert(key, page, toks)
            self._pinned.add(page)
            self._refs[page] += 1
            registered += 1
        return registered

    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        held = sorted(self._refs)
        all_pages = held + self._free
        assert len(all_pages) == self.num_pages, "page leak"
        assert len(set(all_pages)) == self.num_pages, "double allocation"
        # refcount == table memberships + registry pin, everywhere
        counts: Dict[int, int] = {}
        for rid, t in self._tables.items():
            assert t.pages, f"rid {rid}: empty block table"
            cap = len(t.pages) * self.page_size
            assert 0 < t.num_tokens <= cap, (rid, t.num_tokens, cap)
            for p in t.pages:
                counts[p] = counts.get(p, 0) + 1
        for p in self._pinned:
            counts[p] = counts.get(p, 0) + 1
        assert counts == self._refs, (counts, self._refs)
        assert self._pinned == set(self.prefix_cache.pages), \
            (self._pinned, self.prefix_cache.pages)
