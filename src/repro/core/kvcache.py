"""Paged KV-cache block allocator (control plane) + shared-prefix page
registry.

vLLM-style paging, now REAL: under ``EngineConfig.plane="paged"`` the
serving engine stores attention KV in shared per-layer page pools
``(num_pages, page_size, Hkv, D)`` and this allocator's block tables ARE
the physical page map those pools are indexed with (the Pallas paged
decode kernel dereferences them via scalar prefetch).  The allocator
enforces exactly the page-rounded ``sum(m) <= M`` constraint the
scheduler reasons about — control plane and data plane agree
page-for-page by construction.

Beyond plain bookkeeping it owns the two mechanisms contiguous slots
could never express:

* **Refcounted pages + copy-on-write** — a physical page may appear in
  several block tables (shared-prefix reuse) and/or be pinned by the
  ``PrefixCache`` registry.  Writers must call :meth:`ensure_private`
  first; it transparently remaps a shared page to a fresh private one
  (the caller copies the pool contents).
* **Partial free** — :meth:`free_tail` releases only a request's tail
  pages (page-level partial preemption, the §8 replacement idea pushed
  to sub-request granularity).

The ``PrefixCache`` maps chained page-content hashes to physical pages
and holds a +1 pin on each registered page so completed requests leave
their prompt pages behind as a prefix cache.  Pinned-only pages are
RECLAIMABLE: when the free list runs short, :meth:`PagedAllocator._take`
walks the registry in the eviction order of a PLUGGABLE
``policies.ReplacementPolicy`` (``lru``, ``break_even`` — the §6
five-minute rule scored per entry by break-even residency vs observed
idle time — or ``belady-oracle`` for offline ablation), so cached
prefixes never reduce the capacity the scheduler may promise to
requests — ``OutOfPagesError`` stays unreachable on admitted schedules.
Entries whose page a live block table still maps are SKIPPED (evicting
them frees no memory — it would only burn the registry entry; the
pre-fix bug did exactly that) and counted in ``stats["reclaim_skipped"]``.

Eviction feeds an optional ``on_evict`` hook BEFORE the page returns to
the free list: drivers use it to DEMOTE the evicted KV to a host tier
(``serving.swap_store.PrefixPageEntry``) instead of discarding it.  A
later registry miss that hits the host tier PROMOTES the page back
through :meth:`promote_prefix` (one fresh page, re-pinned, re-keyed) —
:func:`attach_prefix_run` implements that two-tier lookup for both the
serving engine (real pool copies) and the simulator's virtual-time
shadow, so every KV access resolves along the Fig. 8 spectrum:
GPU-resident < host swap-in < recompute.

Replacement policy for REQUESTS is still not here — preemption victims
are chosen by ``repro.core.policies``; the engine then calls
``free(rid)`` / ``free_tail(rid, k)``.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Set,
                    Tuple)

from repro.core.invariants import invariant
from repro.core.policies import LRUPolicy, ReplacementPolicy


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class BlockTable:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0  # valid tokens across those pages


class PrefixCache:
    """Chained-hash -> physical page registry with a pluggable
    replacement policy.

    Key ``i`` is a hash over (key ``i-1``, the token ids of page ``i``),
    so a hit on key ``i`` certifies the whole prefix up to and including
    page ``i`` matches.  Each entry also stores the page's OWN token ids
    and ``get`` re-verifies them: Python's 64-bit hash can collide, and
    a collision served unverified would silently map another prompt's
    KV pages into the request — the one failure mode the token-identical
    contract cannot tolerate.  Entries carry their chain depth ``n_kvs``
    (the prefix length the page terminates) — the break-even policy's
    Eq. 5 input.  Lookup/insert feed the policy's recency; the allocator
    evicts in ``eviction_order`` when it needs pages back.
    """

    def __init__(self, policy: Optional[ReplacementPolicy] = None) -> None:
        self.policy = policy if policy is not None else LRUPolicy()
        # key -> (page, that page's token ids, chain depth in tokens)
        self._map: "OrderedDict[int, Tuple[int, Tuple[int, ...], int]]" = \
            OrderedDict()

    def __len__(self) -> int:
        return len(self._map)

    def __contains__(self, key: int) -> bool:
        return key in self._map

    def get(self, key: int, tokens: Optional[Sequence[int]] = None,
            now: float = 0.0) -> Optional[int]:
        entry = self._map.get(key)
        if entry is None:
            return None
        page, page_tokens, _ = entry
        if tokens is not None and tuple(tokens) != page_tokens:
            return None                 # hash collision: NOT a match
        self._map.move_to_end(key)
        self.policy.record_hit(key, now)
        return page

    def insert(self, key: int, page: int, tokens: Sequence[int] = (),
               n_kvs: int = 0, now: float = 0.0) -> None:
        if key in self._map:
            # a silent re-register would leak the old page's +1 pin (and
            # under ``python -O`` a bare assert would not even fire)
            raise ValueError(
                f"prefix key {key} already registered "
                f"(page {self._map[key][0]})")
        self._map[key] = (page, tuple(tokens), int(n_kvs))
        self.policy.record_insert(key, n_kvs, now)

    def entry(self, key: int) -> Tuple[int, Tuple[int, ...], int]:
        """(page, tokens, n_kvs) of a registered key."""
        return self._map[key]

    def remove(self, key: int) -> Tuple[int, Tuple[int, ...], int]:
        entry = self._map.pop(key)
        self.policy.record_remove(key)
        return entry

    def eviction_order(self, now: float = 0.0) -> List[int]:
        """All keys, most-evictable first, per the installed policy."""
        return self.policy.eviction_order(now)

    @property
    def pages(self) -> List[int]:
        return [page for page, _, _ in self._map.values()]

    def check_invariants(self) -> None:
        invariant(set(self._map) == set(self.policy._seq),
                  "policy metadata out of sync with registry entries")

    @staticmethod
    def chain_keys(tokens: Sequence[int], page_size: int) -> List[int]:
        """Chained content hashes for every FULL page of ``tokens``."""
        keys: List[int] = []
        prev = 0
        for i in range(len(tokens) // page_size):
            prev = hash((prev, tuple(tokens[i * page_size:(i + 1) * page_size])))
            keys.append(prev)
        return keys


class PagedAllocator:
    def __init__(self, num_pages: int, page_size: int, *,
                 policy: Optional[ReplacementPolicy] = None,
                 on_evict: Optional[
                     Callable[[int, int, Tuple[int, ...], int], None]]
                 = None):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(f"num_pages={num_pages}, page_size={page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._tables: Dict[int, BlockTable] = {}
        self._refs: Dict[int, int] = {}     # page -> refcount (tables + pin)
        self._pinned: Set[int] = set()      # pages pinned by the registry
        self.prefix_cache = PrefixCache(policy)
        # demotion hook: called as (key, page, page tokens, chain depth)
        # BEFORE an evicted page returns to the free list, while its
        # pool contents are still intact — drivers snapshot it to the
        # host tier here
        self.on_evict = on_evict
        # virtual-time clock the replacement policy scores against;
        # drivers (engine / simulator shadow) keep it current
        self.now = 0.0
        # bumped on every block-table mutation — lets the engine cache
        # its device-side block-table upload across decode steps and
        # invalidate it without tracking call sites by hand
        self.version = 0
        # rids whose PAGE LIST changed since the last consume_dirty():
        # the delta companion to ``version`` — a version bump tells the
        # engine its device tables are stale, the dirty set tells it
        # WHICH host rows to rewrite before the one refresh upload
        self.dirty: Set[int] = set()
        # fault-injection hook: called as fault_hook(need) before pages
        # are taken — a seeded FaultPlan raises a transient FaultError
        # here to model device allocation failures (serving.faults)
        self.fault_hook: Optional[Callable[[int], None]] = None
        self.stats: Dict[str, int] = dict(
            prefix_hits=0, prefix_shared_tokens=0, cow_copies=0,
            reclaimed=0, reclaim_skipped=0)

    # ------------------------------------------------------------------ #
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Physical pages holding live data (tables and/or registry)."""
        return self.num_pages - len(self._free)

    @property
    def table_pages(self) -> int:
        """Pages referenced by at least one block table (excludes pages
        alive only as registry-cached prefixes)."""
        return len({p for t in self._tables.values() for p in t.pages})

    def tokens_capacity(self) -> int:
        return self.num_pages * self.page_size

    def free_tokens(self) -> int:
        return self.free_pages * self.page_size

    def table(self, rid: int) -> BlockTable:
        return self._tables[rid]

    def has(self, rid: int) -> bool:
        return rid in self._tables

    def consume_dirty(self) -> Set[int]:
        """Return-and-clear the rids whose page lists changed since the
        last call — the engine rewrites exactly those host block-table
        rows before its one refresh upload (a freed rid may appear; the
        caller skips rids with no slot)."""
        dirty, self.dirty = self.dirty, set()
        return dirty

    def pages_needed(self, rid: int, new_tokens: int) -> int:
        if new_tokens <= 0:
            return 0
        cur = self._tables.get(rid)
        have = len(cur.pages) * self.page_size - cur.num_tokens if cur else 0
        need_tokens = max(0, new_tokens - have)
        return (need_tokens + self.page_size - 1) // self.page_size

    # --- refcount plumbing --------------------------------------------- #
    def _decref(self, page: int) -> None:
        self._refs[page] -= 1
        invariant(self._refs[page] >= 0, page)
        if self._refs[page] == 0:
            del self._refs[page]
            self._free.append(page)

    def _take(self, need: int) -> List[int]:
        """Pop ``need`` free pages, reclaiming registry entries in the
        replacement policy's eviction order when the free list runs
        short — cached prefixes never block a request the scheduler
        admitted.

        Candidates whose page a live block table still maps are SKIPPED:
        their pin drop would free nothing, so evicting them only burns
        the registry entry (the pre-fix behaviour — under heavy sharing
        it could strip the whole prefix cache while reclaiming zero
        pages).  Each genuinely evicted entry is offered to ``on_evict``
        (host demotion) before its page returns to the free list, and
        only those count as ``reclaimed``."""
        if self.fault_hook is not None and need > 0:
            self.fault_hook(need)
        if len(self._free) < need and len(self.prefix_cache):
            for key in self.prefix_cache.eviction_order(self.now):
                if len(self._free) >= need:
                    break
                page, tokens, n_kvs = self.prefix_cache.entry(key)
                if self._refs[page] > 1:      # pin + live table mapping(s)
                    self.stats["reclaim_skipped"] += 1
                    continue
                self.prefix_cache.remove(key)
                self._pinned.discard(page)
                if self.on_evict is not None:
                    self.on_evict(key, page, tokens, n_kvs)
                self._decref(page)            # pin was the only ref: frees
                self.stats["reclaimed"] += 1
        if need > len(self._free):
            raise OutOfPagesError(
                f"need {need} pages, {len(self._free)} free "
                f"({len(self.prefix_cache)} cached prefixes left, "
                f"none evictable)")
        granted = [self._free.pop() for _ in range(need)]
        for p in granted:
            invariant(p not in self._refs, p)
            self._refs[p] = 1
        return granted

    # ------------------------------------------------------------------ #
    def allocate(self, rid: int, new_tokens: int) -> List[int]:
        """Extend rid's table by new_tokens; returns newly granted pages.
        A zero-token grant is a NO-OP (no phantom empty table)."""
        if new_tokens <= 0:
            return []
        need = self.pages_needed(rid, new_tokens)
        if need:
            # version tracks the PAGE LISTS only: an in-page append
            # (decode filling its current page) must not invalidate the
            # engine's cached device block tables
            self.version += 1
            self.dirty.add(rid)
        granted = self._take(need)
        tbl = self._tables.setdefault(rid, BlockTable())
        tbl.pages.extend(granted)
        tbl.num_tokens += new_tokens
        return granted

    def share(self, rid: int, pages: Sequence[int], num_tokens: int) -> None:
        """Map existing (registry-held) pages as the PREFIX of rid's
        table — shared-prefix reuse.  Only full pages are shareable and
        the table must be empty (prefix attach happens at first claim)."""
        invariant(rid not in self._tables, rid)
        invariant(num_tokens == len(pages) * self.page_size,
                  (num_tokens, len(pages), self.page_size))
        for p in pages:
            invariant(self._refs.get(p, 0) > 0, f"page {p} is not live")
            self._refs[p] += 1
        self.version += 1
        self.dirty.add(rid)
        self._tables[rid] = BlockTable(list(pages), num_tokens)
        self.stats["prefix_hits"] += 1
        self.stats["prefix_shared_tokens"] += num_tokens

    def extend_shared(self, rid: int, page: int, num_tokens: int) -> None:
        """Append ONE live (registry-held) page to the tail of rid's
        table — the host-promotion path of a prefix attach extends the
        run page by page.  The table must be whole full pages so far."""
        tbl = self._tables[rid]
        invariant(num_tokens == self.page_size, num_tokens)
        invariant(tbl.num_tokens == len(tbl.pages) * self.page_size,
                  (rid, tbl.num_tokens, len(tbl.pages)))
        invariant(self._refs.get(page, 0) > 0, f"page {page} is not live")
        self._refs[page] += 1
        self.version += 1
        self.dirty.add(rid)
        tbl.pages.append(page)
        tbl.num_tokens += num_tokens
        self.stats["prefix_shared_tokens"] += num_tokens

    def ensure_private(self, rid: int,
                       page_index: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write guard: before WRITING into table page
        ``page_index``, remap it to a fresh private page if it is shared
        (refcount > 1) or registry-pinned.  Returns ``(old, new)`` when a
        copy is needed (the caller must copy pool contents old -> new),
        else None."""
        tbl = self._tables[rid]
        page = tbl.pages[page_index]
        if self._refs[page] == 1 and page not in self._pinned:
            return None
        self.version += 1
        self.dirty.add(rid)
        new = self._take(1)[0]
        tbl.pages[page_index] = new
        self._decref(page)
        self.stats["cow_copies"] += 1
        return page, new

    def free(self, rid: int) -> int:
        """Release all pages of rid (preemption/completion). Returns count.
        Registry-pinned pages stay alive as cached prefixes."""
        tbl = self._tables.pop(rid, None)
        if tbl is None:
            return 0
        self.version += 1
        self.dirty.add(rid)
        for p in reversed(tbl.pages):
            self._decref(p)
        return len(tbl.pages)

    def free_tail(self, rid: int, npages: int) -> int:
        """Release only the LAST ``npages`` pages of rid's table
        (page-level partial preemption).  Returns the tokens removed;
        the kept pages are full, so the new boundary is page-aligned."""
        tbl = self._tables[rid]
        invariant(0 < npages <= len(tbl.pages),
                  (rid, npages, len(tbl.pages)))
        self.version += 1
        self.dirty.add(rid)
        removed = tbl.pages[-npages:]
        del tbl.pages[-npages:]
        kept_cap = len(tbl.pages) * self.page_size
        tokens_removed = tbl.num_tokens - min(tbl.num_tokens, kept_cap)
        tbl.num_tokens = min(tbl.num_tokens, kept_cap)
        for p in reversed(removed):
            self._decref(p)
        if not tbl.pages:
            del self._tables[rid]
        return tokens_removed

    # --- shared-prefix registry ---------------------------------------- #
    def lookup_prefix(self, keys: Sequence[int],
                      page_tokens: Optional[Sequence[Sequence[int]]] = None
                      ) -> List[int]:
        """Physical pages for the LONGEST consecutive run of key hits
        starting at page 0 (a miss — or a token-verification failure on
        a hash collision — breaks the chain).  ``page_tokens[i]`` are
        the token ids of page ``i``, compared against the registry
        entry's stored tokens when given."""
        pages: List[int] = []
        for i, key in enumerate(keys):
            toks = page_tokens[i] if page_tokens is not None else None
            page = self.prefix_cache.get(key, toks, now=self.now)
            if page is None:
                break
            pages.append(page)
        return pages

    def register_prefix(self, rid: int, keys: Sequence[int],
                        page_tokens: Sequence[Sequence[int]] = ()
                        ) -> int:
        """Publish rid's first ``len(keys)`` table pages under their
        chained content keys (pin +1 each), storing each page's token
        ids for collision verification at lookup and its chain depth
        for the break-even policy.  Pages whose key is already cached —
        including rid's own shared prefix — are skipped.  Returns the
        number of newly registered pages."""
        tbl = self._tables[rid]
        n = min(len(keys), len(tbl.pages))
        registered = 0
        for i in range(n):
            key, page = keys[i], tbl.pages[i]
            if key in self.prefix_cache or page in self._pinned:
                continue
            toks = page_tokens[i] if i < len(page_tokens) else ()
            self.prefix_cache.insert(key, page, toks,
                                     n_kvs=(i + 1) * self.page_size,
                                     now=self.now)
            self._pinned.add(page)
            self._refs[page] += 1
            registered += 1
        return registered

    def promote_prefix(self, key: int, tokens: Sequence[int],
                       n_kvs: int) -> int:
        """Re-admit a host-demoted prefix page: take one page (this may
        itself reclaim/demote colder entries) and register it under its
        chain key as pinned-only.  The caller writes the host snapshot
        into the returned page and charges the swap-in."""
        page = self._take(1)[0]
        # _take set refs[page] = 1 — here that single ref IS the pin
        self.prefix_cache.insert(key, page, tokens, n_kvs=n_kvs,
                                 now=self.now)
        self._pinned.add(page)
        return page

    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        held = sorted(self._refs)
        all_pages = held + self._free
        invariant(len(all_pages) == self.num_pages, "page leak")
        invariant(len(set(all_pages)) == self.num_pages,
                  "double allocation")
        # refcount == table memberships + registry pin, everywhere
        counts: Dict[int, int] = {}
        for rid, t in self._tables.items():
            invariant(t.pages, f"rid {rid}: empty block table")
            cap = len(t.pages) * self.page_size
            invariant(0 < t.num_tokens <= cap, (rid, t.num_tokens, cap))
            for p in t.pages:
                counts[p] = counts.get(p, 0) + 1
        for p in self._pinned:
            counts[p] = counts.get(p, 0) + 1
        invariant(counts == self._refs, (counts, self._refs))
        invariant(self._pinned == set(self.prefix_cache.pages),
                  (self._pinned, self.prefix_cache.pages))
        self.prefix_cache.check_invariants()


# --------------------------------------------------------------------- #
# two-tier prefix attach (device registry, then host demotion tier)
# --------------------------------------------------------------------- #


def attach_prefix_run(alloc: PagedAllocator, rid: int,
                      keys: Sequence[int],
                      page_tokens: Sequence[Sequence[int]],
                      host_tier: Any = None,
                      restore: Optional[Callable[[int, Any], None]] = None,
                      verify: Optional[Callable[[Any], bool]] = None
                      ) -> Tuple[int, int]:
    """Map the longest consecutive run of cached prefix pages starting
    at page 0 into rid's (empty) block table, resolving each chain key
    first against the DEVICE registry, then — when ``host_tier`` is
    given — against host-demoted ``PrefixPageEntry`` snapshots, which
    are PROMOTED back: one fresh page taken (possibly demoting colder
    entries), re-registered under the key, and filled via ``restore(page,
    entry.kv)``.  Every attached page is mapped into the table (and so
    refcount-protected) before the next key is resolved — a promotion's
    own reclaim can never evict pages of the run being built.

    ``verify(entry)`` — when given — gates every host promotion: a
    False verdict (CRC mismatch, injected promote fault) DROPS the
    demoted entry and ends the run there, so a rotten host snapshot
    degrades to a registry miss (recompute) instead of restoring wrong
    KV.  The engine passes ``swap_store.verify_entry`` composed with
    its fault plan; the simulator mirrors the same plan draws.

    Returns ``(attached_tokens, promoted_tokens)``; the caller charges
    ``swap_time(promoted_tokens)`` — the Fig. 8 host-link price of the
    promotions.  Shared by the serving engine (real pool copies) and the
    simulator's virtual-time shadow (``restore=None``).
    """
    pg = alloc.page_size
    attached = promoted = 0
    for i, key in enumerate(keys):
        toks = page_tokens[i]
        page = alloc.prefix_cache.get(key, toks, now=alloc.now)
        from_host = False
        if page is None and host_tier is not None \
                and key not in alloc.prefix_cache:
            # the `not in` guard closes a collision corner: if the key
            # IS device-registered but under different tokens (a 64-bit
            # hash collision), promoting the host copy would try to
            # re-insert the key — a collision must degrade to a miss,
            # never an error (and never another prompt's KV)
            entry = host_tier.peek_prefix(key, toks)
            if entry is not None and verify is not None \
                    and not verify(entry):
                # integrity failure: drop the rotten snapshot and stop
                # the run — the pages it would have covered recompute
                host_tier.discard_prefix(key)  # repro: allow-unpriced-mutation(dropping a corrupt entry moves no bytes; the caller counts it in its integrity stats)
                break
            if entry is not None:
                try:
                    # repro: allow-unpriced-mutation(priced by the caller - promoted tokens are returned and charged swap_time into the batch, parity-tested engine vs simulator)
                    page = alloc.promote_prefix(key, entry.tokens,
                                                entry.n_kvs)
                except OutOfPagesError:
                    break               # nothing evictable: stop the run
                host_tier.pop_prefix(key)  # repro: allow-unpriced-mutation(the promotion above carries the charge; the pop only hands the entry over)
                if restore is not None:
                    restore(page, entry.kv)
                from_host = True
        if page is None:
            break
        if attached == 0:
            alloc.share(rid, [page], pg)  # repro: allow-unpriced-mutation(sharing maps an existing device page - no bytes move; attached tokens are returned for the caller's prefix_stats)
        else:
            alloc.extend_shared(rid, page, pg)  # repro: allow-unpriced-mutation(same zero-copy mapping as the share above)
        attached += pg
        if from_host:
            promoted += pg
    return attached, promoted
