"""Quickstart — the paper's pipeline in 60 seconds, no GPUs:

1. cost models for batch times (§4),
2. simulate schedulers under contention, NRF vs SRF replacement (§5, §8),
3. the five-minute rule for KV residency (§6),
4. a provably-optimal schedule from the CSP solver (§7).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (BatchSpec, TheoreticalCostModel, break_even_table,
                        fresh_requests, get_hardware, run_sim,
                        solve_optimal_schedule)

cfg = get_config("llama2-7b")
hw = get_hardware("a100")
cm = TheoreticalCostModel(cfg, hw, flops_eff=0.6, bw_eff=0.75,
                          attn_bw_eff=0.25)

# -- 1. cost model ------------------------------------------------------
spec = BatchSpec(prefills=[(512, 0)] * 4, decodes=[(1, 1024)] * 32)
print(f"hybrid batch (4 prefills of 512 + 32 decodes @ m=1024): "
      f"{cm.batch_time(spec)*1e3:.2f} ms predicted")

# -- 2. schedulers + replacement policies -------------------------------
print("\nW=256 identical requests (I=8, O=32), tight KV cache M=1000:")
for name, repl in [("vllm_pf", "pf"), ("vllm", "nrf"), ("vllm", "srf")]:
    reqs = fresh_requests([(8, 32, 0.0)] * 256)
    res = run_sim(name, reqs, cm, M=1000, replacement=repl)
    print(f"  {name:8s}/{repl}: latency {res.latency:7.2f}s  "
          f"preemptions {res.num_preemptions:5d}  "
          f"mean TTFT {res.mean_ttft:6.3f}s")

# -- 3. five-minute rule -------------------------------------------------
print("\nbreak-even KV residency (M=100K):")
for b in break_even_table(cm, M=100_000, ns=(1, 512, 32768)):
    print(f"  N={b.n_kvs:6d}: keep KVs resident if re-accessed within "
          f"{b.interval:8.2f}s")

# -- 4. optimal scheduling (CSP) -----------------------------------------
I, O, W = 4, 4, 4
M = max(2 * I, I + O - 1)
res = solve_optimal_schedule([(I, O)] * W, M=M, C=4096, cost_model=cm)
print(f"\nCSP optimum for W={W} x (I={I}, O={O}), M={M}: "
      f"{res.optimal_time*1e3:.2f} ms in {res.num_batches} batches, "
      f"using {res.num_preemptions} preemptions "
      f"(preemption IS optimal for short requests)")
