"""End-to-end serving driver (the paper is an inference paper, so this is
the required E2E example): a REAL model served with continuous batching,
chunked prefill, and the paper's cache-replacement policies — then the
same workload under NRF vs SRF, verifying byte-identical outputs and
comparing cost-model latencies.

Run:  PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (Request, TheoreticalCostModel, get_hardware,
                        make_scheduler)
from repro.models import model as M
from repro.serving import Engine, EngineConfig

ARCH = "tinyllama-1.1b"
N_REQ = 10
M_KV = 120          # tight cache -> forces preemptions
CACHE_LEN = 64

cfg = dataclasses.replace(get_config(ARCH).reduced(), dtype="float32")
params = M.init_params(cfg, jax.random.PRNGKey(0))
cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))


def workload(seed=0):
    rs = np.random.RandomState(seed)
    reqs = []
    for i in range(N_REQ):
        I, O = int(rs.randint(8, 28)), int(rs.randint(4, 12))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        reqs.append(Request(rid=i, input_len=I, output_len=O,
                            arrival=float(i) * 1e-5, prompt=prompt))
    return reqs


outputs = {}
for repl in ("nrf", "srf"):
    sched = make_scheduler("vllm", M_KV, S=CACHE_LEN * 2, replacement=repl)
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=4, cache_len=CACHE_LEN, chunk=16),
                 cost_model=cm)
    res = eng.run(workload())
    s = res.metrics.summary()
    outputs[repl] = res.outputs
    print(f"[{repl.upper()}] latency={s['latency']*1e3:8.3f}ms  "
          f"preemptions={int(s['preemptions']):3d}  "
          f"batches={int(s['batches']):3d}  "
          f"mean TTFT={s['mean_ttft']*1e3:7.3f}ms  wall={res.wall_time:.1f}s")

same = all(outputs["nrf"][i] == outputs["srf"][i] for i in range(N_REQ))
print(f"\noutputs identical under NRF and SRF: {same} "
      f"(replacement policy changes WHEN work happens, never WHAT "
      f"is computed)")
print("sample generation rid=0:", outputs["srf"][0])
assert same
