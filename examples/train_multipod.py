"""Training example: the same train_step that the 512-chip dry-run lowers,
run for real at CPU scale — sharded params on a tiny host mesh, grad
accumulation, deterministic data, async checkpointing with resume.

Run:  PYTHONPATH=src python examples/train_multipod.py
(Spawns itself with XLA_FLAGS for 4 host devices.)
"""
import os
import subprocess
import sys

if os.environ.get("_REPRO_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["_REPRO_CHILD"] = "1"
    env["PYTHONPATH"] = "src"
    raise SystemExit(subprocess.call([sys.executable, __file__], env=env))

sys.path.insert(0, "src")

import dataclasses
import tempfile

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, batch_for_step
from repro.distributed.sharding import named, param_pspecs
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.training import AdamWConfig, init_adamw, make_train_step

cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                          dtype="float32")
mesh = make_test_mesh(2, 2)
print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
      f"on {len(jax.devices())} host devices")

params = M.init_params(cfg, jax.random.PRNGKey(0))
pspecs = param_pspecs(cfg, params, fsdp=False)
# reduced dims aren't all divisible by the toy mesh: replicate leftovers
pspecs = jax.tree.map(
    lambda s, l: s if all(a is None or l.shape[d] % 2 == 0
                          for d, a in enumerate(s)) else P(),
    pspecs, params, is_leaf=lambda x: isinstance(x, P))
params = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                      params, pspecs)
opt_state = init_adamw(params)
opt_cfg = AdamWConfig(lr=1e-3, total_steps=60, warmup_steps=5)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)

with tempfile.TemporaryDirectory() as ckpt_dir:
    mgr = CheckpointManager(ckpt_dir, interval=20, keep=2)
    with mesh:
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2),
                          donate_argnums=(0, 1))
        for step in range(40):
            batch = batch_for_step(dc, step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            mgr.maybe_save({"p": params, "o": opt_state}, step + 1)
            if step % 10 == 0:
                print(f"step {step:3d}  loss {float(metrics['loss']):.4f}  "
                      f"lr {float(metrics['lr']):.2e}")
        mgr.wait()
        # simulate a restart: restore and continue
        restored, at = mgr.restore_latest({"p": params, "o": opt_state})
        print(f"restored checkpoint at step {at}; continuing to 60")
        params, opt_state = restored["p"], restored["o"]
        for step in range(at, 60):
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_for_step(dc, step))
        print(f"final loss {float(metrics['loss']):.4f}")
print("done — the SAME make_train_step is what dryrun.py lowers for "
      "the 512-chip production meshes.")
