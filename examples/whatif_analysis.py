"""InferMax-style what-if analysis (§2, Fig. 1): explore hardware and
policy changes purely in the cost model — no GPUs burned.

  * What if GPU memory shrinks (multi-tenancy)?  -> preemption wins grow.
  * What if HBM bandwidth doubles (future GPUs)? -> decode-bound batches
    speed up ~2x, SLO pareto widens.
  * Which (c, m) keep TPOT under 100 ms on each hardware?

Run:  PYTHONPATH=src python examples/whatif_analysis.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (BatchSpec, TheoreticalCostModel, fresh_requests,
                        get_hardware, run_sim)
from repro.core.slo import pareto_curve

cfg = get_config("llama2-7b")
base_hw = get_hardware("a100")
CAL = dict(flops_eff=0.6, bw_eff=0.75, attn_bw_eff=0.25)

# -- what if memory shrinks (multi-tenancy)? -----------------------------
print("multi-tenancy: shrinking KV cache M (W=512, I=8, O=32)")
cm = TheoreticalCostModel(cfg, base_hw, **CAL)
for M in (50_000, 5_000, 500):
    pf = run_sim("vllm_pf", fresh_requests([(8, 32, 0.0)] * 512), cm, M=M)
    npf = run_sim("vllm", fresh_requests([(8, 32, 0.0)] * 512), cm, M=M)
    better = "preemption" if npf.latency < pf.latency else "PF"
    print(f"  M={M:6d}: vllm {npf.latency:7.2f}s vs PF {pf.latency:7.2f}s "
          f"-> {better} wins")

# -- what if bandwidth doubles (future GPUs)? ----------------------------
print("\nbandwidth scaling on a decode-heavy batch "
      "(128 decodes @ m=4096):")
spec = BatchSpec(decodes=[(1, 4096)] * 128)
for mult in (1.0, 2.0, 4.0):
    hw = dataclasses.replace(base_hw, hbm_bw=base_hw.hbm_bw * mult)
    t = TheoreticalCostModel(cfg, hw, **CAL).batch_time(spec)
    print(f"  {mult:.0f}x HBM bandwidth: batch time {t*1e3:7.2f} ms")
print("  -> near-linear: decode is bandwidth-bound (the paper's "
      "'memory wall')")

# -- SLO pareto per hardware ---------------------------------------------
print("\nlargest decode context m with TPOT <= 100 ms "
      "(8 prefills of c, 32 decodes):")
for hw_name in ("a100", "h100", "tpu_v5e"):
    cm = TheoreticalCostModel(cfg, get_hardware(hw_name), **CAL)
    pts = pareto_curve(cm, num_prefill=8, num_decode=32, threshold=0.1,
                       cs=(64, 1024))
    desc = ", ".join(f"c={p.c}: m<={p.m}" for p in pts) or "infeasible"
    print(f"  {hw_name:8s}: {desc}")
