"""Distribution layer.  In-process tests cover sharding-rule math and
compression; anything needing >1 device runs in a SUBPROCESS with its own
XLA_FLAGS (the main process must keep the single real CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.distributed.compression import (compress_with_feedback,
                                           compressed_psum, init_error_state)
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.distributed.sharding import _param_rule, _path_names  # noqa
from repro.serving.serve_step import param_specs
from repro.distributed.sharding import param_pspecs

MODEL_PAR = 16


def _run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


# --------------------------------------------------------------------- #
# sharding rules (pure spec math — no devices needed)
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_model_sharded_dims_divide_16(arch):
    """Every dim a spec puts on the 'model' axis must divide 16 —
    otherwise the production mesh cannot shard the tensor evenly."""
    cfg = get_config(arch)
    pshape = param_specs(cfg)
    specs = param_pspecs(cfg, pshape, fsdp=True)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_l, _ = jax.tree_util.tree_flatten_with_path(pshape)
    assert len(flat_s) == len(flat_l)
    for (path, spec), (_, leaf) in zip(flat_s, flat_l):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            if "model" in axes:
                assert leaf.shape[dim] % MODEL_PAR == 0, (path, leaf.shape,
                                                          dim, spec)
            if "data" in axes:
                assert leaf.shape[dim] % MODEL_PAR == 0, (path, leaf.shape)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_vocab_padding_multiple(arch):
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab % MODEL_PAR == 0
    if cfg.num_experts:
        assert cfg.padded_experts % MODEL_PAR == 0


def test_straggler_monitor():
    mon = StragglerMonitor(deadline_factor=2.0, min_floor_s=0.0)
    assert not mon.observe(1.0, 1.5)
    assert mon.observe(1.0, 2.5)
    assert len(mon.events) == 1


# --------------------------------------------------------------------- #
# compression
# --------------------------------------------------------------------- #

def test_error_feedback_invariant():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal(256), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)}
    err = init_error_state(g)
    q, err2, deq = compress_with_feedback(g, err)
    for k in g:
        lhs = np.asarray(g[k], np.float32) + np.asarray(err[k])
        rhs = np.asarray(deq[k]) + np.asarray(err2[k])
        np.testing.assert_allclose(lhs, rhs, atol=1e-6)


def test_error_feedback_long_run_unbiased():
    """Sum of compressed grads tracks the true sum within one step's
    quantization error."""
    rng = np.random.default_rng(1)
    err = init_error_state({"w": jnp.zeros(64)})
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    for t in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * (1 + t % 5),
                              jnp.float32)}
        _, err, deq = compress_with_feedback(g, err)
        true_sum += np.asarray(g["w"])
        deq_sum += np.asarray(deq["w"])
    resid = np.abs(true_sum - deq_sum)
    assert resid.max() < 0.2               # ~ one-step quantization error


# --------------------------------------------------------------------- #
# multi-device subprocesses
# --------------------------------------------------------------------- #

def test_seqsharded_flash_decode_matches_reference():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.collectives import (
            make_seqsharded_decode_attn, decode_attn_reference)
        mesh = make_test_mesh(2, 4)
        B, S, H, Hkv, D = 4, 64, 8, 2, 32
        k0 = jax.random.PRNGKey(0)
        q = jax.random.normal(k0, (B, H, D))
        k = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, Hkv, D))
        lens = jnp.array([3, 17, 40, 64], jnp.int32)
        fn = make_seqsharded_decode_attn(mesh)
        out = jax.jit(fn)(q, k, v, lens)
        ref = decode_attn_reference(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """pjit train step on a 2x2 mesh == single-device step (fp32)."""
    out = _run_sub("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import model as M
        from repro.training import AdamWConfig, init_adamw, make_train_step
        from repro.distributed.sharding import param_pspecs, named
        from repro.launch.mesh import make_test_mesh
        from repro.data import DataConfig, batch_for_step

        cfg = dataclasses.replace(get_config('smollm-360m').reduced(),
                                  dtype='float32')
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=1e-3, total_steps=4, warmup_steps=1)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                        global_batch=4)
        batch = batch_for_step(dc, 0)

        # single device
        s0 = init_adamw(params)
        p1, s1, m1 = jax.jit(make_train_step(cfg, opt_cfg))(params, s0,
                                                            batch)
        # 2x2 mesh
        mesh = make_test_mesh(2, 2)
        # reduced dims aren't all divisible by 2 on 'model': replicate
        # anything that does not divide evenly
        ps = param_pspecs(cfg, params, fsdp=False)
        def fix(spec, leaf):
            ok = all(a is None or leaf.shape[d] % 2 == 0
                     for d, a in enumerate(spec))
            return spec if ok else P()
        ps = jax.tree.map(fix, ps, params,
                          is_leaf=lambda x: isinstance(x, P))
        sp = named(mesh, ps)
        params_sharded = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, sp)
        s0b = init_adamw(params_sharded)
        with mesh:
            step = jax.jit(make_train_step(cfg, opt_cfg))
            p2, s2, m2 = step(params_sharded, s0b, batch)
        np.testing.assert_allclose(float(m1['loss']), float(m2['loss']),
                                   rtol=1e-5)
        a = np.asarray(jax.tree.leaves(p1)[0])
        b = np.asarray(jax.tree.leaves(p2)[0])
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_remesh_shrink_and_reshard():
    """512->... CPU-scale analogue: lose half the devices (8 -> 4), rebuild
    the mesh with the model axis intact, reshard params, keep training."""
    out = _run_sub("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import model as M
        from repro.distributed.fault_tolerance import elastic_remesh
        from repro.distributed.sharding import param_pspecs, named
        from jax.sharding import PartitionSpec as P

        cfg = dataclasses.replace(get_config('smollm-360m').reduced(),
                                  dtype='float32')
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        devs = jax.devices()
        assert len(devs) == 8
        mesh = elastic_remesh(devs, model_parallel=2)
        assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {
            'data': 4, 'model': 2}
        # node failure: 3 devices gone
        survivors = devs[:5]
        mesh2 = elastic_remesh(survivors, model_parallel=2)
        assert dict(zip(mesh2.axis_names, mesh2.devices.shape)) == {
            'data': 2, 'model': 2}
        ps = param_pspecs(cfg, params, fsdp=False)
        def fix(spec, leaf):
            ok = all(a is None or leaf.shape[d] % 2 == 0
                     for d, a in enumerate(spec))
            return spec if ok else P()
        ps = jax.tree.map(fix, ps, params,
                          is_leaf=lambda x: isinstance(x, P))
        from repro.distributed.fault_tolerance import reshard
        params2 = jax.tree.map(
            lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(
                mesh2, s)), params, ps)
        # forward still works on the shrunken mesh
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab_size)
        with mesh2:
            loss = jax.jit(lambda p: M.train_loss(
                cfg, p, {'tokens': toks, 'labels': toks}))(params2)
        assert bool(jnp.isfinite(loss))
        print("OK")
    """)
    assert "OK" in out


def test_ep_moe_matches_dense_dispatch():
    """apply_moe_ep (shard_map + all_to_all, §Perf cell B) == the dense
    dispatch oracle, for both MoE archs (incl. shared experts and padded
    expert counts), and gradients flow."""
    out = _run_sub("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import moe as moe_mod
        from repro.models import model as M
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.context import set_mesh

        mesh = make_test_mesh(2, 2)
        set_mesh(mesh)
        for name in ("qwen3-moe-30b-a3b", "qwen2-moe-a2.7b"):
            cfg = dataclasses.replace(get_config(name).reduced(),
                                      dtype='float32')
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            lp = jax.tree.map(lambda a: a[0], params['layers'])
            x = jax.random.normal(jax.random.PRNGKey(1),
                                  (2, 16, cfg.d_model)) * 0.5
            with mesh:
                y_ep = jax.jit(lambda p, xx: moe_mod.apply_moe_ep(
                    p, cfg, xx, capacity_factor=8.0))(lp['moe'], x)
                g = jax.jit(jax.grad(lambda p: jnp.sum(
                    moe_mod.apply_moe_ep(p, cfg, x,
                                         capacity_factor=8.0) ** 2)
                ))(lp['moe'])
            y_dense = moe_mod.apply_moe(lp['moe'], cfg, x)
            np.testing.assert_allclose(np.asarray(y_ep),
                                       np.asarray(y_dense),
                                       rtol=2e-4, atol=2e-4)
            assert all(bool(jnp.all(jnp.isfinite(l)))
                       for l in jax.tree.leaves(g))
        print("OK")
    """)
    assert "OK" in out


def test_seqsharded_decode_partials_merge():
    """shard_map flash-decode partials + two-group merge == reference
    (the deferred-append decode path under sequence sharding)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.collectives import (
            make_seqsharded_decode_attn_partials, decode_attn_reference)
        from repro.models.attention import merge_softmax_groups
        mesh = make_test_mesh(2, 4)
        B, S, H, Hkv, D = 4, 64, 8, 2, 32
        k0 = jax.random.PRNGKey(0)
        q = jax.random.normal(k0, (B, H, D))
        k = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, Hkv, D))
        k_new = jax.random.normal(jax.random.fold_in(k0, 3), (B, Hkv, D))
        v_new = jax.random.normal(jax.random.fold_in(k0, 4), (B, Hkv, D))
        lens = jnp.array([3, 17, 40, 63], jnp.int32)
        fn = make_seqsharded_decode_attn_partials(mesh)
        out1, m1, l1 = jax.jit(fn)(q, k, v, lens)
        G = H // Hkv
        qg = q.reshape(B, Hkv, G, D)
        s2 = jnp.einsum('bhgd,bhd->bhg', qg, k_new) / jnp.sqrt(jnp.asarray(D, jnp.float32))
        v2 = jnp.broadcast_to(v_new[:, :, None, :], (B, Hkv, G, D))
        merged = merge_softmax_groups(out1.reshape(B, Hkv, G, D),
                                      m1.reshape(B, Hkv, G),
                                      l1.reshape(B, Hkv, G), s2, v2)
        # oracle: append the new token at each row's length slot
        rows = jnp.arange(B)
        k_full = k.at[rows, lens].set(k_new)
        v_full = v.at[rows, lens].set(v_new)
        ref = decode_attn_reference(q, k_full, v_full, lens + 1)
        np.testing.assert_allclose(np.asarray(merged.reshape(B, H, D)),
                                   np.asarray(ref), rtol=2e-5, atol=2e-5)
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_single_cell_subprocess():
    """End-to-end dry-run of one cell on the REAL 512-device host mesh
    (the deliverable-e path), multi-pod included."""
    out = _run_sub("""
        from repro.launch.dryrun import dryrun_cell
        rep = dryrun_cell('smollm-360m', 'decode_32k', multi_pod=True,
                          verbose=False)
        assert rep['chips'] == 512
        assert rep['fits_hbm']
        assert rep['roofline']['dominant'] in ('compute_s', 'memory_s',
                                               'collective_s')
        print('OK')
    """, devices=512, timeout=1200)
    assert "OK" in out


def test_run_with_retries():
    from repro.distributed.fault_tolerance import run_with_retries
    calls = []

    def flaky(x):
        calls.append(x)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return x + 1

    assert run_with_retries(flaky, 41, backoff_s=0.0) == 42
    assert len(calls) == 3
    with pytest.raises(ValueError):
        run_with_retries(lambda: (_ for _ in ()).throw(ValueError()),
                         retries=1, backoff_s=0.0)


def test_run_with_retries_exhaustion_reraises():
    from repro.distributed.fault_tolerance import run_with_retries
    attempts = []

    def always_fails():
        attempts.append(1)
        raise RuntimeError("permanent")

    slept = []
    with pytest.raises(RuntimeError, match="permanent"):
        run_with_retries(always_fails, retries=3, backoff_s=0.1,
                         sleep=slept.append)
    # retries+1 total attempts; no sleep after the final failure
    assert len(attempts) == 4
    assert slept == [0.1, 0.2, 0.4]


def test_run_with_retries_injected_sleep_schedule():
    from repro.distributed.fault_tolerance import run_with_retries
    slept = []
    state = {"n": 0}

    def fails_twice():
        state["n"] += 1
        if state["n"] <= 2:
            raise RuntimeError("transient")
        return "ok"

    assert run_with_retries(fails_twice, retries=3, backoff_s=0.1,
                            sleep=slept.append) == "ok"
    # exponential backoff, virtual clock: 0.1, 0.2 — never 0.4
    assert slept == [0.1, 0.2]


def test_run_with_retries_custom_retry_on():
    from repro.distributed.fault_tolerance import run_with_retries
    state = {"n": 0}

    def fails_once():
        state["n"] += 1
        if state["n"] == 1:
            raise KeyError("transient")
        return state["n"]

    assert run_with_retries(fails_once, retries=1, backoff_s=0.0,
                            retry_on=(KeyError,)) == 2
    # RuntimeError is NOT retried when retry_on excludes it
    with pytest.raises(RuntimeError):
        run_with_retries(lambda: (_ for _ in ()).throw(RuntimeError()),
                         retries=3, backoff_s=0.0, retry_on=(KeyError,))
