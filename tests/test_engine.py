"""Serving engine: generation parity under preemption — the paper's
correctness contract (scheduling never changes outputs)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Request, TheoreticalCostModel, get_hardware,
                        make_scheduler)
from repro.models import model as M
from repro.serving import Engine, EngineConfig, generate_reference

RNG = jax.random.PRNGKey(0)


def build(name, M_kv=60, nslots=4, replacement="srf", scheduler="vllm",
          cache_len=64, chunk=16):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    params = M.init_params(cfg, RNG)
    sched = make_scheduler(scheduler, M_kv, S=128, replacement=replacement)
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=nslots, cache_len=cache_len,
                              chunk=chunk), cost_model=cm)
    return cfg, params, eng


def requests_for(cfg, n=5, seed=0):
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        I, O = int(rs.randint(8, 25)), int(rs.randint(3, 9))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        out.append(Request(rid=i, input_len=I, output_len=O,
                           arrival=0.0, prompt=prompt))
    return out


@pytest.mark.parametrize("name,repl", [
    ("tinyllama-1.1b", "srf"),
    ("tinyllama-1.1b", "nrf"),
    ("hymba-1.5b", "srf"),
    ("rwkv6-7b", "srf"),
    ("qwen2-moe-a2.7b", "srf"),
])
def test_generation_parity_under_preemption(name, repl):
    cfg, params, eng = build(name, replacement=repl)
    reqs = requests_for(cfg)
    res = eng.run(reqs)
    assert res.metrics.num_preemptions > 0, "test must exercise preemption"
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=64)
        assert res.outputs[r.rid] == ref, f"rid={r.rid}"


def test_sarathi_chunked_hybrid_parity():
    cfg, params, eng = build("tinyllama-1.1b", scheduler="sarathi",
                             M_kv=80, chunk=8)
    eng.sched.cfg.C = 24                     # small budget: many chunks
    reqs = requests_for(cfg, n=4, seed=3)
    res = eng.run(reqs)
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=64)
        assert res.outputs[r.rid] == ref


def test_online_arrivals_engine():
    cfg, params, eng = build("tinyllama-1.1b", M_kv=200)
    reqs = requests_for(cfg, n=3)
    reqs[2].arrival = 1e9                    # far future
    res = eng.run(reqs)
    assert reqs[2].finish_time >= 1e9
    assert all(r.finished for r in reqs)


def test_engine_respects_slot_cap():
    cfg, params, eng = build("tinyllama-1.1b", M_kv=100_000, nslots=2)
    reqs = requests_for(cfg, n=5)
    res = eng.run(reqs)
    for log in res.metrics.batches:
        assert log.num_prefill + log.num_decode <= 2
    assert all(r.finished for r in reqs)


def test_engine_metrics_sane():
    cfg, params, eng = build("tinyllama-1.1b", M_kv=300)
    reqs = requests_for(cfg, n=4)
    res = eng.run(reqs)
    s = res.metrics.summary()
    assert s["latency"] > 0
    assert s["tps"] > 0
    total = sum(len(v) for v in res.outputs.values())
    assert total == sum(r.output_len for r in reqs)
