"""Serving engine: generation parity under preemption — the paper's
correctness contract (scheduling never changes outputs)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Request, TheoreticalCostModel, get_hardware,
                        make_scheduler)
from repro.models import model as M
from repro.serving import Engine, EngineConfig, generate_reference

RNG = jax.random.PRNGKey(0)


def build(name, M_kv=60, nslots=4, replacement="srf", scheduler="vllm",
          cache_len=64, chunk=16, preempt_mode="recompute",
          swap_bytes=None):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    params = M.init_params(cfg, RNG)
    sched = make_scheduler(scheduler, M_kv, S=128, replacement=replacement,
                           preempt_mode=preempt_mode)
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=nslots, cache_len=cache_len,
                              chunk=chunk, swap_bytes=swap_bytes),
                 cost_model=cm)
    return cfg, params, eng


def requests_for(cfg, n=5, seed=0):
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        I, O = int(rs.randint(8, 25)), int(rs.randint(3, 9))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        out.append(Request(rid=i, input_len=I, output_len=O,
                           arrival=0.0, prompt=prompt))
    return out


@pytest.mark.parametrize("name,repl", [
    ("tinyllama-1.1b", "srf"),
    ("tinyllama-1.1b", "nrf"),
    ("hymba-1.5b", "srf"),
    ("rwkv6-7b", "srf"),
    ("qwen2-moe-a2.7b", "srf"),
])
def test_generation_parity_under_preemption(name, repl):
    cfg, params, eng = build(name, replacement=repl)
    reqs = requests_for(cfg)
    res = eng.run(reqs)
    assert res.metrics.num_preemptions > 0, "test must exercise preemption"
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=64)
        assert res.outputs[r.rid] == ref, f"rid={r.rid}"


# --- §5.4 swap/restore parity ---------------------------------------- #
# One dense config and one windowed-attention hybrid (hymba's reduced
# sliding window + SSM branch): the swap snapshot must round-trip EVERY
# cache leaf — rolling KV buffers, position index, recurrent state.
@pytest.mark.parametrize("name", ["tinyllama-1.1b", "hymba-1.5b"])
def test_swap_parity_across_preempt_modes(name):
    outputs = {}
    for mode in ("recompute", "swap", "auto"):
        cfg, params, eng = build(name, preempt_mode=mode)
        reqs = requests_for(cfg)
        res = eng.run(reqs)
        assert res.metrics.num_preemptions > 0, \
            f"{mode}: test must exercise preemption"
        if mode == "swap":
            assert res.metrics.num_swaps > 0
            assert res.swap_stats["swap_ins"] == res.swap_stats["swap_outs"]
            assert res.swap_stats["swap_ins"] > 0
            assert res.swap_stats["kv_in"] == res.swap_stats["kv_out"] > 0
            # per-request swap counters agree with the engine's stats
            assert sum(r.swaps for r in reqs) == res.swap_stats["swap_ins"]
        else:
            # leak check: every suspend was restored (engine.run asserts
            # the store is empty; double-check through the public stats)
            assert res.swap_stats["swap_ins"] == res.swap_stats["swap_outs"]
        outputs[mode] = res.outputs
    assert outputs["recompute"] == outputs["swap"], "swap changed tokens"
    assert outputs["recompute"] == outputs["auto"], "auto changed tokens"
    # and both match the scheduler-free reference
    cfg, params, _ = build(name)
    for r in requests_for(cfg):
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=64)
        assert outputs["swap"][r.rid] == ref, f"rid={r.rid}"


def test_swap_parity_ssm():
    """SSM (rwkv6) swap: the snapshot carries the recurrent state leaf, so
    suspend/resume must reproduce recompute's tokens exactly too."""
    outputs = {}
    for mode in ("recompute", "swap"):
        cfg, params, eng = build("rwkv6-7b", preempt_mode=mode)
        reqs = requests_for(cfg)
        res = eng.run(reqs)
        assert res.metrics.num_preemptions > 0
        outputs[mode] = res.outputs
    assert outputs["recompute"] == outputs["swap"]


def test_auto_mode_prices_the_crossover():
    """preempt_mode='auto' consults the cost model per victim: with a
    free host link every victim swaps; with swap unpriced it recomputes."""

    class FreeSwap(TheoreticalCostModel):
        def swap_time(self, n_kvs):
            return 1e-12

    class NoSwap(TheoreticalCostModel):
        def swap_time(self, n_kvs):
            return 0.0          # 'not modeled' -> auto falls back

    for cm_cls, expect_swaps in ((FreeSwap, True), (NoSwap, False)):
        cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                                  dtype="float32")
        params = M.init_params(cfg, RNG)
        sched = make_scheduler("vllm", 60, S=128, replacement="srf",
                               preempt_mode="auto")
        cm = cm_cls(cfg, get_hardware("tpu_v5e"))
        eng = Engine(cfg, params, sched,
                     EngineConfig(nslots=4, cache_len=64, chunk=16),
                     cost_model=cm)
        res = eng.run(requests_for(cfg))
        assert res.metrics.num_preemptions > 0
        assert (res.metrics.num_swaps > 0) == expect_swaps, cm_cls.__name__


def test_swap_store_full_falls_back_to_recompute():
    """A bounded host store (EngineConfig.swap_bytes) must not wedge or
    change tokens: victims that don't fit are discarded and recomputed."""
    ref_outputs = None
    for swap_bytes in (None, 1):      # unbounded vs fits-nothing
        cfg, params, eng = build("tinyllama-1.1b", preempt_mode="swap",
                                 swap_bytes=swap_bytes)
        reqs = requests_for(cfg)
        res = eng.run(reqs)
        assert res.metrics.num_preemptions > 0
        if swap_bytes is None:
            assert res.swap_stats["swap_fallbacks"] == 0
            ref_outputs = res.outputs
        else:
            # every suspend attempt overflowed and fell back
            assert res.swap_stats["swap_fallbacks"] > 0
            assert res.swap_stats["swap_outs"] == 0
            assert res.metrics.num_swaps == 0
            assert sum(r.swaps for r in reqs) == 0
            assert res.outputs == ref_outputs, "fallback changed tokens"

    # mixed regime: room for roughly one suspended slot at a time
    import jax.numpy as jnp
    cfg, params, eng = build("tinyllama-1.1b", preempt_mode="swap")
    one_slot = sum(
        np.asarray(leaf).nbytes
        for leaf in jax.tree.leaves(eng._slot_slice(eng.cache,
                                                    jnp.int32(0))))
    cfg, params, eng = build("tinyllama-1.1b", preempt_mode="swap",
                             swap_bytes=int(one_slot * 1.5))
    reqs = requests_for(cfg)
    res = eng.run(reqs)
    assert res.swap_stats["swap_outs"] > 0, "capacity fit no swap at all"
    assert res.outputs == ref_outputs


def test_swap_charges_host_link_in_virtual_time():
    """Same schedule, but swap mode pays swap_time per out+in transfer in
    the engine's virtual clock (mirroring the simulator)."""
    cfg, params, eng = build("tinyllama-1.1b", preempt_mode="swap")
    res = eng.run(requests_for(cfg))
    charged = sum(log.swap_s for log in res.metrics.batches)
    assert res.metrics.num_swaps > 0
    assert charged > 0.0


def test_sarathi_chunked_hybrid_parity():
    cfg, params, eng = build("tinyllama-1.1b", scheduler="sarathi",
                             M_kv=80, chunk=8)
    eng.sched.cfg.C = 24                     # small budget: many chunks
    reqs = requests_for(cfg, n=4, seed=3)
    res = eng.run(reqs)
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=64)
        assert res.outputs[r.rid] == ref


def test_online_arrivals_engine():
    cfg, params, eng = build("tinyllama-1.1b", M_kv=200)
    reqs = requests_for(cfg, n=3)
    reqs[2].arrival = 1e9                    # far future
    res = eng.run(reqs)
    assert reqs[2].finish_time >= 1e9
    assert all(r.finished for r in reqs)


def test_engine_respects_slot_cap():
    cfg, params, eng = build("tinyllama-1.1b", M_kv=100_000, nslots=2)
    reqs = requests_for(cfg, n=5)
    res = eng.run(reqs)
    for log in res.metrics.batches:
        assert log.num_prefill + log.num_decode <= 2
    assert all(r.finished for r in reqs)


def test_engine_metrics_sane():
    cfg, params, eng = build("tinyllama-1.1b", M_kv=300)
    reqs = requests_for(cfg, n=4)
    res = eng.run(reqs)
    s = res.metrics.summary()
    assert s["latency"] > 0
    assert s["tps"] > 0
    total = sum(len(v) for v in res.outputs.values())
    assert total == sum(r.output_len for r in reqs)
