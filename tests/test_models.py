"""Per-arch smoke tests (REDUCED configs, assignment §f) + family
parity properties: chunked-prefill == full-prefill, decode continuity,
vocab-padding neutrality."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, applicable_shapes, get_config
from repro.models import model as M

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, rng=RNG):
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_smoke_train_step(name):
    """One forward/train step on CPU: correct shapes, no NaNs."""
    cfg = get_config(name).reduced()
    params = M.init_params(cfg, RNG)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: M.train_loss(cfg, p, batch))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_smoke_prefill_decode(name):
    cfg = get_config(name).reduced()
    params = M.init_params(cfg, RNG)
    batch = make_batch(cfg)
    cache_len = cfg.window if cfg.window else 64
    logits, cache = M.prefill(cfg, params, batch, cache_len=cache_len)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)
    logits2, cache = M.decode_step(cfg, params, nxt, cache)
    assert logits2.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(cache["index"][0]) == batch["tokens"].shape[1] + (
        cfg.num_patches if cfg.frontend == "patch" else 0) + 1


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "qwen3-4b",
                                  "qwen2-moe-a2.7b", "hymba-1.5b",
                                  "rwkv6-7b", "musicgen-medium",
                                  "starcoder2-3b", "smollm-360m"])
def test_chunked_prefill_parity(name):
    """prefill_chunk over 3 chunks == one full prefill (fp32, exact-ish).
    This is the correctness backbone of chunked prefill + refill."""
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    params = M.init_params(cfg, RNG)
    B, S = 2, 48
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    Smax = cfg.window if cfg.window else 64
    lf, cache_f = M.prefill(cfg, params, {"tokens": toks},
                            cache_len=Smax, moe_impl="dense")
    cache = M.init_cache(cfg, B, Smax)
    for i in range(0, S, 16):
        lc, cache = M.prefill_chunk(cfg, params, toks[:, i:i + 16], cache,
                                    moe_impl="dense")
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc),
                               rtol=2e-4, atol=2e-4)
    # decode parity from both caches
    nxt = jnp.argmax(lf, -1)
    d1, _ = M.decode_step(cfg, params, nxt, cache_f, moe_impl="dense")
    d2, _ = M.decode_step(cfg, params, nxt, cache, moe_impl="dense")
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-4, atol=2e-4)


def test_uneven_chunk_sizes_parity():
    """Arbitrary chunk splits (incl. size-1) stay consistent."""
    cfg = dataclasses.replace(get_config("hymba-1.5b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, RNG)
    toks = jax.random.randint(RNG, (1, 37), 0, cfg.vocab_size)
    Smax = cfg.window
    lf, _ = M.prefill(cfg, params, {"tokens": toks}, cache_len=Smax)
    cache = M.init_cache(cfg, 1, Smax)
    ofs = 0
    for c in (1, 7, 16, 13):
        lc, cache = M.prefill_chunk(cfg, params, toks[:, ofs:ofs + c], cache)
        ofs += c
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc),
                               rtol=3e-4, atol=3e-4)


def test_vocab_padding_never_wins():
    """Padded-vocab logit rows exist but the loss masks them and real
    generation ignores them (sampling slices :vocab_size)."""
    cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                              dtype="float32", vocab_size=250)  # pads to 256
    params = M.init_params(cfg, RNG)
    batch = make_batch(cfg)
    assert cfg.padded_vocab > cfg.vocab_size
    loss = M.train_loss(cfg, params, batch)
    # perturbing padded-row weights must not change the loss
    head_key = "embed" if cfg.tie_embeddings else "head"
    p2 = dict(params)
    p2[head_key] = p2[head_key].at[cfg.vocab_size:].add(7.0)
    loss2 = M.train_loss(cfg, p2, batch)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-6)


def test_moe_padded_experts_get_zero_weight():
    """qwen2-moe 60->64 padding: router never routes to pads."""
    cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b").reduced(),
                              dtype="float32")
    # reduced: num_experts=4 padded to 4; force real padding
    cfg = dataclasses.replace(cfg, num_experts=3, expert_pad_multiple=4)
    params = M.init_params(cfg, RNG)
    batch = make_batch(cfg)
    from repro.models import moe as moe_mod
    lp = jax.tree.map(lambda x: x[0], params["layers"])  # layer 0
    x = jax.random.normal(RNG, (2, 8, cfg.d_model))
    y = moe_mod.apply_moe(lp["moe"], cfg, x)
    # zero out padding experts' weights: output must be identical
    moe_p = dict(lp["moe"])
    for k in ("wi_gate", "wi_up", "wo"):
        moe_p[k] = moe_p[k].at[cfg.num_experts:].set(1234.5)
    y2 = moe_mod.apply_moe(moe_p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)


def test_moe_sparse_matches_dense_without_overflow():
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, RNG)
    from repro.models import moe as moe_mod
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(RNG, (1, 16, cfg.d_model)) * 0.5
    yd = moe_mod.apply_moe(lp["moe"], cfg, x)
    ys = moe_mod.apply_moe_sparse(lp["moe"], cfg, x, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_restricts_attention():
    """Tokens beyond the layered receptive field (L x window) cannot
    influence the output (hymba's windowed-attention branch)."""
    cfg = dataclasses.replace(get_config("hymba-1.5b").reduced(),
                              dtype="float32", ssm_state=0, ssm_heads=0,
                              family="dense")  # isolate windowed attention
    params = M.init_params(cfg, RNG)
    # receptive field grows by `window` per layer: need S > L*window
    S = cfg.num_layers * cfg.window + 8
    toks = jax.random.randint(RNG, (1, S), 0, cfg.vocab_size)
    l1, _ = M.prefill(cfg, params, {"tokens": toks}, cache_len=cfg.window)
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab_size)
    l2, _ = M.prefill(cfg, params, {"tokens": toks2}, cache_len=cfg.window)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_long_500k_only_for_subquadratic():
    for name in ASSIGNED_ARCHS:
        cfg = get_config(name)
        shapes = applicable_shapes(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


@pytest.mark.parametrize("name", ["qwen3-4b", "hymba-1.5b", "rwkv6-7b",
                                  "musicgen-medium"])
def test_deferred_decode_matches_inline(name):
    """decode_step_deferred (once-per-step cache scatter, §Perf cell A)
    stays in exact lockstep with decode_step over several steps."""
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    params = M.init_params(cfg, RNG)
    toks = jax.random.randint(RNG, (2, 40), 0, cfg.vocab_size)
    Smax = cfg.window if cfg.window else 64
    lg, cache = M.prefill(cfg, params, {"tokens": toks}, cache_len=Smax)
    ci, cd = cache, dict(cache)
    cur_i = cur_d = jnp.argmax(lg, -1)
    for _ in range(4):
        li, ci = M.decode_step(cfg, params, cur_i, ci)
        ld, cd = M.decode_step_deferred(cfg, params, cur_d, cd)
        np.testing.assert_allclose(np.asarray(li), np.asarray(ld),
                                   rtol=2e-5, atol=2e-5)
        cur_i, cur_d = jnp.argmax(li, -1), jnp.argmax(ld, -1)
    for a, b in zip(jax.tree.leaves(ci), jax.tree.leaves(cd)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-5)


def test_flash_jnp_decode_matches_reference():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, RNG)
    toks = jax.random.randint(RNG, (2, 40), 0, cfg.vocab_size)
    lg, cache = M.prefill(cfg, params, {"tokens": toks}, cache_len=64)
    nxt = jnp.argmax(lg, -1)
    d_ref, _ = M.decode_step(cfg, params, nxt, cache, impl="reference")
    d_fl, _ = M.decode_step(cfg, params, nxt, cache, impl="flash_jnp")
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_fl),
                               rtol=2e-5, atol=2e-5)


def test_decode_continuity_rwkv_state():
    """RWKV decode continues the prefill state exactly (fp32)."""
    cfg = dataclasses.replace(get_config("rwkv6-7b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, RNG)
    toks = jax.random.randint(RNG, (1, 16), 0, cfg.vocab_size)
    # full prefill of 17 tokens == prefill 16 + decode 1
    t17 = jnp.concatenate([toks, toks[:, :1]], axis=1)
    lf, _ = M.prefill(cfg, params, {"tokens": t17}, cache_len=32)
    _, cache = M.prefill(cfg, params, {"tokens": toks}, cache_len=32)
    ld, _ = M.decode_step(cfg, params, toks[:, 0], cache)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ld),
                               rtol=2e-4, atol=2e-4)
