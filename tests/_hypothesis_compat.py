"""Offline fallback for ``hypothesis`` (given / settings / strategies).

This repo's property tests are written against the hypothesis API, but the
test environment has no network access and hypothesis may not be
installed.  When the real package is available we re-export it verbatim;
otherwise a tiny deterministic sampler stands in: each ``@given`` test is
run ``max_examples`` times over pseudo-random examples drawn from a
per-test seeded ``random.Random`` (seed = CRC32 of the test name), so the
examples are stable across runs and machines.

The fallback intentionally implements ONLY what this suite uses:
``integers, floats, booleans, just, one_of, lists, tuples, sampled_from``
and keyword-style ``@given(...)`` under an optional ``@settings(...)``.
No shrinking, no example database — failures print the generated kwargs
so they can be reproduced by hand.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def just(value) -> _Strategy:
            return _Strategy(lambda rng: value)

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def one_of(*strats: _Strategy) -> _Strategy:
            return _Strategy(
                lambda rng: strats[rng.randrange(len(strats))].example(rng))

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strats: _Strategy) -> _Strategy:
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strats))

    strategies = _Strategies()

    def settings(max_examples: int = 100, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_settings = {"max_examples": max_examples}
            return fn
        return deco

    def given(**strat_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args):
                opts = getattr(wrapper, "_compat_settings", None) \
                    or getattr(fn, "_compat_settings", {})
                n = opts.get("max_examples", 100)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for i in range(n):
                    kwargs = {k: s.example(rng)
                              for k, s in strat_kwargs.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception:
                        print(f"falsifying example #{i} for "
                              f"{fn.__name__}: {kwargs!r}")
                        raise
            # pytest must not see the strategy kwargs as fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco

st = strategies
