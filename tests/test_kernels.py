"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).

Assignment §c: 'For each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py pure-jnp oracle.'
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.paged_attention.ops import (decode_attention_dense,
                                               paged_decode_attention)
from repro.kernels.paged_attention.ref import paged_decode_reference
from repro.kernels.rwkv_scan.ops import wkv6
from repro.kernels.rwkv_scan.ref import wkv6_reference

K = jax.random.PRNGKey


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 4, 4, 64),       # MHA
    (2, 256, 8, 2, 64),       # GQA 4:1
    (1, 128, 8, 1, 128),      # MQA
    (2, 100, 4, 2, 64),       # ragged S (padding path)
    (1, 512, 2, 2, 128),      # long
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, Hkv, D, dtype):
    q = jax.random.normal(K(0), (B, S, H, D), dtype)
    k = jax.random.normal(K(1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(K(2), (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v)
    ref = attention_reference(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    B, S, H, Hkv, D = 1, 256, 4, 2, 64
    q = jax.random.normal(K(0), (B, S, H, D))
    k = jax.random.normal(K(1), (B, S, Hkv, D))
    v = jax.random.normal(K(2), (B, S, Hkv, D))
    out = flash_attention(q, k, v, window=window)
    ref = attention_reference(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_prefix_lm():
    """PaliGemma-style bidirectional prefix."""
    B, S, H, Hkv, D = 1, 128, 4, 4, 64
    q = jax.random.normal(K(0), (B, S, H, D))
    k = jax.random.normal(K(1), (B, S, Hkv, D))
    v = jax.random.normal(K(2), (B, S, Hkv, D))
    out = flash_attention(q, k, v, prefix_len=32)
    ref = attention_reference(q, k, v, prefix_len=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 32), (128, 128)])
def test_flash_attention_block_shapes(bq, bk):
    B, S, H, Hkv, D = 1, 256, 2, 2, 64
    q = jax.random.normal(K(3), (B, S, H, D))
    k = jax.random.normal(K(4), (B, S, Hkv, D))
    v = jax.random.normal(K(5), (B, S, Hkv, D))
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = attention_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- #
# paged decode attention
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("B,H,Hkv,D,page,npages", [
    (2, 8, 2, 64, 16, 4),
    (4, 4, 4, 64, 32, 2),
    (1, 8, 1, 128, 64, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_sweep(B, H, Hkv, D, page, npages, dtype):
    P = B * npages + 3                      # spare pages in the pool
    q = jax.random.normal(K(0), (B, H, D), dtype)
    kp = jax.random.normal(K(1), (P, page, Hkv, D), dtype)
    vp = jax.random.normal(K(2), (P, page, Hkv, D), dtype)
    bt = jax.random.permutation(K(3), P)[:B * npages].reshape(B, npages)
    bt = bt.astype(jnp.int32)
    ctx = jax.random.randint(K(4), (B,), 1, page * npages + 1)
    out = paged_decode_attention(q, kp, vp, bt, ctx)
    ref = paged_decode_reference(q.astype(jnp.float32),
                                 kp.astype(jnp.float32),
                                 vp.astype(jnp.float32), bt, ctx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_paged_decode_short_context_skips_pages():
    """ctx=1: only the first page contributes (pl.when skip path)."""
    B, H, Hkv, D, page, npages = 1, 4, 2, 64, 16, 4
    P = B * npages
    q = jax.random.normal(K(0), (B, H, D))
    kp = jax.random.normal(K(1), (P, page, Hkv, D))
    vp = jax.random.normal(K(2), (P, page, Hkv, D))
    bt = jnp.arange(P).reshape(B, npages).astype(jnp.int32)
    ctx = jnp.array([1], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, ctx)
    ref = paged_decode_reference(q, kp, vp, bt, ctx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_dense_wrapper():
    B, S, H, Hkv, D = 3, 128, 8, 4, 64
    q = jax.random.normal(K(0), (B, H, D))
    k = jax.random.normal(K(1), (B, S, Hkv, D))
    v = jax.random.normal(K(2), (B, S, Hkv, D))
    ctx = jnp.array([5, 64, 128], jnp.int32)
    out = decode_attention_dense(q, k, v, ctx, page_size=32)
    from repro.distributed.collectives import decode_attn_reference
    ref = decode_attn_reference(q, k, v, ctx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- #
# wkv6 chunked scan
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("B,S,H,D,chunk", [
    (1, 64, 2, 32, 16),
    (2, 128, 4, 32, 64),
    (1, 96, 2, 64, 32),
    (2, 64, 2, 32, 64),                    # single chunk
])
def test_wkv6_sweep(B, S, H, D, chunk):
    r = jax.random.normal(K(0), (B, S, H, D)) * 0.3
    k = jax.random.normal(K(1), (B, S, H, D)) * 0.3
    v = jax.random.normal(K(2), (B, S, H, D)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(K(3), (B, S, H, D))) * 0.5 + 0.45
    u = jax.random.normal(K(4), (H, D)) * 0.1
    y, s = wkv6(r, k, v, w, u, chunk=chunk)
    yr, sr = wkv6_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=3e-5, atol=3e-5)


def test_wkv6_decay_extremes():
    """w near 0 (full reset) and near 1 (full memory) stay stable."""
    B, S, H, D = 1, 64, 2, 32
    r = jax.random.normal(K(0), (B, S, H, D)) * 0.3
    k = jax.random.normal(K(1), (B, S, H, D)) * 0.3
    v = jax.random.normal(K(2), (B, S, H, D)) * 0.3
    u = jnp.zeros((H, D))
    for wval in (0.01, 0.999):
        w = jnp.full((B, S, H, D), wval)
        y, s = wkv6(r, k, v, w, u, chunk=16)
        yr, sr = wkv6_reference(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
