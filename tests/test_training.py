"""Training substrate: convergence, microbatch equivalence, determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, batch_for_step
from repro.models import model as M
from repro.training import (AdamWConfig, adamw_update, init_adamw, lr_at,
                            make_train_step)

RNG = jax.random.PRNGKey(0)


def setup(dtype="float32"):
    cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                              dtype=dtype)
    params = M.init_params(cfg, RNG)
    return cfg, params


def test_loss_decreases():
    cfg, params = setup()
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=30, warmup_steps=2)
    state = init_adamw(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    batch = batch_for_step(dc, 0)            # overfit one batch
    losses = []
    for _ in range(25):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_microbatch_equivalence():
    """mb-accumulated gradients == full-batch gradients (fp32).

    (Post-Adam params are NOT compared: the first Adam step is ~sign(g),
    which amplifies fp-reordering noise unboundedly.)"""
    from repro.training import make_loss_fn
    cfg, params = setup()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = batch_for_step(dc, 0)
    loss_fn = make_loss_fn(cfg)
    full_loss, full_grads = jax.value_and_grad(loss_fn)(params, batch)
    for mb in (2, 4):
        accs = None
        losses = []
        for i in range(mb):
            sl = {k: v[i * (8 // mb):(i + 1) * (8 // mb)]
                  for k, v in batch.items()}
            l, g = jax.value_and_grad(loss_fn)(params, sl)
            losses.append(float(l))
            accs = g if accs is None else jax.tree.map(
                lambda a, b: a + b, accs, g)
        accs = jax.tree.map(lambda a: a / mb, accs)
        np.testing.assert_allclose(np.mean(losses), float(full_loss),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(accs), jax.tree.leaves(full_grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-6)
    # the jitted train_step agrees on the reported loss for any mb
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    for mb in (1, 2):
        s = init_adamw(params)
        step = jax.jit(make_train_step(cfg, opt_cfg, microbatches=mb))
        _, _, m = step(params, s, batch)
        np.testing.assert_allclose(float(m["loss"]), float(full_loss),
                                   rtol=1e-4)


def test_grad_clip_bounds_update():
    cfg, params = setup()
    opt_cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0,
                          total_steps=10, warmup_steps=0, schedule="constant")
    state = init_adamw(params)
    big = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 1e6, params)
    p2, s2, m = adamw_update(opt_cfg, big, state, params)
    assert float(m["grad_norm"]) > 1e6
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta < 1.1  # lr * normalized step bounded by adam scale


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine", min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == 1.0
    end = float(lr_at(cfg, jnp.asarray(110)))
    assert abs(end - 0.1) < 1e-6


def test_data_pipeline_deterministic_and_sharded():
    dc = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    a = batch_for_step(dc, 5)
    b = batch_for_step(dc, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(dc, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards are disjoint deterministic slices
    s0 = batch_for_step(dc, 5, shard=0, num_shards=2)
    s1 = batch_for_step(dc, 5, shard=1, num_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
