"""Break-even cache replacement + host demotion tier (PR 5).

Covers: the pluggable page-pool ``ReplacementPolicy`` (lru /
break_even / belady-oracle), the §6 five-minute-rule fixes
(``ValueError`` on bad input, explicit ``mode="swap"``), the reclaim
regression (evicting a still-mapped page must never burn a registry
entry without freeing a page), the duplicate-key registry guard, the
host demotion/promotion loop (engine), simulator-vs-engine parity for
the demotion/promotion charging, and token-identical outputs across
policies on the shared-prefix workloads.
"""
import dataclasses

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import (BeladyOraclePolicy, BreakEvenPolicy, LRUPolicy,
                        OutOfPagesError, PagedAllocator, PrefixCache,
                        PrefixTierSim, TheoreticalCostModel,
                        belady_future_from_requests, get_hardware,
                        make_replacement_policy, make_scheduler, simulate)
from repro.core.five_minute_rule import break_even_interval
from repro.data.workloads import shared_prefix, zipf_shared_prefix
from repro.models import model as M
from repro.serving import Engine, EngineConfig
from repro.serving.swap_store import KVSwapStore, SwapStoreFullError

RNG = jax.random.PRNGKey(0)
_CFG_CACHE = {}


def model_and_params(name="tinyllama-1.1b"):
    if name not in _CFG_CACHE:
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        _CFG_CACHE[name] = (cfg, M.init_params(cfg, RNG))
    return _CFG_CACHE[name]


def cost_model():
    cfg, _ = model_and_params()
    return TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))


def build_engine(M_kv=256, *, policy="lru", demotion=False, nslots=4,
                 page_size=8, swap_bytes=None, async_swap=True):
    cfg, params = model_and_params()
    sched = make_scheduler("vllm", M_kv, S=512, replacement="srf")
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=nslots, cache_len=64, chunk=16,
                              plane="paged", page_size=page_size,
                              cache_policy=policy, cache_demotion=demotion,
                              swap_bytes=swap_bytes,
                              async_swap=async_swap),
                 cost_model=cost_model())
    return cfg, params, eng


# --------------------------------------------------------------------- #
# five-minute rule satellites
# --------------------------------------------------------------------- #

def test_break_even_rejects_nonpositive_n():
    cm = cost_model()
    for bad in (0, -1, -100):
        with pytest.raises(ValueError, match="n_kvs"):
            break_even_interval(cm, bad, 1000)


def test_break_even_mode_swap_and_unknown():
    cm = cost_model()
    be = break_even_interval(cm, 64, 1000, mode="swap")
    # in swap mode the PRIMARY interval is the swap-priced one
    assert be.interval == be.interval_swap
    assert be.t_recom == cm.swap_time(64)
    full = break_even_interval(cm, 64, 1000, mode="full")
    kvp = break_even_interval(cm, 64, 1000, mode="kv_projection")
    assert full.t_recom >= kvp.t_recom          # refill >= projection-only
    # every mode still reports the swap spectrum column
    assert full.interval_swap == kvp.interval_swap == be.interval_swap
    with pytest.raises(ValueError, match="mode"):
        break_even_interval(cm, 64, 1000, mode="bogus")


# --------------------------------------------------------------------- #
# registry guards (satellites)
# --------------------------------------------------------------------- #

def test_prefix_insert_duplicate_key_raises():
    """REGRESSION: the duplicate-key guard was a bare ``assert`` —
    stripped under ``python -O`` a re-registered key silently leaked the
    old page's pin.  It must be a real exception."""
    pc = PrefixCache()
    pc.insert(7, 0, (1, 2), n_kvs=2)
    with pytest.raises(ValueError, match="already registered"):
        pc.insert(7, 1, (1, 2), n_kvs=2)
    # the original entry is untouched
    assert pc.get(7) == 0


def test_reclaim_never_burns_entry_without_freeing():
    """REGRESSION (the PR's headline bugfix): under heavy sharing the
    old ``_take`` popped LRU registry entries whose pages live tables
    still mapped — destroying the entry, counting it reclaimed, and
    freeing NOTHING, stripping the whole prefix cache for zero pages.
    Now still-mapped candidates are skipped and ``reclaimed`` counts
    only pages actually returned to the free list."""
    a = PagedAllocator(num_pages=6, page_size=2)
    keys = PrefixCache.chain_keys([1, 2, 3, 4, 5, 6, 7, 8], 2)
    a.allocate(0, 8)                    # 4 pages
    a.register_prefix(0, keys)
    pages = a.lookup_prefix(keys)
    a.share(1, pages, 8)                # rid 1 maps ALL cached pages
    a.free(0)
    # free: 2 pages; every registry page is still table-mapped by rid 1.
    # old behaviour: evict all 4 entries, free 0 pages, then raise with
    # the registry burned.  new: skip all, raise, registry intact.
    with pytest.raises(OutOfPagesError):
        a.allocate(2, 6)                # needs 3 pages
    assert len(a.prefix_cache) == 4     # nothing burned
    assert a.stats["reclaimed"] == 0
    # one skip per blocked NODE per sweep — the four pages form a
    # single trie node whose (blocked) tail ends the node's sweep
    assert a.stats["reclaim_skipped"] >= 1
    assert a.lookup_prefix(keys) == pages   # hits still served
    a.check_invariants()
    # once the sharer lets go the pages become pinned-only and reclaim
    # works normally — entries evicted if and only if pages freed, and
    # only as many as the deficit needs (2 free + 1 evicted = 3 pages)
    a.free(1)
    a.allocate(2, 6)
    assert a.stats["reclaimed"] == 1 and len(a.prefix_cache) == 3
    a.check_invariants()


# --------------------------------------------------------------------- #
# policy units
# --------------------------------------------------------------------- #

def test_lru_policy_order():
    p = LRUPolicy()
    p.record_insert(1, 2, 0.0)
    p.record_insert(2, 2, 1.0)
    p.record_insert(3, 2, 2.0)
    assert p.eviction_order(3.0) == [1, 2, 3]
    p.record_hit(1, 3.0)                       # refresh 1
    assert p.eviction_order(4.0) == [2, 3, 1]


def test_break_even_policy_long_prefix_evicts_sooner():
    """Eq. 5: the break-even interval FALLS with chain depth, so at
    equal idle time the LONG prefix ranks first for eviction — and a
    recently-hit short entry outlives a colder long one even when the
    long one is more recent (scan resistance LRU lacks)."""
    cm = cost_model()
    p = BreakEvenPolicy(cm, M=100_000)
    p.record_insert(10, 16, 0.0)     # short prefix (2 pages of 8)
    p.record_insert(11, 512, 0.0)    # long prefix, same recency
    order = p.eviction_order(1.0)
    assert order[0] == 11, order     # long evicts first
    # hot short survives a newer cold long entry: idle/B(n) dominates
    p2 = BreakEvenPolicy(cm, M=100_000)
    p2.record_insert(1, 16, 0.0)
    p2.record_hit(1, 9.0)            # hot: hit just before the decision
    p2.record_insert(2, 2048, 8.0)   # cold scan entry, MORE recent insert
    lru = LRUPolicy()
    lru.record_insert(1, 16, 0.0)
    lru.record_insert(2, 2048, 8.0)
    lru.record_hit(1, 9.0)
    assert lru.eviction_order(10.0)[0] == 2    # LRU agrees here...
    assert p2.eviction_order(10.0)[0] == 2
    # ...but when the hot entry's last hit is slightly OLDER than the
    # scan entry's insert, LRU evicts the hot one while break-even still
    # keeps it: idle_hot/B(16) = 2/B(16) < 1/B(2048) = idle_cold/B(2048)
    # because B(16) ≈ 3x B(2048) (weight-load amortizes with depth)
    p3 = BreakEvenPolicy(cm, M=100_000)
    p3.record_insert(1, 16, 0.0)
    p3.record_hit(1, 8.0)
    p3.record_insert(2, 2048, 9.0)
    lru3 = LRUPolicy()
    lru3.record_insert(1, 16, 0.0)
    lru3.record_hit(1, 8.0)
    lru3.record_insert(2, 2048, 9.0)
    assert lru3.eviction_order(10.0)[0] == 1   # recency-blind to cost
    assert p3.eviction_order(10.0)[0] == 2     # five-minute rule keeps hot


def test_belady_oracle_policy():
    p = BeladyOraclePolicy({1: [5.0, 20.0], 2: [8.0], 3: []})
    p.record_insert(1, 8, 0.0)
    p.record_insert(2, 8, 0.0)
    p.record_insert(3, 8, 0.0)
    # at t=0: next accesses are 5.0 (1), 8.0 (2), never (3)
    assert p.eviction_order(0.0) == [3, 2, 1]
    # after t=8 request for key 2 passed: 2 is never used again either;
    # ties (both inf) break by insertion order
    assert p.eviction_order(9.0) == [2, 3, 1]


def test_make_replacement_policy_factory():
    assert isinstance(make_replacement_policy("lru"), LRUPolicy)
    assert isinstance(
        make_replacement_policy("break_even", cost_model=cost_model(),
                                M=100), BreakEvenPolicy)
    assert isinstance(make_replacement_policy("belady-oracle"),
                      BeladyOraclePolicy)
    with pytest.raises(ValueError):
        make_replacement_policy("break_even")    # needs cost model + M
    with pytest.raises(ValueError):
        make_replacement_policy("mru")


def test_belady_future_from_requests():
    reqs = shared_prefix(n=4, input_len=16, prefix_frac=0.5,
                         output_len=2, vocab=50, stagger=1.0, seed=0)
    fut = belady_future_from_requests(reqs, page_size=8)
    shared_key = PrefixCache.chain_keys(reqs[0].prompt, 8)[0]
    assert len(fut[shared_key]) == 4           # every request shares page 0
    assert fut[shared_key] == sorted(fut[shared_key])


# --------------------------------------------------------------------- #
# churn property test (satellite): reclaim correctness under load
# --------------------------------------------------------------------- #

@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 5), st.integers(1, 9),
                              st.integers(0, 4)), max_size=80),
       policy_i=st.integers(0, 2))
def test_property_churn_reclaim_frees_or_skips(ops, policy_i):
    """alloc/share/register/reclaim/free churn under a seeded schedule,
    ``check_invariants`` after every op, under all three policies.  The
    eviction hook observes every reclaim: an evicted page must be
    pinned-only (refcount 1) at eviction time — i.e. reclaim NEVER
    evicts a still-mapped page — and the reclaimed counter must equal
    the number of hook firings (every eviction freed a page)."""
    policy = [lambda: None,
              lambda: make_replacement_policy(
                  "break_even", cost_model=cost_model(), M=40),
              lambda: make_replacement_policy("belady")][policy_i]()
    evicted = []
    a = PagedAllocator(num_pages=10, page_size=4, policy=policy,
                       on_evict=lambda k, pg, t, n: evicted.append(pg))
    orig_on_evict = a.on_evict

    def checked_evict(key, page, tokens, n_kvs):
        # the fix's contract: eviction implies the pin is the ONLY ref
        assert a._refs[page] == 1, (page, a._refs[page])
        orig_on_evict(key, page, tokens, n_kvs)

    a.on_evict = checked_evict
    a.now = 0.0
    for step, (rid, tokens, op) in enumerate(ops):
        a.now = float(step)
        if op == 0:
            a.free(rid)
        elif op == 1 and a.has(rid):
            a.free_tail(rid, 1)
        elif op == 2 and a.has(rid):
            a.register_prefix(rid, [hash((rid, i, len(a.table(rid).pages)))
                                    for i in range(len(a.table(rid).pages))])
        elif op == 3 and len(a.prefix_cache) and not a.has(rid + 10):
            # map a cached page into a fresh table (sharing pressure)
            key = a.prefix_cache.eviction_order(a.now)[0]
            page, _, _ = a.prefix_cache.entry(key)
            a.share(rid + 10, [page], 4)
        else:
            try:
                a.allocate(rid, tokens)
            except OutOfPagesError:
                pass
        a.check_invariants()
        assert a.stats["reclaimed"] == len(evicted)
    for rid in range(16):
        a.free(rid)
    a.check_invariants()
    # drain surviving pins through the reclaim path: with no tables
    # left, every cached page is pinned-only and must free
    try:
        a.allocate(99, 40)
        assert len(a.prefix_cache) == 0
        a.free(99)
    except OutOfPagesError:
        pass
    a.check_invariants()
    assert a.stats["reclaimed"] == len(evicted)


# --------------------------------------------------------------------- #
# host demotion tier (swap store unit + engine loop)
# --------------------------------------------------------------------- #

def test_attach_host_hit_under_device_collided_key_is_a_miss():
    """If a chain key is device-registered under DIFFERENT tokens (a
    64-bit hash collision) while the host tier holds the matching
    entry, the two-tier attach must treat it as a miss — promoting
    would re-insert the occupied key and crash."""
    from repro.core import attach_prefix_run

    a = PagedAllocator(num_pages=4, page_size=2)
    store = KVSwapStore()
    key = PrefixCache.chain_keys([1, 2], 2)[0]
    # device registry: key occupied by ANOTHER prompt's page (collision)
    a.allocate(0, 2)
    a.register_prefix(0, [key], [(7, 8)])
    # host tier: the matching snapshot under the same key
    store.put_prefix(key, (1, 2), 2, None, nbytes=4)
    attached, promoted = attach_prefix_run(a, 5, [key], [(1, 2)],
                                           host_tier=store)
    assert (attached, promoted) == (0, 0)      # miss, no crash
    assert store.has_prefix(key)               # host copy untouched
    assert not a.has(5)
    a.check_invariants()


def test_swap_store_prefix_entries():
    store = KVSwapStore(capacity_bytes=100)
    e = store.put_prefix(5, (1, 2), 16, None, nbytes=60)
    assert e.nbytes == 60 and store.nbytes == 60
    assert store.has_prefix(5) and store.num_prefix_entries == 1
    assert len(store) == 0                     # not suspend bookkeeping
    with pytest.raises(ValueError):
        store.put_prefix(5, (1, 2), 16, None, nbytes=1)
    with pytest.raises(SwapStoreFullError):
        store.put_prefix(6, (3, 4), 16, None, nbytes=60)
    # token verification: a hash collision is a miss
    assert store.peek_prefix(5, (9, 9)) is None
    assert store.peek_prefix(5, (1, 2)) is e
    store.check_invariants()
    got = store.pop_prefix(5)
    assert got is e and store.nbytes == 0
    with pytest.raises(KeyError):
        store.pop_prefix(5)
    store.check_invariants()


def test_engine_demotion_promotes_back_with_identical_tokens():
    """Evicted prefix pages land in the host tier and are promoted back
    on the next registry hit — charged swap_time in virtual time —
    with outputs identical to the no-demotion run."""
    wl_kw = dict(n=24, num_groups=6, page_size=8, seed=3)

    def run(policy, demotion):
        cfg, _, eng = build_engine(policy=policy, demotion=demotion)
        res = eng.run(zipf_shared_prefix(vocab=cfg.vocab_size, **wl_kw))
        return res, eng

    res_off, eng_off = run("break_even", False)
    res_on, eng_on = run("break_even", True)
    assert res_on.outputs == res_off.outputs
    assert eng_on.swap_stats["demotions"] > 0
    assert eng_on.swap_stats["promotions"] > 0
    assert eng_on.swap_stats["kv_promoted"] % 8 == 0
    # promotion = more shared tokens than discarding evictions
    assert eng_on.allocator.stats["prefix_shared_tokens"] \
        > eng_off.allocator.stats["prefix_shared_tokens"]
    # promotions were charged host-link time: virtual makespan grows
    assert res_on.metrics.makespan > res_off.metrics.makespan
    # host tier may legitimately hold demoted prefixes at end of run;
    # suspend bookkeeping must still be clean
    assert len(eng_on.swap_store) == 0


def test_async_demotion_parity_with_sync():
    """The ``async_swap`` demotion path (device-side page gather +
    ``copy_to_host_async`` + drain-boundary finalize) must be
    behaviourally identical to the synchronous ``device_get`` path it
    replaces: same outputs, same demotion/promotion accounting, same
    virtual-time charges, and byte-identical host-tier snapshots at end
    of run — only the wall-clock placement of the D2H copy differs."""
    wl_kw = dict(n=24, num_groups=6, page_size=8, seed=3)

    def run(async_swap):
        cfg, _, eng = build_engine(policy="break_even", demotion=True,
                                   async_swap=async_swap)
        res = eng.run(zipf_shared_prefix(vocab=cfg.vocab_size, **wl_kw))
        return res, eng

    res_s, eng_s = run(False)
    res_a, eng_a = run(True)
    assert res_a.outputs == res_s.outputs
    for k in ("demotions", "promotions", "kv_demoted", "kv_promoted",
              "demote_drops"):
        assert eng_a.swap_stats[k] == eng_s.swap_stats[k], k
    assert eng_a.swap_stats["demotions"] > 0
    # identical virtual-time charging => identical makespans
    assert res_a.metrics.makespan == res_s.metrics.makespan
    # every in-flight transfer was finalized; surviving host-tier
    # entries hold host arrays with the same bytes as the sync run
    assert not eng_a._pending_demotes
    assert eng_a.swap_store.num_prefix_entries \
        == eng_s.swap_store.num_prefix_entries
    for key, ent_a in eng_a.swap_store._prefixes.items():
        ent_s = eng_s.swap_store._prefixes[key]
        assert ent_a.tokens == ent_s.tokens
        assert isinstance(ent_a.kv["k"], np.ndarray), \
            "async demotion left a device array in the host tier"
        np.testing.assert_array_equal(ent_a.kv["k"], ent_s.kv["k"])
        np.testing.assert_array_equal(ent_a.kv["v"], ent_s.kv["v"])
        assert ent_a.nbytes == ent_s.nbytes


def test_engine_demotion_store_full_falls_back():
    """A full host store drops demotions (pages fall back to recompute
    on the next miss) without corrupting outputs."""
    cfg, _, eng_ref = build_engine(policy="break_even", demotion=False)
    wl = zipf_shared_prefix(n=16, num_groups=6, page_size=8, seed=1,
                            vocab=cfg.vocab_size)
    res_ref = eng_ref.run(wl)
    cfg, _, eng = build_engine(policy="break_even", demotion=True,
                               swap_bytes=1)   # nothing fits
    wl2 = zipf_shared_prefix(n=16, num_groups=6, page_size=8, seed=1,
                             vocab=cfg.vocab_size)
    res = eng.run(wl2)
    assert res.outputs == res_ref.outputs
    assert eng.swap_stats["demotions"] == 0
    assert eng.swap_stats["demote_drops"] > 0
    assert eng.swap_stats["promotions"] == 0


# --------------------------------------------------------------------- #
# simulator-vs-engine parity + cross-policy token identity (heavy)
# --------------------------------------------------------------------- #

def _page_nbytes(cfg, page_size):
    import jax.numpy as jnp
    return 2 * cfg.num_layers * page_size * cfg.num_kv_heads \
        * cfg.head_dim_ * jnp.dtype(cfg.dtype).itemsize


@pytest.mark.slow
@pytest.mark.parametrize("policy,demotion", [("lru", True),
                                             ("break_even", True),
                                             ("break_even", False)])
def test_sim_engine_demotion_promotion_parity(policy, demotion):
    """The simulator's PrefixTierSim shadow must agree with the paged
    engine batch-for-batch: same demotion/promotion/reclaim counts, same
    prefix hits, and the same virtual time (the swap_time charges land
    in the same batches)."""
    wl_kw = dict(n=24, num_groups=6, page_size=8, seed=3)
    cfg, _, eng = build_engine(policy=policy, demotion=demotion)
    res = eng.run(zipf_shared_prefix(vocab=cfg.vocab_size, **wl_kw))

    cm = cost_model()
    sched = make_scheduler("vllm", 256, S=512, replacement="srf",
                           page_size=8, cache_policy=policy,
                           cache_demotion=demotion)
    sched.cfg.max_running = 4                  # engine slot cap
    shadow = PrefixTierSim(sched.cfg, cm,
                           page_nbytes=_page_nbytes(cfg, 8))
    sim = simulate(sched, zipf_shared_prefix(vocab=cfg.vocab_size,
                                             **wl_kw),
                   cm, prefix_sim=shadow)

    assert sim.prefix_stats["demotions"] == eng.swap_stats["demotions"]
    assert sim.prefix_stats["promotions"] == eng.swap_stats["promotions"]
    assert sim.prefix_stats["kv_promoted"] == eng.swap_stats["kv_promoted"]
    assert sim.prefix_stats["demote_drops"] == eng.swap_stats["demote_drops"]
    for key in ("prefix_hits", "prefix_shared_tokens", "reclaimed",
                "reclaim_skipped", "cow_copies"):
        assert sim.prefix_stats[key] == eng.allocator.stats[key], key
    assert sim.makespan == pytest.approx(res.metrics.makespan, rel=1e-9)
    # charges landed batch-for-batch, not just in total
    eng_swaps = [b.swap_s for b in res.metrics.batches]
    sim_swaps = [b.swap_s for b in sim.batches]
    assert len(eng_swaps) == len(sim_swaps)
    assert eng_swaps == pytest.approx(sim_swaps, rel=1e-9)


@pytest.mark.slow
def test_outputs_identical_across_policies_shared_prefix():
    """Replacement policy and demotion tier must never change generated
    tokens on the shared-prefix workloads (satellite contract)."""
    outs = {}
    for label, (policy, demotion) in {
            "lru": ("lru", False), "be": ("break_even", False),
            "bed": ("break_even", True)}.items():
        cfg, _, eng = build_engine(M_kv=200, policy=policy,
                                   demotion=demotion)
        wl = shared_prefix(n=10, input_len=32, prefix_frac=0.75,
                           output_len=6, vocab=cfg.vocab_size,
                           stagger=1e-6, seed=5)
        outs[label] = eng.run(wl).outputs
    assert outs["lru"] == outs["be"] == outs["bed"]
