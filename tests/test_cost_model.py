"""Cost models (paper §4): Eq. 1-3 values, monotonicity, linear fit."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.cost_model import (BatchSpec, LinearCostModel,
                                   TheoreticalCostModel, attention_flops_rw,
                                   calibrated_cost_model, fit_linear_model,
                                   get_hardware, group_labels_from_theory,
                                   profile_synthetic)
from repro.core.five_minute_rule import break_even_table
from repro.core.slo import balanced_intensity, max_m_for_threshold, pareto_curve

CFG = get_config("llama2-7b")
HW = get_hardware("a100")


def test_attention_eq1_eq2_exact():
    """Eq. 1: FLOPs = 4c(c+m)HN_Q; Eq. 2 RW with the ceil(c/H) KV term."""
    H, nq, nkv = CFG.head_dim_, CFG.num_heads, CFG.num_kv_heads
    c, m = 256, 512
    fl, rw = attention_flops_rw(c, m, CFG, tp=1, bytes_per_el=2)
    assert fl == 4 * c * (c + m) * H * nq
    expect_rw = (2 * c * H * nq + 2 * c * (c + m) * nq
                 + 2 * int(np.ceil(c / H)) * (c + m) * H * nkv) * 2
    assert rw == expect_rw


def test_batch_time_monotone_in_c_and_m():
    cm = TheoreticalCostModel(CFG, HW)
    base = cm.batch_time(BatchSpec(prefills=[(128, 0)], decodes=[(1, 256)]))
    assert cm.batch_time(BatchSpec(prefills=[(256, 0)],
                                   decodes=[(1, 256)])) > base
    assert cm.batch_time(BatchSpec(prefills=[(128, 0)],
                                   decodes=[(1, 512)])) > base
    assert cm.batch_time(BatchSpec()) == 0.0


def test_decode_attention_bottlenecked_by_m():
    """§5.2: decode attention time is linear in m (KV reads)."""
    cm = TheoreticalCostModel(CFG, HW)
    t1 = cm.op_times(BatchSpec(decodes=[(1, 1000)]))["attn_decode"]
    t2 = cm.op_times(BatchSpec(decodes=[(1, 2000)]))["attn_decode"]
    assert t2 / t1 == pytest.approx(2.0, rel=0.05)


def test_attention_is_memory_bound_even_for_prefill():
    """§5.2 Remark: attention points sit in the memory-bound region."""
    cm = TheoreticalCostModel(CFG, HW)
    for c, m in [(128, 0), (1024, 0), (4096, 0)]:
        fl, rw = attention_flops_rw(c, m, CFG, 1, 2)
        intensity = fl / rw
        turning = HW.flops / HW.hbm_bw
        assert intensity < turning  # memory-bound on A100


def test_intensity_convergence_formula():
    """§5.2: intensity -> 2/(1/H + ceil(c/H)N_KV/(cN_Q)); prefill ~ H=128,
    decode ~ 2."""
    assert balanced_intensity(128, 32, 32, 4096) == pytest.approx(128, rel=0.05)
    assert balanced_intensity(128, 32, 32, 1) == pytest.approx(2, rel=0.05)


def test_matmul_compute_bound_only_for_large_c():
    """§5.2: matmuls become compute-bound once c amortizes weight loads."""
    cm = TheoreticalCostModel(CFG, HW)
    small = cm.batch_terms(BatchSpec(prefills=[(8, 0)]))
    large = cm.batch_terms(BatchSpec(prefills=[(8192, 0)]))
    assert small["memory_s"] > small["compute_s"]
    assert large["compute_s"] > large["memory_s"]


def test_linear_fit_recovers_theory():
    """Fit on noisy synthetic profiles -> <15% median relative error
    (paper reports 6% avg / 12% max for its linear models)."""
    samples = profile_synthetic(CFG, HW, n=300, noise=0.02)
    lm = fit_linear_model(samples)
    truth = TheoreticalCostModel(CFG, HW, flops_eff=0.6, bw_eff=0.75,
                                 attn_bw_eff=0.25)
    errs = []
    for spec, _ in profile_synthetic(CFG, HW, seed=1, n=60, noise=0.0):
        t = truth.batch_time(spec)
        p = lm.batch_time(spec)
        errs.append(abs(p - t) / t)
    assert np.median(errs) < 0.15


def test_linear_model_serialization():
    lm = calibrated_cost_model(CFG, HW)
    lm2 = LinearCostModel.from_dict(lm.to_dict())
    spec = BatchSpec(prefills=[(64, 0)], decodes=[(1, 100)] * 4)
    assert lm.batch_time(spec) == lm2.batch_time(spec)


@settings(max_examples=50, deadline=None)
@given(c=st.integers(1, 4096), m=st.integers(0, 8192),
       b=st.integers(1, 64))
def test_property_linear_model_monotone(c, m, b):
    lm = calibrated_cost_model(CFG, HW)
    t0 = lm.batch_time(BatchSpec(decodes=[(1, m)] * b))
    t1 = lm.batch_time(BatchSpec(decodes=[(1, m + 1)] * b))
    t2 = lm.batch_time(BatchSpec(prefills=[(c, m)], decodes=[(1, m)] * b))
    assert t1 >= t0 - 1e-12
    assert t2 >= t0 - 1e-12


def test_slo_pareto_monotone_and_feasible():
    """§5.3: the (c, m) pareto of batch time == threshold; m falls as c
    grows, and every returned point respects the threshold."""
    cm = TheoreticalCostModel(CFG, HW, flops_eff=0.6, bw_eff=0.75,
                              attn_bw_eff=0.25)
    pts = pareto_curve(cm, num_prefill=8, num_decode=32, threshold=1.0,
                       cs=(1, 64, 1024, 4096))
    assert len(pts) >= 2
    ms = [p.m for p in pts]
    assert all(a >= b for a, b in zip(ms, ms[1:]))   # m falls with c
    for p in pts:
        assert p.batch_time <= 1.0 + 1e-6


def test_five_minute_rule_interval_shrinks_with_length():
    """§6: longer requests -> smaller break-even residency interval; the
    paper reports [0.33 s, 130 s] on H100 with M=100K."""
    cm = TheoreticalCostModel(get_config("llama2-7b"), get_hardware("h100"),
                              flops_eff=0.6, bw_eff=0.75, attn_bw_eff=0.25)
    table = break_even_table(cm, M=100_000, ns=(1, 64, 4095))
    ivals = [b.interval for b in table]
    assert all(a > b for a, b in zip(ivals, ivals[1:]))
    assert 0.05 < ivals[-1] < 10.0        # seconds-scale for long requests
    assert 10.0 < ivals[0] < 1000.0       # minutes-scale for 1 KV


def test_swap_vs_recompute_turning_point():
    """§5.4/Fig 8: with activation-cached KV rebuild, swapping wins only
    below a small turning point (paper: < ~100 KVs); above it the
    weight-load bias is amortized and recompute wins."""
    cm = TheoreticalCostModel(CFG, HW, flops_eff=0.6, bw_eff=0.75,
                              attn_bw_eff=0.25)
    assert cm.swap_time(8) < cm.kv_projection_time(8)      # tiny: swap wins
    assert cm.kv_projection_time(65_536) < cm.swap_time(65_536)
    # turning point is small relative to the cache size M=100K
    lo, hi = 1, 100_000
    while lo < hi:
        mid = (lo + hi) // 2
        if cm.kv_projection_time(mid) < cm.swap_time(mid):
            hi = mid
        else:
            lo = mid + 1
    assert lo < 5_000
    # the FULL refill (preemption cost) keeps growing superlinearly —
    # this is why preempting long requests is expensive (§7)
    assert (cm.recompute_time(4096) / 4096
            > 1.5 * cm.recompute_time(256) / 256)
