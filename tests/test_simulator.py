"""Simulator (InferMax loop): termination, conservation, paper phenomena."""
import pytest

from repro.configs import get_config
from repro.core.cost_model import TheoreticalCostModel, get_hardware
from repro.core.simulator import fresh_requests, run_sim

CFG = get_config("llama2-7b")
CM = TheoreticalCostModel(CFG, get_hardware("a100"), flops_eff=0.6,
                          bw_eff=0.75, attn_bw_eff=0.25)


def offline(n, I, O):
    return fresh_requests([(I, O, 0.0)] * n)


def test_all_requests_finish_and_conserve_tokens():
    reqs = offline(32, 16, 8)
    res = run_sim("vllm", reqs, CM, M=1000)
    assert all(r.finished for r in reqs)
    total = sum(r.generated for r in reqs)
    assert total == 32 * 8
    assert res.latency > 0 and res.tps > 0


def test_low_contention_no_preemption():
    """App. A: W=32 triggers no evictions."""
    res = run_sim("vllm", offline(32, 64, 32), CM, M=100_000)
    assert res.num_preemptions == 0


def test_preemption_helps_under_tight_memory():
    """§5.7/Fig 12: at M=100, non-PF beats PF by ~2x (I small)."""
    pf = run_sim("sarathi_pf", offline(256, 8, 32), CM, M=100)
    npf = run_sim("sarathi", offline(256, 8, 32), CM, M=100)
    assert npf.num_preemptions > 0
    assert pf.latency / npf.latency > 1.4


def test_preemption_hurts_with_ample_memory():
    """§5.7/Fig 12: at M=10K the PF schedule is no worse."""
    pf = run_sim("vllm_pf", offline(256, 8, 32), CM, M=10_000)
    npf = run_sim("vllm", offline(256, 8, 32), CM, M=10_000)
    assert pf.latency <= npf.latency * 1.02


def test_pf_higher_ttft_lower_tpot():
    """§5.6/Fig 11: PF trades (much) higher TTFT for lower TPOT."""
    pf = run_sim("vllm_pf", offline(128, 8, 64), CM, M=2_000)
    npf = run_sim("vllm", offline(128, 8, 64), CM, M=2_000)
    assert pf.max_ttft > npf.max_ttft
    assert pf.mean_tpot < npf.mean_tpot


def test_effective_batch_size_approx_m_over_i_plus_o():
    """§5.6 Remark: PF average batch size ~= M/(I+O)."""
    I, O, M = 32, 96, 4_000
    res = run_sim("vllm_pf", offline(256, I, O), CM, M=M)
    expected = M / (I + O)
    assert res.mean_batch_size == pytest.approx(expected, rel=0.35)


def test_srf_no_regression_vs_nrf():
    """§8: SRF never loses to NRF (and LRF is strictly worse)."""
    import numpy as np
    rng = np.random.default_rng(0)
    spec = []
    for i in range(128):
        I = int(rng.choice([8, 16, 512, 1024]))
        O = int(rng.choice([16, 256]))
        spec.append((I, O, 0.0))
    out = {}
    for repl in ("nrf", "srf", "lrf"):
        out[repl] = run_sim("vllm", fresh_requests(spec), CM, M=8_000,
                            replacement=repl)
    assert out["srf"].latency <= out["nrf"].latency * 1.01
    assert out["lrf"].latency > out["srf"].latency


def test_srf_fairness_preserved():
    """§8/Fig 15: SRF still completes earlier-arrived requests first
    (rank correlation between arrival and finish stays positive)."""
    import numpy as np
    rng = np.random.default_rng(1)
    spec = [(int(rng.choice([8, 512])), 32, float(i) * 1e-4)
            for i in range(64)]
    reqs = fresh_requests(spec)
    run_sim("vllm", reqs, CM, M=2_000, replacement="srf")
    arrivals = np.array([r.arrival for r in reqs])
    finishes = np.array([r.finish_time for r in reqs])
    rho = np.corrcoef(np.argsort(np.argsort(arrivals)),
                      np.argsort(np.argsort(finishes)))[0, 1]
    assert rho > 0.3


def test_online_arrivals_idle_gap():
    reqs = fresh_requests([(8, 4, 0.0), (8, 4, 100.0)])
    res = run_sim("vllm", reqs, CM, M=1000)
    assert reqs[1].finish_time > 100.0
    assert reqs[0].finish_time < 1.0


def test_histogram_gate_reduces_preemptions():
    """SRF+Hist defers long-output requests -> fewer preemptions."""
    spec = [(8, 256, float(i)) for i in range(64)]
    base = run_sim("vllm", fresh_requests(spec), CM, M=1_500,
                   replacement="srf")
    hist = run_sim("vllm", fresh_requests(spec), CM, M=1_500,
                   replacement="srf", use_histogram=True)
    assert hist.num_preemptions <= base.num_preemptions


# --- §5.4 swap-aware simulation -------------------------------------- #

def test_sim_swap_charges_host_link_and_skips_refill():
    """Swap mode restores suspended KVs instead of re-prefilling: the
    simulator must count swaps, charge swap_time in virtual time, and
    still finish every request."""
    reqs_r = offline(256, 8, 32)
    reqs_s = offline(256, 8, 32)
    rec = run_sim("vllm", reqs_r, CM, M=300)
    swp = run_sim("vllm", reqs_s, CM, M=300, preempt_mode="swap")
    assert rec.num_preemptions > 0 and rec.num_swaps == 0
    assert swp.num_swaps > 0
    assert all(r.finished for r in reqs_s)
    charged = sum(b.swap_s for b in swp.batches)
    ins = sum(b.swapped_in for b in swp.batches)
    outs = sum(b.swapped_out for b in swp.batches)
    assert charged > 0.0 and ins == outs > 0
    # A100 host link is fast vs recomputing the whole context: restoring
    # beats refilling, so the swap schedule cannot be slower by much
    assert swp.latency <= rec.latency * 1.05


def test_sim_auto_matches_best_fixed_mode():
    """'auto' picks per-victim via the cost model; it should never lose
    to BOTH fixed policies on the same workload."""
    lat = {}
    for mode in ("recompute", "swap", "auto"):
        reqs = offline(256, 8, 32)
        lat[mode] = run_sim("vllm", reqs, CM, M=300,
                            preempt_mode=mode).latency
    assert lat["auto"] <= max(lat["recompute"], lat["swap"]) * (1 + 1e-9)
