"""Checkpoint store + manager: roundtrip, corruption, retention, resume."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.store as store
from repro.checkpoint import CheckpointManager


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "b": jax.random.normal(k, (16,), jnp.bfloat16),   # ml_dtypes path
        "nested": {"s": jnp.asarray(3, jnp.int32)},
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_roundtrip_bf16(tmp_path):
    t = tree()
    store.save(t, str(tmp_path), 7)
    restored, step = store.restore(t, str(tmp_path))
    assert step == 7
    assert_tree_equal(t, restored)


def test_latest_and_retention(tmp_path):
    t = tree()
    for s in (1, 2, 3, 4):
        store.save(t, str(tmp_path), s)
    assert store.list_steps(str(tmp_path)) == [1, 2, 3, 4]
    store.retain(str(tmp_path), keep=2)
    assert store.list_steps(str(tmp_path)) == [3, 4]
    _, step = store.restore(t, str(tmp_path))
    assert step == 4


def test_corruption_detected_and_skipped(tmp_path):
    t = tree()
    mgr = CheckpointManager(str(tmp_path), interval=1, keep=5,
                            async_write=False)
    mgr.save(t, 1, block=True)
    t2 = tree(seed=1)
    mgr.save(t2, 2, block=True)
    # corrupt the newest checkpoint's payload
    path = sorted(glob.glob(str(tmp_path) + "/step_*"))[-1]
    f = os.path.join(path, "leaves.npz")
    size = os.path.getsize(f)
    with open(f, "r+b") as fh:
        fh.seek(size // 2)
        fh.write(os.urandom(64))
    assert not store.verify(path)
    restored, step = mgr.restore_latest(t)
    assert step == 1                          # fell back to the valid one
    assert_tree_equal(t, restored)


def test_async_save_then_restore(tmp_path):
    t = tree()
    mgr = CheckpointManager(str(tmp_path), interval=2, keep=3)
    assert mgr.maybe_save(t, 2)
    assert not mgr.maybe_save(t, 3)
    mgr.wait()
    restored, step = mgr.restore_latest(t)
    assert step == 2
    assert_tree_equal(t, restored)


def test_failure_injection_keeps_previous(tmp_path):
    t = tree()
    mgr = CheckpointManager(str(tmp_path), interval=1, async_write=False)
    mgr.save(t, 1, block=True)

    def boom(step):
        raise RuntimeError("disk died")

    mgr.failure_injection = boom
    with pytest.raises(RuntimeError):
        mgr.save(tree(seed=2), 2, block=True)
    restored, step = mgr.restore_latest(t)
    assert step == 1


def test_uncommitted_tmp_ignored(tmp_path):
    t = tree()
    store.save(t, str(tmp_path), 1)
    # simulate a torn write: directory without COMMITTED marker
    os.makedirs(str(tmp_path) + "/step_000000002")
    assert store.list_steps(str(tmp_path)) == [1]
