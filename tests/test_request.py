"""Request FSM invariants (paper §3) — unit + hypothesis property tests."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.request import Phase, Request


def drive(r: Request, chunks):
    """Apply a chunk/preempt script; returns tokens generated."""
    gen = 0
    t = 0.0
    for c in chunks:
        if c == "P":
            r.preempt()
            continue
        if not r.running:
            r.running = True
        c = min(c, r.remaining_prefill)
        if c <= 0:
            continue
        t += 1.0
        gen += int(r.advance(c, t))
        if r.finished:
            break
    return gen


def test_basic_lifecycle():
    r = Request(rid=0, input_len=4, output_len=3)
    assert r.phase == Phase.WAITING
    r.running = True
    assert r.phase == Phase.PREFILL
    assert not r.advance(3, 1.0)          # partial prefill
    assert r.advance(1, 2.0)              # completes prefill -> token 1
    assert r.phase == Phase.DECODE
    assert r.advance(1, 3.0)              # token 2
    assert r.advance(1, 4.0)              # token 3 -> finished
    assert r.finished and r.phase == Phase.FINISHED
    assert r.m == 0                        # memory released
    assert r.latency() == 4.0
    assert r.ttft() == 2.0
    assert r.tpot() == 1.0


def test_peak_kv_is_i_plus_o_minus_1():
    r = Request(rid=0, input_len=5, output_len=4)
    r.running = True
    peak = 0
    t = 0.0
    while not r.finished:
        c = r.remaining_prefill
        t += 1
        peak = max(peak, r.m + c)   # in-batch reservation (m after proc)
        r.advance(c, t)
    assert peak == r.peak_kv == 5 + 4 - 1


def test_refill_after_preemption():
    r = Request(rid=0, input_len=4, output_len=4)
    r.running = True
    r.advance(4, 1.0)                      # prefill -> 1 token (m=4)
    r.advance(1, 2.0)                      # decode -> 2 tokens (m=5)
    assert r.m == 5 and r.generated == 2
    released = r.preempt()
    assert released == 5 and r.m == 0 and not r.running
    # refill must reprocess input + generated tokens
    assert r.remaining_prefill == 4 + 2
    r.running = True
    assert r.phase == Phase.PREFILL        # refill is a prefill
    r.advance(6, 3.0)                      # full refill -> token 3
    assert r.generated == 3


@settings(max_examples=200, deadline=None)
@given(I=st.integers(1, 64), O=st.integers(1, 16),
       script=st.lists(
           st.one_of(st.integers(1, 32), st.just("P")), max_size=80))
def test_property_token_conservation(I, O, script):
    """However the request is chunked/preempted: it finishes iff it
    generates exactly O tokens, each token emerges exactly when m reaches
    I+generated, and m never exceeds I+O-1."""
    r = Request(rid=0, input_len=I, output_len=O)
    gen = 0
    t = 0.0
    for step in script + [I + O + 100] * (O + 2):  # ensure termination
        if r.finished:
            break
        if step == "P":
            r.preempt()
            assert r.m == 0
            continue
        if not r.running:
            r.running = True
        c = min(step, r.remaining_prefill)
        if c <= 0:
            continue
        t += 1.0
        before_target = r.target_context
        got = r.advance(c, t)
        assert r.m <= I + O - 1 or r.finished
        assert got == (r.m == 0 and r.finished or r.m == before_target)
        gen += int(got)
    assert r.finished
    assert gen == O == r.generated
    assert len(r.token_times) == O


def test_over_processing_rejected():
    r = Request(rid=0, input_len=4, output_len=2)
    r.running = True
    with pytest.raises(AssertionError):
        r.advance(5, 1.0)
