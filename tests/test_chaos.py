"""Chaos suite (PR 7): transactional step execution under seeded fault
injection.

The failure-model contract: under ANY deterministic ``FaultSpec`` the
engine may retry, roll back, and degrade requests to recompute — but it
must never emit a different token than the fault-free run, never leak a
page or a store entry, and (where a simulator mirror exists) the
virtual-time trace must stay in parity batch-for-batch.  Unit tests pin
the building blocks (FaultPlan determinism, CRC seal/verify/flip,
StepTxn rollback); the chaos matrix sweeps planes × preempt modes ×
seeds against the fault-free reference.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (PagedAllocator, Request, TheoreticalCostModel,
                        PrefixTierSim, get_hardware, make_scheduler,
                        simulate)
from repro.data.workloads import conversation_tree, zipf_shared_prefix
from repro.models import model as M
from repro.serving import Engine, EngineConfig, KVSwapStore
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.swap_store import flip_bit, seal_entry, verify_entry
from repro.serving.txn import begin_step_txn

RNG = jax.random.PRNGKey(0)
_CFG_CACHE = {}


def model_and_params(name="tinyllama-1.1b"):
    if name not in _CFG_CACHE:
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        _CFG_CACHE[name] = (cfg, M.init_params(cfg, RNG))
    return _CFG_CACHE[name]


def cost_model():
    cfg, _ = model_and_params()
    return TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))


def requests_for(cfg, n=5, seed=0):
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        I, O = int(rs.randint(8, 25)), int(rs.randint(3, 9))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        out.append(Request(rid=i, input_len=I, output_len=O,
                           arrival=0.0, prompt=prompt))
    return out


def build_slot(M_kv=60, *, preempt_mode="swap", faults=None,
               straggler=None):
    """Batched slot-plane engine (full-slot snapshots on suspend)."""
    cfg, params = model_and_params()
    sched = make_scheduler("vllm", M_kv, S=128, replacement="srf",
                           preempt_mode=preempt_mode)
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=4, cache_len=64, chunk=16,
                              faults=faults, straggler_factor=straggler),
                 cost_model=cost_model())
    return cfg, params, eng


def build_paged(M_kv=256, *, scheduler="vllm", S=512,
                preempt_mode="recompute", partial=False,
                demotion=False, policy="lru", faults=None):
    """Pooled paged-plane engine (page runs, prefix tier)."""
    cfg, params = model_and_params()
    sched = make_scheduler(scheduler, M_kv, S=S, replacement="srf",
                           preempt_mode=preempt_mode,
                           partial_preempt=partial)
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=4, cache_len=64, chunk=16,
                              plane="paged", page_size=8,
                              cache_policy=policy, cache_demotion=demotion,
                              faults=faults),
                 cost_model=cost_model())
    return cfg, params, eng


# paged configurations with real churn, mirroring the recipes the
# fault-free suites already pin down:
#   swap      — full-suspend churn (test_paged_plane parity recipe)
#   partial   — tail-run shedding (test_partial_preemption_parity)
#   demotion  — prefix host tier  (test_sim_engine_demotion_parity)
PAGED_CONFIGS = {
    "swap": dict(scheduler="vllm", M_kv=60, S=128,
                 preempt_mode="swap"),
    "partial": dict(scheduler="sarathi_cs", M_kv=72, S=128,
                    preempt_mode="swap", partial=True),
    "demotion": dict(scheduler="vllm", M_kv=256, S=512,
                     preempt_mode="recompute", demotion=True,
                     policy="break_even"),
    # the PR 9 radix-trie participant: branching conversations whose
    # partial-prefix attaches, node demotions, and txn rollbacks must
    # all survive the fault schedule
    "trie": dict(scheduler="vllm", M_kv=256, S=512,
                 preempt_mode="recompute", demotion=True,
                 policy="break_even"),
}


def paged_workload(cfg, name):
    if name == "demotion":
        return zipf_shared_prefix(n=16, num_groups=6, page_size=8,
                                  seed=1, vocab=cfg.vocab_size)
    if name == "trie":
        return conversation_tree(n=12, page_size=8, vocab=cfg.vocab_size)
    if name == "partial":
        rs = np.random.RandomState(2)
        out = []
        for i in range(8):
            I, O = int(rs.randint(4, 28)), int(rs.randint(3, 16))
            prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
            out.append(Request(rid=i, input_len=I, output_len=O,
                               arrival=0.0, prompt=prompt))
        return out
    return requests_for(cfg)


def _no_leaks(eng):
    assert len(eng.swap_store) == 0, "suspend entries leaked"
    assert not eng._pending_swaps and not eng._pending_demotes


# --------------------------------------------------------------------- #
# unit: FaultPlan
# --------------------------------------------------------------------- #

def test_fault_plan_deterministic_and_rate_bounds():
    spec = FaultSpec(seed=7, p_store_permanent=0.5, p_corrupt=0.5)
    a, b = FaultPlan(spec), FaultPlan(spec)
    keys = [(rid, m, s) for rid in range(8) for m in (8, 16)
            for s in (0, 1)]
    draws_a = [a.decide("perm_put", *k) for k in keys]
    draws_b = [b.decide("perm_put", *k) for k in keys]
    assert draws_a == draws_b                 # stateless, process-stable
    assert any(draws_a) and not all(draws_a)  # 0.5 actually splits
    # p=0 never fires, p=1 always fires
    never = FaultPlan(FaultSpec(seed=7))
    always = FaultPlan(FaultSpec(seed=7, p_store_permanent=1.0))
    assert not any(never.decide("perm_put", *k) for k in keys)
    assert all(always.decide("perm_put", *k) for k in keys)
    # a different seed reshuffles the schedule
    other = FaultPlan(FaultSpec(seed=8, p_store_permanent=0.5,
                                p_corrupt=0.5))
    assert [other.decide("perm_put", *k) for k in keys] != draws_a


def test_fault_plan_alloc_attempt_keyed():
    """Allocation faults clear on retry: for any faulting (step,
    attempt, ordinal) some later attempt draws clean, so the step loop
    cannot livelock on one allocation."""
    plan = FaultPlan(FaultSpec(seed=1, p_alloc=0.5))
    for step in range(10):
        for ordinal in range(4):
            assert not all(plan.alloc_fault(step, att, ordinal)
                           for att in range(50))


def test_fault_plan_rejects_bad_rates():
    with pytest.raises(ValueError, match="p_alloc"):
        FaultSpec(p_alloc=1.5)
    with pytest.raises(ValueError, match="p_corrupt"):
        FaultSpec(p_corrupt=-0.1)


def test_transient_failure_count_within_retry_budget():
    """``transient_failures`` returns 0 or 1..3 — always within the
    engine's ``run_with_retries(retries=3)`` budget of 4 attempts, so a
    transient store fault NEVER escalates to a dropped snapshot."""
    plan = FaultPlan(FaultSpec(seed=2, p_store_transient=1.0))
    counts = {plan.transient_failures("store_put", rid, m, s)
              for rid in range(16) for m in (8, 24) for s in (0, 1, 2)}
    assert counts and counts <= {1, 2, 3}
    clean = FaultPlan(FaultSpec(seed=2))
    assert clean.transient_failures("store_put", 0, 8, 0) == 0


# --------------------------------------------------------------------- #
# unit: integrity seal
# --------------------------------------------------------------------- #

def test_seal_verify_and_flip_targets_largest_leaf():
    store = KVSwapStore()
    cache = {"index": np.array([3], np.int32),
             "k": np.arange(64, dtype=np.float32),
             "v": np.arange(64, dtype=np.float32)}
    entry = store.put(1, cache, [1, 2, 3], 3)
    seal_entry(entry)
    assert verify_entry(entry)
    crc0 = entry.crc
    seal_entry(entry)                      # idempotent: never re-bless
    assert entry.crc == crc0
    assert flip_bit(entry.cache)
    assert not verify_entry(entry)
    # rot lands in the KV bytes; slot metadata stays intact for the
    # engine's drain-time index asserts
    assert int(entry.cache["index"][0]) == 3


def test_metadata_only_entries_verify_trivially():
    store = KVSwapStore()
    entry = store.put_prefix(99, (1, 2), 2, None, nbytes=128)
    seal_entry(entry)
    assert entry.crc is None and verify_entry(entry)
    assert not flip_bit({"empty": np.zeros(0)})


# --------------------------------------------------------------------- #
# unit: step transaction rollback
# --------------------------------------------------------------------- #

def test_step_txn_restores_every_participant():
    alloc = PagedAllocator(num_pages=8, page_size=2)
    store = KVSwapStore()
    sched = make_scheduler("vllm", 64, S=128)
    r = Request(rid=0, input_len=4, output_len=4, arrival=0.0,
                prompt=[1, 2, 3, 4])
    sched.add_request(r)
    txn = begin_step_txn(scheduler=sched, allocator=alloc, store=store,
                         requests=[r])
    alloc.allocate(0, 6)
    store.put(0, {"k": np.zeros(4, np.float32)}, [1, 2], 2)
    r.m, r.generated, r.running = 3, 2, True
    sched.waiting.clear()
    sched.running.append(r)
    txn.rollback()
    assert alloc.free_pages == 8
    assert len(store) == 0
    assert (r.m, r.generated, r.running) == (0, 0, False)
    assert sched.waiting == [r] and sched.running == []
    with pytest.raises(RuntimeError, match="twice"):
        txn.rollback()                   # double rollback is a bug, loudly


# --------------------------------------------------------------------- #
# engine: each fault class alone
# --------------------------------------------------------------------- #

def test_alloc_faults_roll_back_and_retry():
    cfg, _, ref = build_paged(**PAGED_CONFIGS["swap"])
    res_ref = ref.run(paged_workload(cfg, "swap"))
    cfg, _, eng = build_paged(faults=FaultSpec(seed=5, p_alloc=0.5),
                              **PAGED_CONFIGS["swap"])
    res = eng.run(paged_workload(cfg, "swap"))
    assert res.outputs == res_ref.outputs
    assert eng.recovery_stats["alloc_faults"] > 0
    assert eng.recovery_stats["rollbacks"] >= \
        eng.recovery_stats["alloc_faults"]
    assert res.metrics.makespan == pytest.approx(res_ref.metrics.makespan)
    _no_leaks(eng)


def test_transient_store_faults_retry_with_backoff():
    cfg, _, ref = build_slot(preempt_mode="swap")
    res_ref = ref.run(requests_for(cfg))
    assert res_ref.metrics.num_swaps > 0
    cfg, _, eng = build_slot(preempt_mode="swap",
                             faults=FaultSpec(seed=6,
                                              p_store_transient=1.0))
    res = eng.run(requests_for(cfg))
    assert res.outputs == res_ref.outputs
    assert eng.swap_stats["transient_retries"] > 0
    assert eng.swap_stats["backoff_s"] > 0.0
    # transients always succeed within the retry budget: same swap
    # traffic as the fault-free run
    assert eng.swap_stats["swap_outs"] == ref.swap_stats["swap_outs"]
    assert eng.recovery_stats["rollbacks"] == 0
    _no_leaks(eng)


def test_permanent_store_faults_degrade_to_recompute():
    cfg, _, ref = build_slot(preempt_mode="swap")
    res_ref = ref.run(requests_for(cfg))
    cfg, _, eng = build_slot(preempt_mode="swap",
                             faults=FaultSpec(seed=6,
                                              p_store_permanent=1.0))
    res = eng.run(requests_for(cfg))
    assert res.outputs == res_ref.outputs
    assert eng.swap_stats["permanent_store_failures"] > 0
    assert eng.swap_stats["swap_fallbacks"] > 0
    assert eng.swap_stats["swap_outs"] == 0      # no put ever landed
    _no_leaks(eng)


def test_corrupt_snapshots_degrade_to_recompute():
    cfg, _, ref = build_slot(preempt_mode="swap")
    res_ref = ref.run(requests_for(cfg))
    cfg, _, eng = build_slot(preempt_mode="swap",
                             faults=FaultSpec(seed=6, p_corrupt=1.0))
    res = eng.run(requests_for(cfg))
    assert res.outputs == res_ref.outputs
    assert eng.recovery_stats["integrity_failures"] > 0
    assert eng.recovery_stats["degraded_recomputes"] > 0
    assert eng.recovery_stats["rollbacks"] >= \
        eng.recovery_stats["integrity_failures"]
    _no_leaks(eng)


def test_demote_promote_faults_never_corrupt_prefix_reuse():
    cfg, _, ref = build_paged(**PAGED_CONFIGS["demotion"])
    res_ref = ref.run(paged_workload(cfg, "demotion"))
    cfg, _, eng = build_paged(faults=FaultSpec(seed=9, p_demote_fail=0.5,
                                               p_promote_fail=0.5,
                                               p_corrupt=0.5),
                              **PAGED_CONFIGS["demotion"])
    res = eng.run(paged_workload(cfg, "demotion"))
    assert res.outputs == res_ref.outputs
    # a failed demotion or promotion costs reuse, never correctness
    assert eng.swap_stats["demote_drops"] + \
        eng.swap_stats["prefix_integrity"] > 0
    assert eng.swap_stats["promotions"] <= ref.swap_stats["promotions"]
    _no_leaks(eng)


def test_straggler_requeue_preserves_tokens():
    cfg, _, ref = build_slot(preempt_mode="recompute")
    res_ref = ref.run(requests_for(cfg))
    # a microscopic deadline factor marks every batch a straggler
    cfg, _, eng = build_slot(preempt_mode="recompute", straggler=1e-12)
    res = eng.run(requests_for(cfg))
    assert res.outputs == res_ref.outputs
    assert eng.recovery_stats["straggler_requeues"] > 0
    assert res.metrics.num_preemptions > res_ref.metrics.num_preemptions
    _no_leaks(eng)


# --------------------------------------------------------------------- #
# chaos matrix: all fault classes at once
# --------------------------------------------------------------------- #

MIXED = dict(p_alloc=0.05, p_store_transient=0.3, p_store_permanent=0.15,
             p_corrupt=0.2, p_demote_fail=0.3, p_promote_fail=0.3)

SLOT_MODES = ("recompute", "swap", "auto")


def _chaos_slot(mode, seed):
    cfg, _, ref = build_slot(preempt_mode=mode)
    res_ref = ref.run(requests_for(cfg))
    assert res_ref.metrics.num_preemptions > 0
    cfg, _, eng = build_slot(preempt_mode=mode,
                             faults=FaultSpec(seed=seed, **MIXED))
    res = eng.run(requests_for(cfg))
    assert res.outputs == res_ref.outputs, (mode, seed)
    _no_leaks(eng)
    return eng


def _chaos_paged(name, seed):
    cfg, _, ref = build_paged(**PAGED_CONFIGS[name])
    res_ref = ref.run(paged_workload(cfg, name))
    if name not in ("demotion", "trie"):
        assert res_ref.metrics.num_preemptions > 0
    cfg, _, eng = build_paged(faults=FaultSpec(seed=seed, **MIXED),
                              **PAGED_CONFIGS[name])
    res = eng.run(paged_workload(cfg, name))
    assert res.outputs == res_ref.outputs, (name, seed)
    _no_leaks(eng)
    return eng


@pytest.mark.parametrize("mode", SLOT_MODES)
def test_chaos_slot_plane_smoke(mode):
    eng = _chaos_slot(mode, seed=0)
    if mode != "recompute":
        # the mixed spec actually exercised the failure paths
        assert eng.recovery_stats["rollbacks"] + \
            eng.swap_stats["transient_retries"] + \
            eng.swap_stats["permanent_store_failures"] > 0


@pytest.mark.parametrize("name", sorted(PAGED_CONFIGS))
def test_chaos_paged_plane_smoke(name):
    # seed 1 draws at least one fault in every paged config (the few
    # suspends these small workloads produce make seed 0 all-clean)
    eng = _chaos_paged(name, seed=1)
    assert eng.recovery_stats["rollbacks"] + \
        eng.recovery_stats["integrity_failures"] + \
        eng.swap_stats["transient_retries"] + \
        eng.swap_stats["permanent_store_failures"] + \
        eng.swap_stats["demote_drops"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_chaos_full_matrix(seed):
    for mode in SLOT_MODES:
        _chaos_slot(mode, seed)
    for name in PAGED_CONFIGS:
        _chaos_paged(name, seed)


# --------------------------------------------------------------------- #
# engine-vs-simulator parity under faults
# --------------------------------------------------------------------- #

def _page_nbytes(cfg, page_size):
    return 2 * cfg.num_layers * page_size * cfg.num_kv_heads \
        * cfg.head_dim_ * jnp.dtype(cfg.dtype).itemsize


FAULTED = FaultSpec(seed=4, p_store_transient=0.5, p_store_permanent=0.2,
                    p_corrupt=0.3, p_demote_fail=0.3, p_promote_fail=0.3)


@pytest.mark.parametrize("name", sorted(PAGED_CONFIGS))
@pytest.mark.parametrize("spec", [FaultSpec(seed=0), FAULTED],
                         ids=["faultless", "faulted"])
def test_sim_engine_parity_under_faults(name, spec):
    """The simulator's fault mirror must reproduce the engine's abort/
    degrade trace exactly: same rollbacks, same degraded requests, same
    retry/backoff charges, and the same virtual time batch-for-batch.
    (p_alloc stays 0: allocation faults are trace-free retries the
    simulator never models.)"""
    kw = PAGED_CONFIGS[name]
    cfg, _, eng = build_paged(faults=spec, **kw)
    res = eng.run(paged_workload(cfg, name))
    _no_leaks(eng)

    cm = cost_model()
    sched = make_scheduler(kw["scheduler"], kw["M_kv"], S=kw["S"],
                           replacement="srf",
                           preempt_mode=kw["preempt_mode"], page_size=8,
                           partial_preempt=kw.get("partial", False),
                           cache_policy=kw.get("policy", "lru"),
                           cache_demotion=kw.get("demotion", False))
    sched.cfg.max_running = 4                  # engine slot cap
    sched.cfg.faults = spec
    shadow = PrefixTierSim(sched.cfg, cm,
                           page_nbytes=_page_nbytes(cfg, 8))
    sim = simulate(sched, paged_workload(cfg, name), cm,
                   prefix_sim=shadow)

    # abort/degrade trace (engine rollbacks minus trace-free alloc
    # retries == the mirror's rollbacks; p_alloc is 0 here anyway)
    assert sim.recovery_stats["rollbacks"] == \
        eng.recovery_stats["rollbacks"] - eng.recovery_stats["alloc_faults"]
    for key in ("integrity_failures", "degraded_recomputes"):
        assert sim.recovery_stats[key] == eng.recovery_stats[key], key
    for key in ("permanent_store_failures", "transient_retries",
                "swap_fallbacks"):
        assert sim.recovery_stats[key] == eng.swap_stats[key], key
    assert sim.recovery_stats["backoff_s"] == \
        pytest.approx(eng.swap_stats["backoff_s"])
    # prefix tier: drops and integrity rejections line up too
    for key in ("demote_drops", "prefix_integrity", "demotions",
                "promotions"):
        assert sim.prefix_stats[key] == eng.swap_stats[key], key
    assert sim.num_preemptions == res.metrics.num_preemptions
    assert sim.num_swaps == res.metrics.num_swaps
    # virtual time: batch-for-batch, not just in total
    assert sim.makespan == pytest.approx(res.metrics.makespan, rel=1e-9)
    eng_swaps = [b.swap_s for b in res.metrics.batches]
    sim_swaps = [b.swap_s for b in sim.batches]
    assert len(eng_swaps) == len(sim_swaps)
    assert eng_swaps == pytest.approx(sim_swaps, rel=1e-9)
