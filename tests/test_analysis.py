"""Golden tests for the repo-specific static analysis (PR 6).

Each checker gets fixture snippets with KNOWN true positives and clean
negatives — if a rule is disabled or its heuristics regress, the
true-positive assertions fail.  A meta-test pins the committed
baseline to a fresh full-repo run, and regression fixtures re-create
the two bugs the gate exists to catch statically: the synchronous
prefix-page demotion and an unpriced allocator mutation.
"""
import json
import os
import textwrap

from repro.analysis import asserts, charges, hostsync, recompile
from repro.analysis.astutil import ModuleIndex
from repro.analysis.findings import (apply_suppressions, load_baseline,
                                     parse_suppressions)
from repro.analysis.runner import run_paths

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _index(source, path="src/repro/serving/mod.py"):
    return ModuleIndex(path, textwrap.dedent(source))


def _run(checker, source, path="src/repro/serving/mod.py"):
    mod = _index(source, path)
    findings = checker(mod)
    by_line, bad = parse_suppressions(mod.source_lines, path)
    return apply_suppressions(findings, by_line) + bad


def _blocking(findings, rule=None):
    return [f for f in findings if f.blocking
            and (rule is None or f.rule == rule)]


# --------------------------------------------------------------------- #
# checker 1: recompile hazards
# --------------------------------------------------------------------- #

JITTED_BRANCH_ON_TRACED = """
    import jax, jax.numpy as jnp

    @jax.jit
    def f(x):
        if x > 0:
            return x + 1
        return x
"""

JITTED_HOST_MATERIALIZE = """
    import jax, numpy as np

    @jax.jit
    def f(x):
        v = x.item()
        a = np.asarray(x)
        return v, a
"""

JITTED_FSTRING = """
    import jax

    @jax.jit
    def f(x):
        name = f"val={x}"
        return x
"""

JITTED_STATIC_OK = """
    import jax, jax.numpy as jnp

    @jax.jit
    def f(x, y=None):
        if x.shape[0] > 4:
            x = x[:4]
        if x.ndim == 2 and len(x) > 1:
            x = x.sum(0)
        if y is None:
            return x
        return x + y
"""

UNJITTED_BRANCH_OK = """
    def g(x):
        if x > 0:
            return x + 1
        return x
"""

CALLGRAPH_REACH = """
    import jax

    def helper(x):
        return x.item()

    @jax.jit
    def f(x):
        return helper(x)
"""

SCAN_CALLBACK_REACH = """
    import jax

    def body(carry, x):
        if x > 0:
            carry = carry + x
        return carry, x

    def run(xs):
        import jax.numpy as jnp
        return jax.lax.scan(body, jnp.zeros(()), xs)
"""


def test_recompile_branch_on_traced_flagged():
    fs = _blocking(_run(recompile.check_module, JITTED_BRANCH_ON_TRACED),
                   recompile.RULE)
    assert len(fs) == 1 and "branch on traced value" in fs[0].message


def test_recompile_host_materialization_flagged():
    fs = _blocking(_run(recompile.check_module, JITTED_HOST_MATERIALIZE),
                   recompile.RULE)
    assert len(fs) == 2
    assert any(".item()" in f.message for f in fs)
    assert any("np.asarray" in f.message for f in fs)


def test_recompile_fstring_interpolation_flagged():
    fs = _blocking(_run(recompile.check_module, JITTED_FSTRING),
                   recompile.RULE)
    assert len(fs) == 1 and "f-string" in fs[0].message


def test_recompile_static_branches_clean():
    assert not _blocking(_run(recompile.check_module, JITTED_STATIC_OK))


def test_recompile_outside_jit_clean():
    assert not _blocking(_run(recompile.check_module, UNJITTED_BRANCH_OK))


def test_recompile_reaches_through_call_graph():
    fs = _blocking(_run(recompile.check_module, CALLGRAPH_REACH),
                   recompile.RULE)
    assert len(fs) == 1 and fs[0].symbol == "helper"


def test_recompile_reaches_scan_callbacks():
    fs = _blocking(_run(recompile.check_module, SCAN_CALLBACK_REACH),
                   recompile.RULE)
    assert len(fs) == 1 and fs[0].symbol == "body"


DYNAMIC_SHAPE = """
    import jax, jax.numpy as jnp

    def model(params, toks):
        return toks

    _prefill_many = jax.jit(model)

    def drive(ids, start, n):
        toks = jnp.asarray(ids[start:start + n])
        return _prefill_many(None, toks)
"""

BUCKETED_OK = """
    import jax, jax.numpy as jnp
    import numpy as np

    def model(params, toks):
        return toks

    _prefill_many = jax.jit(model)

    def drive(ids, bucket, nslots):
        grid = np.zeros((nslots, bucket), np.int32)
        toks = jnp.asarray(grid)
        return _prefill_many(None, toks)
"""


def test_dynamic_shape_into_entry_point_flagged():
    fs = _blocking(_run(recompile.check_module, DYNAMIC_SHAPE),
                   recompile.RULE_SHAPE)
    assert len(fs) == 1 and "_prefill_many" in fs[0].message


def test_bucketed_staging_clean():
    assert not _blocking(_run(recompile.check_module, BUCKETED_OK),
                         recompile.RULE_SHAPE)


# --------------------------------------------------------------------- #
# checker 2: host syncs
# --------------------------------------------------------------------- #

HOST_SYNC_HOT = """
    import jax
    import numpy as np
    import jax.numpy as jnp

    class Engine:
        def __init__(self):
            self.cache = jnp.zeros((4, 4))

        def fetch(self):
            snap = jax.device_get(self.cache)
            jax.block_until_ready(self.cache)
            host = np.asarray(self.cache)
            return snap, host
"""

HOST_SYNC_CLEAN = """
    import numpy as np

    class Engine:
        def __init__(self):
            self.meta = [1, 2, 3]

        def fetch(self):
            return np.asarray(self.meta)
"""


def test_host_sync_flags_all_three_forms():
    fs = _blocking(_run(hostsync.check_module, HOST_SYNC_HOT),
                   hostsync.RULE)
    msgs = " | ".join(f.message for f in fs)
    assert len(fs) == 3
    assert "device_get" in msgs and "block_until_ready" in msgs \
        and "np.asarray" in msgs


def test_host_sync_ignores_host_data():
    assert not _blocking(_run(hostsync.check_module, HOST_SYNC_CLEAN))


def test_host_sync_out_of_scope_path_clean():
    fs = _run(hostsync.check_module, HOST_SYNC_HOT,
              path="src/repro/launch/tool.py")
    assert fs == []


def test_host_sync_reintroducing_sync_demotion_is_caught():
    """The satellite-1 regression fixture: a demotion that gathers pool
    pages through np.asarray (the pre-PR-6 synchronous path) must be a
    blocking finding in the serving scope."""
    src = """
        import numpy as np
        import jax.numpy as jnp

        class Engine:
            def __init__(self):
                self.k_pools = jnp.zeros((2, 8, 4))

            def _demote_prefix(self, key, page):
                kv = np.asarray(self.k_pools[:, [page]])
                return kv
    """
    fs = _blocking(_run(hostsync.check_module, src,
                        path="src/repro/serving/engine.py"),
                   hostsync.RULE)
    assert len(fs) == 1 and fs[0].symbol == "_demote_prefix"


# --------------------------------------------------------------------- #
# checker 3: charge auditor
# --------------------------------------------------------------------- #

UNPRICED = """
    class Engine:
        def demote(self, key, kv):
            self.swap_store.put_prefix(key, (), 8, kv)
"""

PRICED = """
    class Engine:
        def demote(self, key, kv):
            self.swap_store.put_prefix(key, (), 8, kv)
            self._tier_swap_s += self._swap_time(8)
            self.swap_stats["demotions"] += 1
"""

SIBLING_BRANCH_CHARGE = """
    class Engine:
        def demote(self, key, kv, fast):
            if fast:
                self.swap_store.put_prefix(key, (), 8, kv)
            else:
                self._tier_swap_s += self._swap_time(8)
"""

GUARDED_MUTATION_CHARGED_AFTER = """
    class Engine:
        def demote(self, key, kv, ok):
            if ok:
                self.swap_store.put_prefix(key, (), 8, kv)
            self._tier_swap_s += self._swap_time(8)
"""


def test_unpriced_mutation_flagged():
    fs = _blocking(_run(charges.check_module, UNPRICED), charges.RULE)
    assert len(fs) == 1 and "put_prefix" in fs[0].message


def test_priced_mutation_clean():
    assert not _blocking(_run(charges.check_module, PRICED))


def test_sibling_branch_charge_does_not_pair():
    fs = _blocking(_run(charges.check_module, SIBLING_BRANCH_CHARGE),
                   charges.RULE)
    assert len(fs) == 1


def test_dominating_charge_pairs_across_branch():
    assert not _blocking(_run(charges.check_module,
                              GUARDED_MUTATION_CHARGED_AFTER))


def test_unpriced_out_of_scope_clean():
    fs = _run(charges.check_module, UNPRICED,
              path="src/repro/launch/tool.py")
    assert _blocking(fs, charges.RULE) == []


def test_config_mirror_missing_writethrough_flagged(tmp_path):
    (tmp_path / "core").mkdir()
    (tmp_path / "serving").mkdir()
    (tmp_path / "core" / "scheduler.py").write_text(textwrap.dedent("""
        from dataclasses import dataclass

        @dataclass
        class SchedulerConfig:
            M: int = 0
            page_size: int = 1
            cache_policy: str = "lru"
    """))
    engine_src = textwrap.dedent("""
        from dataclasses import dataclass

        @dataclass
        class EngineConfig:
            nslots: int = 4
            page_size: int = 1
            cache_policy: str = "lru"

        class Engine:
            def __init__(self, scheduler, ecfg):
                scheduler.cfg.page_size = ecfg.page_size
    """)
    path = str(tmp_path / "serving" / "engine.py")
    mod = ModuleIndex(path, engine_src)
    fs = [f for f in charges.check_module(mod)
          if f.rule == charges.RULE_MIRROR]
    assert len(fs) == 1 and "cache_policy" in fs[0].message

    fixed = engine_src.replace(
        "scheduler.cfg.page_size = ecfg.page_size",
        "scheduler.cfg.page_size = ecfg.page_size\n"
        "        scheduler.cfg.cache_policy = ecfg.cache_policy")
    mod = ModuleIndex(path, fixed)
    assert [f for f in charges.check_module(mod)
            if f.rule == charges.RULE_MIRROR] == []


# --------------------------------------------------------------------- #
# checker 5: bare asserts in the control plane
# --------------------------------------------------------------------- #

BARE_ASSERT = """
    def alloc(self, need):
        assert need > 0, need
        return self._take(need)
"""

GATED_ASSERT = """
    def step(self):
        executed = self._run()
        if self.cfg.check_invariants:
            assert self._slots_consistent(), self.slot_of
        return executed
"""

INVARIANT_CALL = """
    from repro.core.invariants import invariant

    def alloc(self, need):
        invariant(need > 0, need)
        return self._take(need)
"""

ALLOWED_BARE_ASSERT = """
    def narrow(self, entry):
        assert entry is not None  # repro: allow-bare-invariant-assert(type narrowing for the checker below)
        return entry.kv
"""


def test_bare_assert_flagged():
    fs = _blocking(_run(asserts.check_module, BARE_ASSERT), asserts.RULE)
    assert len(fs) == 1 and "python -O" in fs[0].message


def test_check_invariants_gated_assert_clean():
    assert not _blocking(_run(asserts.check_module, GATED_ASSERT))


def test_invariant_call_clean():
    assert not _blocking(_run(asserts.check_module, INVARIANT_CALL))


def test_suppressed_bare_assert_clean():
    assert not _blocking(_run(asserts.check_module, ALLOWED_BARE_ASSERT))


def test_bare_assert_out_of_scope_clean():
    fs = _run(asserts.check_module, BARE_ASSERT,
              path="src/repro/launch/tool.py")
    assert _blocking(fs, asserts.RULE) == []


# --------------------------------------------------------------------- #
# suppressions + baseline
# --------------------------------------------------------------------- #

def test_suppression_with_rationale_applies():
    src = """
        class Engine:
            def demote(self, key, kv):
                self.swap_store.put_prefix(key, (), 8, kv)  # repro: allow-unpriced-mutation(fixture rationale)
    """
    fs = _run(charges.check_module, src)
    assert len(fs) == 1 and fs[0].suppressed \
        and fs[0].reason == "fixture rationale"


def test_suppression_comment_above_applies():
    src = """
        class Engine:
            def demote(self, key, kv):
                # repro: allow-unpriced-mutation(fixture rationale above)
                self.swap_store.put_prefix(key, (), 8, kv)
    """
    fs = _run(charges.check_module, src)
    assert len(fs) == 1 and fs[0].suppressed


def test_suppression_without_rationale_is_a_finding():
    src = """
        class Engine:
            def demote(self, key, kv):
                self.swap_store.put_prefix(key, (), 8, kv)  # repro: allow-unpriced-mutation
    """
    fs = _run(charges.check_module, src)
    rules = sorted(f.rule for f in fs if f.blocking)
    assert rules == ["bad-suppression", "unpriced-mutation"]


def test_wrong_rule_suppression_does_not_apply():
    src = """
        class Engine:
            def demote(self, key, kv):
                self.swap_store.put_prefix(key, (), 8, kv)  # repro: allow-host-sync(wrong rule)
    """
    fs = _blocking(_run(charges.check_module, src), charges.RULE)
    assert len(fs) == 1


def test_committed_baseline_matches_fresh_run():
    """`python -m repro.analysis src/` must exit 0 against the committed
    baseline — and the baseline must not hide findings that no longer
    exist (stale fingerprints force a regenerate)."""
    baseline_path = os.path.join(REPO_ROOT, "analysis_baseline.json")
    committed = set(load_baseline(baseline_path))
    fresh = run_paths([os.path.join(REPO_ROOT, "src")])
    fingerprints = {f.fingerprint for f in fresh if not f.suppressed}
    blocking = {f.fingerprint for f in fresh if f.blocking}
    # everything blocking is known...
    assert blocking <= committed, \
        f"new findings not in baseline: {sorted(blocking - committed)}"
    # ...and everything known still exists (no stale grandfathering)
    assert committed <= fingerprints, \
        f"stale baseline entries: {sorted(committed - fingerprints)}"


def test_hlo_host_transfer_and_custom_call_scan():
    """The artifact audit's HLO text scanners: host-boundary ops and
    custom_call targets are found; clean modules report nothing."""
    from repro.launch.hlo_analysis import custom_calls, host_transfer_ops
    hlo = textwrap.dedent("""
        ENTRY %main (p0: f32[4]) -> f32[4] {
          %p0 = f32[4] parameter(0)
          %t = token[] after-all()
          %o = token[] outfeed(%p0, %t)
          %cc = f32[4] custom-call(%p0), custom_call_target="my_pallas_kernel"
          %s = (f32[4], u32[], token[]) send(%p0, %t), channel_id=1
          ROOT %r = f32[4] add(%p0, %p0)
        }
    """)
    assert host_transfer_ops(hlo) == {"outfeed": 1, "send": 1}
    assert custom_calls(hlo) == {"my_pallas_kernel": 1}
    clean = "ENTRY %m (p0: f32[4]) -> f32[4] {\n  ROOT %r = f32[4] add(%p0, %p0)\n}"
    assert host_transfer_ops(clean) == {}
    assert custom_calls(clean) == {}


def test_compile_budget_file_checked_in():
    path = os.path.join(REPO_ROOT, "src", "repro", "analysis",
                        "compile_budget.json")
    with open(path) as f:
        data = json.load(f)
    assert set(data["num_compiles"]) == {"batched", "paged"}
    for plane, n in data["num_compiles"].items():
        assert 0 < n <= 16, (plane, n)   # small constant, per PR 2


def test_baseline_file_shape():
    with open(os.path.join(REPO_ROOT, "analysis_baseline.json")) as f:
        data = json.load(f)
    assert sorted(data) == ["fingerprints", "note"]
    assert data["fingerprints"] == sorted(set(data["fingerprints"]))


# --------------------------------------------------------------------- #
# checker 5: txn-coverage (PR 10)
# --------------------------------------------------------------------- #

SNAPSHOT_CLASS_HOLE = """
    class ShadowThing:
        def __init__(self):
            self.runs = {}
            self.epoch = 0

        def snapshot(self):
            runs = dict(self.runs)

            def restore():
                self.runs = dict(runs)
            return restore

        def advance(self):
            self.epoch += 1
            self.runs[1] = 2
"""

SNAPSHOT_CLASS_COMPLETE = """
    class ShadowThing:
        def __init__(self):
            self.runs = {}
            self.epoch = 0

        def snapshot(self):
            runs = dict(self.runs)
            epoch = self.epoch

            def restore():
                self.runs = dict(runs)
                self.epoch = epoch
            return restore

        def advance(self):
            self.epoch += 1
            self.runs[1] = 2
"""

# regression fixture: the live-code shape this PR annotated — engine
# state mutated on a step-reachable path that the restore closure never
# captures (the real recovery_stats/wall/_step_no sites carry allows)
ENGINE_TXN_HOLE = """
    class Engine:
        def _begin_txn(self):
            cache = self.cache

            def restore():
                self.cache = cache
            return restore

        def step(self):
            txn = self._begin_txn()
            self.cache = {}
            self.wall += 1.0
"""

ENGINE_TXN_COMPLETE = """
    class Engine:
        def _begin_txn(self):
            cache = self.cache
            wall = self.wall

            def restore():
                self.cache = cache
                self.wall = wall
            return restore

        def step(self):
            txn = self._begin_txn()
            self.cache = {}
            self.wall += 1.0
"""


def test_txncov_snapshot_class_hole_flagged():
    from repro.analysis import txncov
    fs = _blocking(_run(txncov.check_module, SNAPSHOT_CLASS_HOLE),
                   txncov.RULE)
    assert len(fs) == 1 and "epoch" in fs[0].message


def test_txncov_snapshot_class_complete_clean():
    from repro.analysis import txncov
    assert not _blocking(_run(txncov.check_module, SNAPSHOT_CLASS_COMPLETE))


def test_txncov_engine_hole_flagged():
    from repro.analysis import txncov
    fs = _blocking(_run(txncov.check_module, ENGINE_TXN_HOLE), txncov.RULE)
    assert len(fs) == 1 and "self.wall" in fs[0].message


def test_txncov_engine_complete_clean():
    from repro.analysis import txncov
    assert not _blocking(_run(txncov.check_module, ENGINE_TXN_COMPLETE))


def test_txncov_out_of_scope_clean():
    from repro.analysis import txncov
    fs = _run(txncov.check_module, SNAPSHOT_CLASS_HOLE,
              path="src/repro/launch/tool.py")
    assert _blocking(fs, txncov.RULE) == []


def test_txncov_live_participants_clean():
    """The real participant write-sets are fully captured by txn.py —
    deleting one snapshot field is the seeded gate check."""
    from repro.analysis import txncov
    for rel in ("src/repro/core/kvcache.py", "src/repro/core/request.py",
                "src/repro/core/scheduler.py",
                "src/repro/serving/swap_store.py"):
        with open(os.path.join(REPO_ROOT, rel)) as f:
            mod = ModuleIndex(rel, f.read())
        fs = [x for x in txncov.check_module(mod)]
        assert fs == [], (rel, [x.render() for x in fs])


def test_txncov_request_field_deletion_detected(tmp_path):
    """Seeded mutation (a): dropping a field from _REQUEST_FIELDS makes
    the Request write-set check fire exactly once."""
    from repro.analysis import txncov
    with open(os.path.join(REPO_ROOT, "src/repro/serving/txn.py")) as f:
        txn_src = f.read().replace('"predicted_output",', "")
    with open(os.path.join(REPO_ROOT, "src/repro/core/request.py")) as f:
        req_src = f.read()
    base = tmp_path / "src" / "repro"
    (base / "serving").mkdir(parents=True)
    (base / "core").mkdir()
    (base / "serving" / "txn.py").write_text(txn_src)
    req_path = base / "core" / "request.py"
    req_path.write_text(req_src)
    mod = ModuleIndex(str(req_path), req_src)
    fs = _blocking(txncov.check_module(mod), txncov.RULE)
    assert len(fs) == 1 and "predicted_output" in fs[0].message


# --------------------------------------------------------------------- #
# checker 6: stat-mirror (PR 10)
# --------------------------------------------------------------------- #

ENGINE_ONLY_STAT_KEY = """
    class EngineResult:
        pass

    class Engine:
        def _account(self):
            self.swap_stats["bogus_counter_xyz"] = 1
"""

ENGINE_MIRRORED_STAT_KEYS = """
    from repro.core import stat_keys as SK

    class EngineResult:
        pass

    class Engine:
        def _account(self):
            self.swap_stats[SK.PROMOTIONS] += 1
            self.swap_stats["wall_out_s"] += 0.5
"""


def test_statmirror_engine_only_key_flagged():
    from repro.analysis import statmirror
    fs = _blocking(_run(statmirror.check_module, ENGINE_ONLY_STAT_KEY),
                   statmirror.RULE)
    assert len(fs) == 1 and "bogus_counter_xyz" in fs[0].message


def test_statmirror_mirrored_and_allowlisted_clean():
    """A key the simulator mirror also writes, and a sanctioned
    engine-wall key, both pass — constants resolve through
    core/stat_keys.py."""
    from repro.analysis import statmirror
    assert not _blocking(_run(statmirror.check_module,
                              ENGINE_MIRRORED_STAT_KEYS))


def test_statmirror_live_engine_and_sim_clean():
    from repro.analysis import statmirror
    for rel in ("src/repro/serving/engine.py", "src/repro/core/simulator.py"):
        with open(os.path.join(REPO_ROOT, rel)) as f:
            mod = ModuleIndex(rel, f.read())
        fs = _blocking(statmirror.check_module(mod), statmirror.RULE)
        assert fs == [], (rel, [x.render() for x in fs])


def test_statmirror_batchlog_asymmetry_flagged():
    """An engine-side BatchLog field the simulator never emits (and the
    allowlist does not sanction) is per-batch parity drift."""
    from repro.analysis import statmirror
    src = ENGINE_MIRRORED_STAT_KEYS + """
    def log(self):
        self.batch_logs.append(BatchLog(t_start=0.0, bogus_field=1))
"""
    fs = _blocking(_run(statmirror.check_module, src), statmirror.RULE)
    assert len(fs) == 1 and "bogus_field" in fs[0].message


# --------------------------------------------------------------------- #
# checker 7: async-drain (PR 10)
# --------------------------------------------------------------------- #

UNDRAINED_POP = """
    class Engine:
        def _swap_in(self, r):
            entry = self.swap_store.pop(r.rid)
            self.cache = entry.cache
"""

DRAINED_POP = """
    class Engine:
        def _swap_in(self, r):
            if r.rid in self._pending_swaps:
                self._drain_swaps(rid=r.rid)
            entry = self.swap_store.pop(r.rid)
            self.cache = entry.cache
"""

METADATA_POP = """
    class Engine:
        def _repair(self, r):
            for run in self.swap_store.pop_runs(r.rid):
                r.drop_tail_run(run.num_tokens)
"""

UNREGISTERED_START = """
    class Engine:
        def _swap_out(self, snap):
            snap.copy_to_host_async()
"""

REGISTERED_START = """
    class Engine:
        def _swap_out(self, rid, snap):
            snap.copy_to_host_async()
            self._pending_swaps[rid] = snap
"""

CALLER_REGISTERED_START = """
    class Engine:
        def _gather(self, kv):
            kv.copy_to_host_async()
            return kv

        def _swap_out(self, rid, kv):
            entry = self._gather(kv)
            self._pending_runs[rid] = entry
"""

UNDRAINED_RESULT = """
    class Engine:
        def run(self):
            return EngineResult(outputs={})
"""

DRAINED_RESULT = """
    class Engine:
        def run(self):
            self._drain_swaps()
            return EngineResult(outputs={})
"""

DRAIN_UNDER_JIT = """
    import jax

    @jax.jit
    def kernel(x):
        _drain_swaps()
        return x
"""


def test_asyncdrain_undrained_pop_flagged():
    from repro.analysis import asyncdrain
    fs = _blocking(_run(asyncdrain.check_module, UNDRAINED_POP),
                   asyncdrain.RULE)
    assert len(fs) == 1 and ".cache read" in fs[0].message


def test_asyncdrain_drained_pop_clean():
    from repro.analysis import asyncdrain
    assert not _blocking(_run(asyncdrain.check_module, DRAINED_POP))


def test_asyncdrain_metadata_pop_clean():
    """Rollback repairs read run metadata only — no drain required."""
    from repro.analysis import asyncdrain
    assert not _blocking(_run(asyncdrain.check_module, METADATA_POP))


def test_asyncdrain_unregistered_start_flagged():
    from repro.analysis import asyncdrain
    fs = _blocking(_run(asyncdrain.check_module, UNREGISTERED_START),
                   asyncdrain.RULE)
    assert len(fs) == 1 and "never" in fs[0].message


def test_asyncdrain_registered_start_clean():
    from repro.analysis import asyncdrain
    assert not _blocking(_run(asyncdrain.check_module, REGISTERED_START))


def test_asyncdrain_caller_registered_start_clean():
    """Builder pattern: the copy starts in a helper, every call site
    registers the returned buffers (the _gather_pages_device shape)."""
    from repro.analysis import asyncdrain
    assert not _blocking(_run(asyncdrain.check_module,
                              CALLER_REGISTERED_START))


def test_asyncdrain_undrained_result_flagged():
    from repro.analysis import asyncdrain
    fs = _blocking(_run(asyncdrain.check_module, UNDRAINED_RESULT),
                   asyncdrain.RULE)
    assert len(fs) == 1 and "EngineResult" in fs[0].message


def test_asyncdrain_drained_result_clean():
    from repro.analysis import asyncdrain
    assert not _blocking(_run(asyncdrain.check_module, DRAINED_RESULT))


def test_asyncdrain_drain_under_jit_flagged():
    from repro.analysis import asyncdrain
    fs = _blocking(_run(asyncdrain.check_module, DRAIN_UNDER_JIT),
                   asyncdrain.RULE)
    assert len(fs) == 1 and "jit-reachable" in fs[0].message


def test_asyncdrain_live_engine_clean():
    from repro.analysis import asyncdrain
    rel = "src/repro/serving/engine.py"
    with open(os.path.join(REPO_ROOT, rel)) as f:
        mod = ModuleIndex(rel, f.read())
    fs = _blocking(asyncdrain.check_module(mod), asyncdrain.RULE)
    assert fs == [], [x.render() for x in fs]


# --------------------------------------------------------------------- #
# CLI: --json schema, per-rule summary, --write-baseline determinism
# --------------------------------------------------------------------- #

def test_cli_json_schema(tmp_path, capsys):
    """Downstream tooling consumes --json: top-level and per-finding
    keys are stable across checkers."""
    from repro.analysis.__main__ import main
    from repro.analysis.runner import ALL_RULES
    (tmp_path / "serving").mkdir()
    bad = tmp_path / "serving" / "mod.py"
    bad.write_text(textwrap.dedent(ENGINE_TXN_HOLE))
    rc = main(["--json", "--no-baseline", str(bad)])
    out = json.loads(capsys.readouterr().out)
    assert sorted(out) == ["blocking", "findings", "per_rule"]
    assert sorted(out["per_rule"]) == sorted(ALL_RULES)
    assert out["blocking"] >= 1 and rc == 1
    for f in out["findings"]:
        assert sorted(f) == ["baselined", "col", "fingerprint", "line",
                             "message", "path", "reason", "rule",
                             "suppressed", "symbol"]


def test_cli_per_rule_summary_line(tmp_path, capsys):
    from repro.analysis.__main__ import main
    clean = tmp_path / "mod.py"
    clean.write_text("x = 1\n")
    rc = main(["--no-baseline", str(clean)])
    out = capsys.readouterr().out
    assert rc == 0 and "-- per rule:" in out \
        and "txn-coverage=0" in out


def test_cli_write_baseline_deterministic(tmp_path, capsys):
    """--write-baseline regenerates the grandfather file byte-for-byte
    reproducibly: sorted unique fingerprints, stable note."""
    from repro.analysis.__main__ import main
    (tmp_path / "serving").mkdir()
    bad = tmp_path / "serving" / "mod.py"
    bad.write_text(textwrap.dedent(ENGINE_TXN_HOLE + UNDRAINED_POP))
    b1, b2 = tmp_path / "b1.json", tmp_path / "b2.json"
    assert main(["--write-baseline", "--baseline", str(b1), str(bad)]) == 0
    assert main(["--write-baseline", "--baseline", str(b2), str(bad)]) == 0
    capsys.readouterr()
    assert b1.read_bytes() == b2.read_bytes()
    data = json.loads(b1.read_text())
    assert data["fingerprints"] == sorted(set(data["fingerprints"])) \
        and len(data["fingerprints"]) >= 2
    # the regenerated file silences the findings it records
    assert main(["--baseline", str(b1), str(bad)]) == 0
