"""CSP optimal scheduling (paper §7): optimality, Fig-13 reproduction."""
import pytest

from repro.configs import get_config
from repro.core.cost_model import TheoreticalCostModel, get_hardware
from repro.core.csp import (exists_schedule_below, solve_optimal_schedule)
from repro.core.simulator import fresh_requests, run_sim

CFG = get_config("llama2-7b")
CM = TheoreticalCostModel(CFG, get_hardware("a100"), flops_eff=0.6,
                          bw_eff=0.75, attn_bw_eff=0.25)


def sched_latency(name, reqs_spec, M):
    reqs = fresh_requests([(I, O, 0.0) for I, O in reqs_spec])
    return run_sim(name, reqs, CM, M=M).latency


def test_csp_single_request():
    res = solve_optimal_schedule([(4, 2)], M=16, C=4096, cost_model=CM)
    assert res.feasible
    assert res.num_batches == 2            # prefill+token, decode+token
    assert res.num_preemptions == 0


def test_csp_never_worse_than_named_schedulers():
    """The CSP optimum lower-bounds every deployable schedule."""
    for I, O, M in [(4, 4, 12), (16, 4, 32), (64, 4, 128)]:
        spec = [(I, O)] * 4
        opt = solve_optimal_schedule(spec, M=M, C=4096, cost_model=CM)
        for name in ("vllm", "sarathi", "vllm_pf"):
            lat = sched_latency(name, spec, M)
            assert opt.optimal_time <= lat + 1e-12, (I, O, M, name)


def test_fig13_preemption_optimal_for_short_requests():
    """O=W=4, M=max(2I, I+O-1): CSP preempts for small I..."""
    I, O = 4, 4
    res = solve_optimal_schedule([(I, O)] * 4, M=max(2 * I, I + O - 1),
                                 C=4096, cost_model=CM)
    assert res.num_preemptions > 0
    pf = sched_latency("vllm_pf", [(I, O)] * 4, max(2 * I, I + O - 1))
    assert res.optimal_time < pf


def test_fig13_preemption_avoided_for_long_requests():
    """...and avoids preemption for large I (refill cost grows with I)."""
    I, O = 1024, 4
    res = solve_optimal_schedule([(I, O)] * 4, M=max(2 * I, I + O - 1),
                                 C=4096, cost_model=CM)
    assert res.num_preemptions == 0
    pf = sched_latency("vllm_pf", [(I, O)] * 4, max(2 * I, I + O - 1))
    assert res.optimal_time == pytest.approx(pf, rel=1e-6)


def test_schedule_satisfies_constraints():
    """Replay the returned schedule and check the paper's constraints."""
    M, C = 12, 8
    res = solve_optimal_schedule([(4, 3), (6, 2)], M=M, C=C, cost_model=CM)
    assert res.feasible
    state = {i: [I, O, 0, 0] for i, (I, O) in enumerate([(4, 3), (6, 2)])}
    for step in res.schedule:
        total_c = 0
        for idx, ((I, O, m, g), act) in enumerate(step):
            cur = state[idx]
            assert (cur[2], cur[3]) == (m, g)  # schedule matches replay
            if act[0] == "evict":
                cur[2] = 0
            elif act[0] == "run":
                c = act[1]
                total_c += c
                assert c <= (I + g) - m        # tokens-to-process (7)
                cur[2] += c
                if cur[2] == I + cur[3]:       # token generation (8)
                    cur[3] += 1
                    if cur[3] >= O:
                        cur[2] = 0
        assert total_c <= C                     # batch constraint (9)
        assert sum(s[2] for s in state.values()) <= M
    for (I, O, *_), s in zip([(4, 3), (6, 2)], state.values()):
        assert s[3] == O                        # termination


def test_existence_query():
    spec = [(4, 4)] * 4
    M = 8
    vllm = sched_latency("vllm", spec, M)
    assert exists_schedule_below(spec, M=M, C=4096, cost_model=CM,
                                 bound=vllm * 1.001)
    opt = solve_optimal_schedule(spec, M=M, C=4096, cost_model=CM)
    assert not exists_schedule_below(spec, M=M, C=4096, cost_model=CM,
                                     bound=opt.optimal_time * 0.999)


def test_batch_time_bound_constraint():
    """§7 objective variant: constrain per-batch time (TPOT-style SLO)."""
    spec = [(64, 2)] * 2
    free = solve_optimal_schedule(spec, M=1000, C=4096, cost_model=CM)
    from repro.core.cost_model import BatchSpec
    one_tok = CM.batch_time(BatchSpec(prefills=[(64, 0)]))
    res = solve_optimal_schedule(spec, M=1000, C=4096, cost_model=CM,
                                 batch_time_bound=one_tok * 1.01)
    assert res.feasible
    assert res.optimal_time >= free.optimal_time
    assert res.num_batches >= free.num_batches
