"""KVSwapStore invariants (§5.4 suspend/resume bookkeeping): exact
snapshot round-trips, byte accounting, capacity bounds, no leaks."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serving.swap_store import (KVSwapStore, SwapEntry,
                                      SwapStoreFullError)


def snapshot(num_kv: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "k": rng.standard_normal((2, 1, num_kv, 4)).astype(np.float32),
        "v": rng.standard_normal((2, 1, num_kv, 4)).astype(np.float32),
        "index": np.asarray([num_kv], np.int32),
    }


def test_put_pop_roundtrip_exact():
    store = KVSwapStore()
    snap = snapshot(5, seed=1)
    tokens = [3, 1, 4, 1, 5]
    store.put(7, snap, tokens, 5)
    assert 7 in store and len(store) == 1
    entry = store.pop(7)
    assert entry.rid == 7 and entry.num_kv == 5
    assert entry.tokens == tokens
    for key in snap:
        assert np.array_equal(entry.cache[key], snap[key]), key
    assert 7 not in store and len(store) == 0 and store.nbytes == 0


def test_tokens_are_copied_at_put():
    store = KVSwapStore()
    tokens = [1, 2, 3]
    store.put(0, snapshot(3), tokens, 3)
    tokens.append(99)              # caller keeps sampling after suspend
    assert store.pop(0).tokens == [1, 2, 3]


def test_double_put_and_missing_pop_raise():
    store = KVSwapStore()
    store.put(1, snapshot(2), [0, 0], 2)
    with pytest.raises(ValueError):
        store.put(1, snapshot(2), [0, 0], 2)
    with pytest.raises(KeyError):
        store.pop(42)
    store.check_invariants()


def test_capacity_bound_enforced():
    one = SwapEntry(rid=0, cache=snapshot(4), tokens=[0] * 4, num_kv=4)
    store = KVSwapStore(capacity_bytes=one.nbytes)
    store.put(0, snapshot(4), [0] * 4, 4)
    with pytest.raises(SwapStoreFullError):
        store.put(1, snapshot(4), [0] * 4, 4)
    # the failed put must not corrupt accounting
    store.check_invariants()
    assert store.suspended_rids == [0]
    store.pop(0)
    store.put(1, snapshot(4), [0] * 4, 4)   # space freed -> fits again
    store.check_invariants()


def test_discard_drops_without_restore():
    store = KVSwapStore()
    store.put(3, snapshot(2), [0, 0], 2)
    assert store.discard(3) is True
    assert store.discard(3) is False
    assert len(store) == 0 and store.nbytes == 0


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), st.integers(0, 7),
                              st.integers(1, 9)),
                    min_size=1, max_size=40))
def test_random_put_pop_sequences_never_leak(ops):
    store = KVSwapStore()
    live = {}
    for is_put, rid, num_kv in ops:
        if is_put and rid not in live:
            store.put(rid, snapshot(num_kv, seed=rid), [0] * num_kv, num_kv)
            live[rid] = num_kv
        elif not is_put and rid in live:
            assert store.pop(rid).num_kv == live.pop(rid)
        store.check_invariants()
    assert store.suspended_rids == sorted(live)
    for rid in sorted(live):
        store.pop(rid)
    assert len(store) == 0 and store.nbytes == 0


# --------------------------------------------------------------------- #
# page-granular runs (partial preemption, §8)
# --------------------------------------------------------------------- #

def _run_kv(npages, page=4, seed=0):
    rng = np.random.default_rng(seed)
    return {"k": rng.standard_normal((2, npages, page, 1, 4)),
            "v": rng.standard_normal((2, npages, page, 1, 4))}


def test_page_runs_stack_and_restore_sorted():
    store = KVSwapStore()
    # tail shed top-down: run [8, 10) first, then [4, 8), then full [0, 4)
    store.put_run(0, start=8, num_tokens=2, kv=_run_kv(1))
    store.put_run(0, start=4, num_tokens=4, kv=_run_kv(1, seed=1))
    store.put_run(0, start=0, num_tokens=4, kv=_run_kv(1, seed=2))
    store.check_invariants()
    assert store.run_tokens(0) == 10 and store.has_runs(0)
    runs = store.pop_runs(0)
    assert [r.start for r in runs] == [0, 4, 8]   # ascending for restore
    assert len(store) == 0 and store.nbytes == 0


def test_page_runs_capacity_shared_with_slot_entries():
    one = _run_kv(1)
    nbytes = sum(a.nbytes for a in one.values())
    store = KVSwapStore(capacity_bytes=int(nbytes * 2.5))
    store.put_run(0, start=0, num_tokens=4, kv=one)
    store.put_run(1, start=0, num_tokens=4, kv=_run_kv(1, seed=1))
    with pytest.raises(SwapStoreFullError):
        store.put_run(2, start=0, num_tokens=4, kv=_run_kv(1, seed=2))
    store.check_invariants()
    assert store.discard_runs(1) == 1
    store.put_run(2, start=0, num_tokens=4, kv=_run_kv(1, seed=2))
    store.check_invariants()


def test_page_runs_must_tile_contiguously():
    store = KVSwapStore()
    store.put_run(0, start=8, num_tokens=4, kv=_run_kv(1))
    store.put_run(0, start=0, num_tokens=4, kv=_run_kv(1, seed=1))
    with pytest.raises(AssertionError):   # gap [4, 8) missing
        store.check_invariants()
