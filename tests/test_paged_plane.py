"""``plane="paged"`` invariants (PR 4): page-rounded accounting that
makes OutOfPagesError unreachable, pooled-KV parity with the batched
plane and the reference oracle, page-level partial preemption under all
three preempt modes, shared-prefix page reuse with copy-on-write
divergence, and allocator/store leak freedom under churn."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (OutOfPagesError, PagedAllocator, Request,
                        TheoreticalCostModel, get_hardware, make_scheduler)
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.simulator import simulate
from repro.data.workloads import shared_prefix
from repro.models import model as M
from repro.serving import Engine, EngineConfig, generate_reference

RNG = jax.random.PRNGKey(0)
_CFG_CACHE = {}


def build(name="tinyllama-1.1b", M_kv=60, nslots=4, scheduler="vllm",
          replacement="srf", cache_len=64, chunk=16, S=128,
          preempt_mode="recompute", partial_preempt=False, **ekw):
    if name not in _CFG_CACHE:
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        _CFG_CACHE[name] = (cfg, M.init_params(cfg, RNG))
    cfg, params = _CFG_CACHE[name]
    sched = make_scheduler(scheduler, M_kv, S=S, replacement=replacement,
                           preempt_mode=preempt_mode,
                           partial_preempt=partial_preempt)
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=nslots, cache_len=cache_len,
                              chunk=chunk, **ekw),
                 cost_model=cm)
    return cfg, params, eng


def requests_for(cfg, n=5, seed=0, max_i=25, max_o=9):
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        I, O = int(rs.randint(4, max_i)), int(rs.randint(3, max_o))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        out.append(Request(rid=i, input_len=I, output_len=O,
                           arrival=0.0, prompt=prompt))
    return out


def assert_reference_parity(cfg, params, requests, outputs, cache_len=64):
    for r in requests:
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=cache_len)
        assert outputs[r.rid] == ref, f"rid={r.rid}"


# --------------------------------------------------------------------- #
# satellite 1: the page-accounting mismatch is fixed
# --------------------------------------------------------------------- #

def test_page16_accounting_regression():
    """REGRESSION (fails on the pre-PR-4 engine): with page_size=16 the
    old ``num_pages = M // page_size`` floor plus raw-token admission
    made the allocator raise OutOfPagesError on schedules the scheduler
    proved feasible.  Page-rounded accounting on both sides must run
    this workload to completion with reference-identical tokens."""
    cfg, params, eng = build(M_kv=40, page_size=16, plane="paged")
    assert eng.allocator.num_pages == 3          # ceil(40/16), not floor=2
    assert eng.sched.cfg.page_size == 16
    assert eng.sched.M_eff == eng.allocator.tokens_capacity()
    reqs = [Request(rid=i, input_len=9, output_len=3, arrival=0.0,
                    prompt=np.random.RandomState(i).randint(
                        0, cfg.vocab_size, size=9).tolist())
            for i in range(4)]
    res = eng.run(reqs)                          # old code: OutOfPagesError
    assert_reference_parity(cfg, params, reqs, res.outputs)


@pytest.mark.parametrize("preempt_mode", ["recompute", "swap", "auto"])
def test_page16_random_churn_never_out_of_pages(preempt_mode):
    """Acceptance: page_size=16, randomized admit/preempt/resume churn —
    OutOfPagesError provably unreachable on admitted schedules."""
    cfg, params, eng = build(M_kv=70, page_size=16, plane="paged",
                             partial_preempt=True,
                             preempt_mode=preempt_mode)
    reqs = requests_for(cfg, n=8, seed=3, max_i=30, max_o=10)
    res = eng.run(reqs)                          # must not raise
    assert res.metrics.num_preemptions > 0       # churn was real
    assert_reference_parity(cfg, params, reqs, res.outputs)


def test_scheduler_admissions_always_allocator_feasible():
    """Control-plane/allocator agreement at scale, no model compute: a
    shadow allocator replays every admitted grant; rounding on both
    sides must make OutOfPagesError literally unreachable."""
    rs = np.random.RandomState(0)
    for trial in range(10):
        pg = int(rs.choice([2, 4, 16]))
        M_kv = int(rs.randint(40, 120))
        scfg = SchedulerConfig(M=M_kv, C=64, S=256, chunked=True,
                               hybrid=True, priority="decode_first",
                               replacement="srf", page_size=pg,
                               partial_preempt=bool(trial % 2),
                               preempt_mode="swap" if trial % 3 else
                               "recompute")
        sched = Scheduler(scfg)
        alloc = PagedAllocator(num_pages=max(1, -(-M_kv // pg)),
                               page_size=pg)
        for i in range(12):
            sched.add_request(Request(
                rid=i, input_len=int(rs.randint(1, 40)),
                output_len=int(rs.randint(1, 12)),
                arrival=float(i % 3)))
        now, guard = 0.0, 0
        while sched.has_work() and guard < 4000:
            guard += 1
            batch = sched.get_next_batch()
            for r, npages, n_tokens, _ in batch.partial_preempted:
                assert alloc.free_tail(r.rid, npages) == n_tokens
            for victim in batch.preempted:
                alloc.free(victim.rid)
            if not batch.items:
                now += 1.0
                continue
            for r, _ in batch.items:
                if r.suspended:
                    r.resume()
                    alloc.allocate(r.rid, r.m)   # must not raise
                elif r.tail_suspended_m:
                    alloc.allocate(r.rid, r.resume_tail())
            for r, c in batch.items:
                alloc.allocate(r.rid, c)         # must not raise
                r.advance(c, now)
                if r.finished:
                    sched.complete(r)
                    alloc.free(r.rid)
            alloc.check_invariants()
            now += 1.0
        assert guard < 4000, "scheduler did not converge"
        assert alloc.used_pages == sum(
            -(-r.m // pg) for r in sched.running)


# --------------------------------------------------------------------- #
# pooled-plane parity
# --------------------------------------------------------------------- #

def test_paged_parity_dense():
    """tinyllama pooled pages vs batched slots under preemption churn:
    identical tokens, all matching the scheduler-free oracle."""
    outs = {}
    for tag, kw in (("batched", dict(plane="batched")),
                    ("paged", dict(plane="paged", page_size=8))):
        cfg, params, eng = build(preempt_mode="swap", **kw)
        reqs = requests_for(cfg)
        res = eng.run(reqs)
        assert res.metrics.num_preemptions > 0
        outs[tag] = res.outputs
    assert outs["batched"] == outs["paged"]
    cfg, params, _ = build()
    assert_reference_parity(cfg, params, requests_for(cfg), outs["paged"])


@pytest.mark.slow
@pytest.mark.parametrize("name", ["hymba-1.5b", "rwkv6-7b"])
def test_paged_parity_bounded_state_families(name):
    """Sliding-window / SSM state is slot-resident under plane="paged"
    (nothing unbounded to page); the page-rounded control plane must
    still produce identical tokens."""
    outs = {}
    for tag, kw in (("batched", dict(plane="batched")),
                    ("paged", dict(plane="paged", page_size=8))):
        cfg, params, eng = build(name, preempt_mode="swap", **kw)
        if tag == "paged":
            assert not eng._pooled
        reqs = requests_for(cfg)
        res = eng.run(reqs)
        assert res.metrics.num_preemptions > 0
        outs[tag] = res.outputs
    assert outs["batched"] == outs["paged"]


def test_paged_compile_count_constant():
    """The pooled plane inherits the batched plane's shape stability:
    compiles must not grow with workload size or churn."""
    counts = {}
    for tag, (n, seed) in {"small": (5, 2), "large": (11, 5)}.items():
        cfg, params, eng = build(M_kv=50, plane="paged", page_size=8,
                                 preempt_mode="swap")
        eng.run(requests_for(cfg, n=n, seed=seed, max_i=40))
        counts[tag] = eng.num_compiles
    assert counts["small"] == counts["large"], counts
    assert counts["small"] <= 10, counts


# --------------------------------------------------------------------- #
# page-level partial preemption
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("preempt_mode", ["recompute", "swap", "auto"])
def test_partial_preemption_parity(preempt_mode):
    """Shedding only tail pages — swap or recompute per run — never
    changes tokens, and the runs really happen."""
    cfg, params, eng = build(M_kv=72, nslots=4, scheduler="sarathi_cs",
                             plane="paged", page_size=8,
                             preempt_mode=preempt_mode,
                             partial_preempt=True)
    reqs = requests_for(cfg, n=8, seed=2, max_i=28, max_o=16)
    res = eng.run(reqs)
    assert res.metrics.num_partial_preempts > 0, "no partial preemptions"
    if preempt_mode == "swap":
        assert res.metrics.num_swaps > 0
        assert eng.swap_stats["swap_ins"] == eng.swap_stats["swap_outs"] > 0
    assert len(eng.swap_store) == 0
    assert_reference_parity(cfg, params, reqs, res.outputs)


def test_mixed_mode_sheds_forced_to_swap():
    """REGRESSION: under preempt_mode="auto" a recompute-mode shed BELOW
    host-stored swap runs would leave an unrestorable gap in the run
    tiling (silent garbage KV after restore) — once any run is
    host-resident, later sheds and the full preempt must stay swaps."""
    from repro.core.cost_model import CostModel

    class FlippingCM(CostModel):
        cheap = True

        def swap_time(self, n):
            return 1e-3 if self.cheap else 1e3

        def kv_projection_time(self, n):
            return 1.0

        def recompute_time(self, n, context=0):
            return 1.0

    cm = FlippingCM()
    sched = Scheduler(SchedulerConfig(M=256, C=64, page_size=8,
                                      partial_preempt=True,
                                      preempt_mode="auto"), cost_model=cm)
    r = Request(rid=0, input_len=32, output_len=8)
    r.running, r.m = True, 32
    sched.running.append(r)
    assert sched._partial_preempt(r, deficit=8)[2] == "swap"
    cm.cheap = False                    # crossover now favors recompute…
    assert sched._partial_preempt(r, deficit=8)[2] == "swap"   # …forced
    assert r.tail_suspended_m == 16 and r.m == 16
    sched._preempt(r)                   # full preempt likewise forced
    assert r.suspended and r.suspended_m == 32
    # without pending runs, auto is free to choose recompute again
    r2 = Request(rid=1, input_len=32, output_len=8)
    r2.running, r2.m = True, 32
    sched.running.append(r2)
    assert sched._partial_preempt(r2, deficit=8)[2] == "recompute"


def test_partial_preemption_in_simulator():
    """The simulator charges per-run swap time and restores tails — same
    control plane, virtual time only."""
    cm = TheoreticalCostModel(
        dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                            dtype="float32"),
        get_hardware("tpu_v5e"))
    for mode in ("recompute", "swap"):
        sched = make_scheduler("sarathi_cs", 72, S=128, replacement="srf",
                               preempt_mode=mode, page_size=8,
                               partial_preempt=True, cost_model=cm)
        reqs = [Request(rid=i, input_len=10 + 2 * i, output_len=12,
                        arrival=0.0) for i in range(8)]
        res = simulate(sched, reqs, cm)
        assert all(r.finished for r in reqs)
        assert res.num_partial_preempts > 0
        if mode == "swap":
            assert res.num_swaps > 0
            assert sum(b.swap_s for b in res.batches) > 0
        assert all(r.tail_suspended_m == 0 for r in reqs)


def test_shed_store_full_mid_stack_folds_stored_runs_back():
    """REGRESSION: when a second (lower) tail run overflows the store,
    the run(s) already stored above it become unrestorable across the
    gap — they must fold back to recompute too, or a later restore
    writes past the block table and silently serves garbage KV."""
    cfg0 = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                               dtype="float32")
    run_bytes = 2 * cfg0.num_layers * 8 * cfg0.num_kv_heads \
        * cfg0.head_dim_ * 4                       # one 8-token page, k+v
    cfg, params, eng = build(M_kv=400, nslots=4, plane="paged",
                             page_size=8, preempt_mode="swap",
                             partial_preempt=True,
                             swap_bytes=int(run_bytes * 1.5))
    r = Request(rid=0, input_len=32, output_len=4, arrival=0.0,
                prompt=np.random.RandomState(0).randint(
                    0, cfg.vocab_size, size=32).tolist())
    eng.submit(r)
    eng.step()                                     # full prefill, m=32
    # shed page [24, 32): fits the store
    r.partial_preempt(8, mode="swap")
    eng.sched.num_swaps += 1
    assert eng._shed_tail(r, 1, 8, "swap") is True
    # shed page [16, 24): overflows -> BOTH runs fold to recompute
    r.partial_preempt(8, mode="swap")
    eng.sched.num_swaps += 1
    assert eng._shed_tail(r, 1, 8, "swap") is False
    assert r.tail_suspended_m == 0 and r.swaps == 0
    assert not eng.swap_store.has_runs(0)
    assert eng.sched.num_swaps == 0
    eng.swap_store.check_invariants()
    assert r.remaining_prefill == 32 - 16 + 1      # refill covers the gap
    # the engine finishes the request with reference-identical tokens
    for _ in range(50):
        if r.finished:
            break
        eng.step()
    assert r.finished
    assert eng.outputs[0] == generate_reference(cfg, params, r.prompt,
                                                r.output_len, cache_len=64)


def test_shed_then_full_preempt_same_round_merges_snapshot():
    """A victim partially shed and THEN fully swap-preempted in the same
    scheduler round: nothing was freed mid-round, so the full-preempt
    snapshot covers the whole table (tail included) as ONE run and the
    restore brings back exactly suspended_m tokens."""
    cfg, params, eng = build(M_kv=400, nslots=4, plane="paged",
                             page_size=8, preempt_mode="swap",
                             partial_preempt=True)
    r = Request(rid=0, input_len=32, output_len=4, arrival=0.0,
                prompt=np.random.RandomState(1).randint(
                    0, cfg.vocab_size, size=32).tolist())
    eng.submit(r)
    eng.step()                                     # m=32, one token out
    # mimic the scheduler's round: shed one page, then full swap preempt
    r.partial_preempt(8, mode="swap")
    eng.sched.num_swaps += 1
    r.preempt(mode="swap")                         # folds tail into full
    eng.sched.num_swaps += 1
    eng.sched.running.remove(r)
    assert r.suspended and r.suspended_m == 32 and r.swap_out_m == 24
    # engine replay: the partial event is skipped for non-running
    # victims; the full snapshot covers all 32 tokens in one run
    assert eng._swap_out_paged(r) is True
    assert eng.swap_store.run_tokens(0) == 32
    # restore and run to completion with reference-identical tokens
    eng.sched.running.append(r)
    r.running = True
    eng._swap_in_paged(r)
    assert r.m == 32 and not r.suspended
    for _ in range(50):
        if r.finished:
            break
        eng.step()
    assert r.finished
    assert eng.outputs[0] == generate_reference(cfg, params, r.prompt,
                                                r.output_len, cache_len=64)


def test_recompute_shed_then_swap_preempt_same_round():
    """REGRESSION: a recompute-mode shed followed by a swap-mode full
    preemption of the same victim in one round — the shed tokens must
    come OFF the block table before the full snapshot, or the stored
    run covers more tokens than suspended_m and the restore crashes
    (or silently corrupts position bookkeeping)."""
    from repro.core.scheduler import Batch

    cfg, params, eng = build(M_kv=400, nslots=4, plane="paged",
                             page_size=8, preempt_mode="auto",
                             partial_preempt=True)
    r = Request(rid=0, input_len=32, output_len=4, arrival=0.0,
                prompt=np.random.RandomState(2).randint(
                    0, cfg.vocab_size, size=32).tolist())
    eng.submit(r)
    eng.step()                                     # m=32, one token out
    # mimic auto flipping modes within one round: recompute shed first,
    # then a swap-mode full preemption (suspended_m excludes the shed)
    r.partial_preempt(8, mode="recompute")
    r.preempt(mode="swap")
    eng.sched.num_swaps += 1
    eng.sched.running.remove(r)
    eng.sched.waiting.append(r)
    assert r.suspended_m == 24
    crafted = Batch(items=[], preempted=[r],
                    partial_preempted=[(r, 1, 8, "recompute")])
    orig = eng.sched.get_next_batch
    eng.sched.get_next_batch = lambda: crafted
    eng.step()                 # the REAL replay loop frees the shed tail
    eng.sched.get_next_batch = orig
    assert eng.swap_store.run_tokens(0) == 24      # not 32
    # normal re-admission restores 24 tokens and re-prefills the rest
    for _ in range(50):
        if r.finished:
            break
        eng.step()
    assert r.finished
    assert eng.outputs[0] == generate_reference(cfg, params, r.prompt,
                                                r.output_len, cache_len=64)


def test_block_table_cache_hits_on_in_page_appends():
    """The device block-table upload is cached against the allocator's
    page-list version: an in-page append (decode filling its current
    page) must NOT invalidate it."""
    cfg, params, eng = build(M_kv=400, nslots=4, plane="paged",
                             page_size=8)
    eng.allocator.allocate(0, 8)
    v0 = eng.allocator.version
    eng.allocator.allocate(0, 4)       # new page: bumps
    assert eng.allocator.version == v0 + 1
    eng.allocator.allocate(0, 2)       # in-page append: no bump
    assert eng.allocator.version == v0 + 1
    eng.slot_of[0] = 0
    bt1 = eng._block_tables_device()
    assert eng._block_tables_device() is bt1       # cache hit
    eng.allocator.allocate(0, 4)       # crosses into a new page
    assert eng._block_tables_device() is not bt1   # invalidated
    del eng.slot_of[0]
    eng.allocator.free(0)


def test_partial_swap_store_full_falls_back():
    """A full host store mid-run degrades a swap-mode tail run to
    recompute — tokens unchanged."""
    cfg, params, eng = build(M_kv=72, nslots=4, scheduler="sarathi_cs",
                             plane="paged", page_size=8,
                             preempt_mode="swap", partial_preempt=True)
    ref_res = eng.run(requests_for(cfg, n=8, seed=2, max_i=28, max_o=16))

    cfg, params, eng = build(M_kv=72, nslots=4, scheduler="sarathi_cs",
                             plane="paged", page_size=8,
                             preempt_mode="swap", partial_preempt=True,
                             swap_bytes=1)
    reqs = requests_for(cfg, n=8, seed=2, max_i=28, max_o=16)
    res = eng.run(reqs)
    assert eng.swap_stats["swap_fallbacks"] > 0
    assert res.metrics.num_swaps == 0 and sum(r.swaps for r in reqs) == 0
    assert res.outputs == ref_res.outputs


# --------------------------------------------------------------------- #
# shared-prefix reuse
# --------------------------------------------------------------------- #

def test_shared_prefix_dedup_and_cow_divergence():
    """≥8 requests sharing a 75% prefix: the sharers map the SAME
    physical pages (measurably fewer resident pages), their outputs
    diverge correctly after the prefix (suffix tokens land in private
    pages), and every output matches the oracle."""
    cfg, params, _ = build()
    wl_kw = dict(n=8, input_len=32, prefix_frac=0.75, output_len=6,
                 vocab=cfg.vocab_size, stagger=1e-6, seed=3)
    peaks, outs = {}, {}
    for sharing in (False, True):
        cfg, params, eng = build(M_kv=400, nslots=8, S=512, plane="paged",
                                 page_size=8, prefix_sharing=sharing)
        reqs = shared_prefix(**wl_kw)
        res = eng.run(reqs)
        peaks[sharing] = max(b.pages_used for b in res.metrics.batches)
        outs[sharing] = res.outputs
        if sharing:
            assert eng.allocator.stats["prefix_hits"] >= 7
            assert eng.allocator.stats["prefix_shared_tokens"] >= 7 * 24
        assert_reference_parity(cfg, params, reqs, res.outputs)
    assert peaks[True] < peaks[False], peaks
    # sharing changes memory, never tokens
    assert outs[True] == outs[False]
    # divergence: same prefix, different generated suffixes across rids
    assert len({tuple(v) for v in outs[True].values()}) > 1


def test_cow_copy_preserves_owner_pages():
    """Direct CoW exercise at the engine level: forcing a write into a
    registry-pinned page must copy it, leaving the registry (and any
    sharer) intact."""
    cfg, params, eng = build(M_kv=400, nslots=8, S=512, plane="paged",
                             page_size=8, prefix_sharing=True)
    r = Request(rid=0, input_len=16, output_len=2, arrival=0.0,
                prompt=list(range(100, 116)))
    eng.submit(r)
    eng.step()                 # full prefill: both prompt pages register
    pinned = eng.allocator.table(0).pages[1]
    old_content = np.asarray(eng.k_pools[:, pinned])
    eng._cow_guard(0, 12)      # mid-page write landing in a pinned page
    assert eng.allocator.stats["cow_copies"] == 1
    new_page = eng.allocator.table(0).pages[1]
    assert new_page != pinned
    # the writer got a byte-identical private copy; the registry page
    # (and with it every other sharer) is untouched
    np.testing.assert_array_equal(
        np.asarray(eng.k_pools[:, new_page]), old_content)
    np.testing.assert_array_equal(
        np.asarray(eng.k_pools[:, pinned]), old_content)
    eng.allocator.check_invariants()
