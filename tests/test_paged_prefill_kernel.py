"""Fused paged-prefill Pallas kernel vs the jnp oracle (PR 8).

Interpret-mode parity for the gather-write-attend kernel — masked
padded rows, shared (CoW-attached) pages, attach-then-diverge, and the
bucket-ladder edge sizes — plus the engine contracts the kernel path
rides on: async pooled suspend snapshots are output- and
stats-identical to the sync path, and ``Engine.warmup`` really does
pre-compile every signature the run loop can hit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_prefill_attention
from repro.kernels.paged_attention.ref import paged_prefill_reference

from tests.test_paged_plane import (assert_reference_parity, build,
                                    requests_for)

K = jax.random.PRNGKey
TOL = dict(rtol=2e-5, atol=2e-5)


def _mk(B, c, H, Hkv, D, page, maxp, spare=3, seed=0):
    """Random chunk + pools + disjoint per-row block tables."""
    P = B * maxp + spare
    q = jax.random.normal(K(seed), (B, c, H, D))
    k = jax.random.normal(K(seed + 1), (B, c, Hkv, D))
    v = jax.random.normal(K(seed + 2), (B, c, Hkv, D))
    kp = jax.random.normal(K(seed + 3), (P, page, Hkv, D))
    vp = jax.random.normal(K(seed + 4), (P, page, Hkv, D))
    bt = jax.random.permutation(K(seed + 5), P)[:B * maxp] \
        .reshape(B, maxp).astype(jnp.int32)
    return q, k, v, kp, vp, bt


def _both(q, k, v, kp, vp, bt, starts, lengths):
    starts = jnp.asarray(starts, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    got = paged_prefill_attention(q, k, v, kp, vp, bt, starts, lengths,
                                  interpret=True)
    want = paged_prefill_reference(q, k, v, kp, vp, bt, starts, lengths)
    return got, want


def _assert_triple(got, want, lengths, c):
    out_g, kp_g, vp_g = map(np.asarray, got)
    out_w, kp_w, vp_w = map(np.asarray, want)
    # attention outputs only matter on real rows — padded rows are
    # masked inert by contract, not required to be numerically equal
    valid = np.arange(c)[None, :] < np.asarray(lengths)[:, None]
    np.testing.assert_allclose(out_g[valid], out_w[valid], **TOL)
    # the pools must match EVERYWHERE: same writes, zero scribbles
    np.testing.assert_allclose(kp_g, kp_w, **TOL)
    np.testing.assert_allclose(vp_g, vp_w, **TOL)


# --------------------------------------------------------------------- #
# interpret-mode kernel parity
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("B,c,H,Hkv,D,page,maxp", [
    (2, 8, 4, 2, 64, 8, 3),       # GQA, mid-chunk
    (1, 16, 2, 2, 64, 4, 5),      # MHA, small pages
    (2, 8, 4, 1, 128, 16, 2),     # MQA, wide head
])
def test_prefill_kernel_parity_sweep(B, c, H, Hkv, D, page, maxp):
    q, k, v, kp, vp, bt = _mk(B, c, H, Hkv, D, page, maxp)
    starts = np.array([page, 0][:B] + [0] * max(0, B - 2))[:B]
    lengths = np.full((B,), c)
    got, want = _both(q, k, v, kp, vp, bt, starts, lengths)
    _assert_triple(got, want, lengths, c)


def test_prefill_kernel_masked_padded_rows():
    """Rows padded below the bucket — including fully inert length-0
    rows — write nothing: their pool pages are bit-untouched."""
    B, c, H, Hkv, D, page, maxp = 3, 8, 4, 2, 64, 8, 3
    q, k, v, kp, vp, bt = _mk(B, c, H, Hkv, D, page, maxp, seed=7)
    starts = np.array([0, 4, 0])
    lengths = np.array([c, 3, 0])          # full / partial / inert
    got, want = _both(q, k, v, kp, vp, bt, starts, lengths)
    _assert_triple(got, want, lengths, c)
    # the inert row's pages are bit-identical to the input pool
    own = np.asarray(bt[2])
    np.testing.assert_array_equal(np.asarray(got[1])[own],
                                  np.asarray(kp)[own])
    # the partial row beyond its length wrote nothing either: positions
    # [4+3, 8) of its first page keep the original pool bytes
    p0 = int(np.asarray(bt[1])[0])
    np.testing.assert_array_equal(np.asarray(got[1])[p0, 7:],
                                  np.asarray(kp)[p0, 7:])


@pytest.mark.parametrize("c", [8, 16, 64])
@pytest.mark.parametrize("ln_kind", ["one", "edge", "full"])
def test_prefill_kernel_bucket_ladder_edges(c, ln_kind):
    """Every ladder bucket at its edge lengths (1, bucket-1, bucket)."""
    B, H, Hkv, D, page = 2, 4, 2, 64, 16
    maxp = max(2, (c + page - 1) // page + 1)
    q, k, v, kp, vp, bt = _mk(B, c, H, Hkv, D, page, maxp, seed=c)
    ln = {"one": 1, "edge": c - 1, "full": c}[ln_kind]
    starts = np.array([0, page])
    lengths = np.array([ln, max(1, ln - 1)])
    got, want = _both(q, k, v, kp, vp, bt, starts, lengths)
    _assert_triple(got, want, lengths, c)


def test_prefill_kernel_attach_then_diverge():
    """Two rows share a physical prefix page (a zero-copy registry
    attach); each prefills only its private continuation.  The shared
    page must be read by both and written by neither."""
    B, c, H, Hkv, D, page, maxp = 2, 8, 4, 2, 64, 8, 3
    q, k, v, kp, vp, bt = _mk(B, c, H, Hkv, D, page, maxp, seed=11)
    bt = np.array(bt)
    bt[1, 0] = bt[0, 0]              # attach: same physical first page
    bt = jnp.asarray(bt)
    starts = np.array([page, page])  # both start past the shared page
    lengths = np.array([c, c])
    got, want = _both(q, k, v, kp, vp, bt, starts, lengths)
    _assert_triple(got, want, lengths, c)
    shared = int(np.asarray(bt)[0, 0])
    np.testing.assert_array_equal(np.asarray(got[1])[shared],
                                  np.asarray(kp)[shared])
    np.testing.assert_array_equal(np.asarray(got[2])[shared],
                                  np.asarray(vp)[shared])
    # rows carry different chunks past the shared page: they diverge
    assert not np.allclose(np.asarray(got[0])[0], np.asarray(got[0])[1])


def test_prefill_kernel_cow_boundary_page():
    """A row resuming mid-page (the CoW-guarded in-page append case)
    writes only positions >= start of that page."""
    B, c, H, Hkv, D, page, maxp = 1, 8, 4, 2, 64, 8, 2
    q, k, v, kp, vp, bt = _mk(B, c, H, Hkv, D, page, maxp, seed=13)
    starts = np.array([5])           # mid-page resume
    lengths = np.array([3])          # stays inside the boundary page
    got, want = _both(q, k, v, kp, vp, bt, starts, lengths)
    _assert_triple(got, want, lengths, c)
    p0 = int(np.asarray(bt)[0, 0])
    np.testing.assert_array_equal(np.asarray(got[1])[p0, :5],
                                  np.asarray(kp)[p0, :5])


# --------------------------------------------------------------------- #
# engine contracts: async pooled suspends, warmup
# --------------------------------------------------------------------- #

_COUNTERS = ("swap_outs", "swap_ins", "kv_out", "kv_in", "swap_fallbacks",
             "promotions", "demotions", "kv_promoted", "kv_demoted")


@pytest.mark.slow
@pytest.mark.parametrize("partial", [False, True])
def test_async_pooled_suspend_parity_vs_sync(partial):
    """Async page-run snapshots (device-side gathers drained at step
    boundaries) are token- and counter-identical to the sync path —
    only wall attribution may differ."""
    results = {}
    for async_swap in (False, True):
        cfg, params, eng = build(M_kv=40, page_size=8, plane="paged",
                                 preempt_mode="swap",
                                 partial_preempt=partial,
                                 async_swap=async_swap)
        reqs = requests_for(cfg, n=6, seed=3)
        res = eng.run(reqs)
        assert res.metrics.num_swaps > 0, "churn was not real"
        assert not eng._pending_runs
        assert len(eng.swap_store) == 0
        results[async_swap] = (res.outputs,
                               {k: eng.swap_stats[k] for k in _COUNTERS})
    assert results[True][0] == results[False][0]
    assert results[True][1] == results[False][1]


@pytest.mark.slow
@pytest.mark.parametrize("plane", ["paged", "batched"])
def test_warmup_precompiles_every_signature(plane):
    """After ``warmup()`` a preemption-free workload hits only warmed
    signatures: ``num_compiles`` does not move during ``run``."""
    cfg, params, eng = build(M_kv=200, nslots=4, plane=plane,
                             page_size=8 if plane == "paged" else 1)
    eng.warmup()
    n0 = eng.num_compiles
    assert n0 > 0
    reqs = requests_for(cfg, n=4, seed=1)
    res = eng.run(reqs)
    assert eng.num_compiles == n0, (eng.num_compiles, n0)
    assert_reference_parity(cfg, params, reqs, res.outputs)
