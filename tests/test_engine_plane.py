"""Execution-plane invariants (PR 2): shape-stable compile counts,
padded-bucket parity, fused sampling, deferred decode append, and the
async double-buffered swap-out path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Request, TheoreticalCostModel, get_hardware,
                        make_scheduler)
from repro.models import model as M
from repro.serving import Engine, EngineConfig, generate_reference

RNG = jax.random.PRNGKey(0)


def build(name="tinyllama-1.1b", M_kv=60, nslots=4, scheduler="vllm",
          replacement="srf", cache_len=64, chunk=16,
          preempt_mode="recompute", **ekw):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    params = M.init_params(cfg, RNG)
    sched = make_scheduler(scheduler, M_kv, S=128, replacement=replacement,
                           preempt_mode=preempt_mode)
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=nslots, cache_len=cache_len,
                              chunk=chunk, **ekw),
                 cost_model=cm)
    return cfg, params, eng


def requests_for(cfg, n=5, seed=0, max_i=25):
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        I, O = int(rs.randint(4, max_i)), int(rs.randint(3, 9))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        out.append(Request(rid=i, input_len=I, output_len=O,
                           arrival=0.0, prompt=prompt))
    return out


# --------------------------------------------------------------------- #
# shape stability: compile count is a small constant
# --------------------------------------------------------------------- #

def test_compile_count_constant_across_workloads():
    """The batched plane's number of distinct XLA compiles must not grow
    with request count, prompt lengths, or preemptions — the
    shape-stability invariant the bucket ladder + length mask buy."""
    counts = {}
    preempts = {}
    for tag, (n, seed) in {"small": (6, 2), "large": (14, 5)}.items():
        cfg, params, eng = build(M_kv=50, preempt_mode="swap")
        res = eng.run(requests_for(cfg, n=n, seed=seed, max_i=40))
        counts[tag] = res.num_compiles
        preempts[tag] = res.metrics.num_preemptions
    assert min(preempts.values()) > 0, preempts   # churn is exercised
    # 2.3x the requests, fresh prompt lengths, more preemption churn:
    # the signature count must not move, and stays a small constant
    assert counts["small"] == counts["large"], counts
    assert counts["small"] <= 10, counts


def test_legacy_plane_recompiles_per_tail():
    """Sanity check on the baseline the benchmark compares against: the
    PR-1 plane compiles a new prefill signature per distinct tail."""
    cfg, params, eng_leg = build(plane="legacy", M_kv=200)
    res_leg = eng_leg.run(requests_for(cfg, n=8, seed=3, max_i=40))
    cfg, params, eng_bat = build(plane="batched", M_kv=200)
    res_bat = eng_bat.run(requests_for(cfg, n=8, seed=3, max_i=40))
    assert res_leg.num_compiles > res_bat.num_compiles
    assert res_leg.outputs == res_bat.outputs


# --------------------------------------------------------------------- #
# parity: planes and knobs never change tokens
# --------------------------------------------------------------------- #

@pytest.mark.slow
@pytest.mark.parametrize("name", ["tinyllama-1.1b", "hymba-1.5b",
                                  "rwkv6-7b"])
def test_plane_parity_under_preemption(name):
    """legacy vs batched vs batched+deferred, under real preemption, on
    dense / windowed-hybrid / SSM — identical tokens, all matching the
    scheduler-free reference oracle."""
    outs = {}
    for tag, kw in (("legacy", dict(plane="legacy")),
                    ("batched", dict(plane="batched")),
                    ("deferred", dict(plane="batched",
                                      decode_append="deferred"))):
        cfg, params, eng = build(name, **kw)
        reqs = requests_for(cfg)
        res = eng.run(reqs)
        assert res.metrics.num_preemptions > 0
        outs[tag] = res.outputs
    assert outs["legacy"] == outs["batched"] == outs["deferred"]
    cfg, params, _ = build(name)
    for r in requests_for(cfg):
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=64)
        assert outs["batched"][r.rid] == ref, f"rid={r.rid}"


def test_padded_chunk_matches_unpadded():
    """models-layer contract: a bucketed chunk with a length mask leaves
    every cache leaf equal to the unpadded call — bit-identical for the
    pure-attention family (masked writes are dropped, nothing else
    moves), and within float reduction-order noise for the recurrent
    families (padding changes the inner scans' chunk factorization, so
    the same sums associate differently) — and rows with length 0 are
    untouched."""
    for name in ("tinyllama-1.1b", "hymba-1.5b", "rwkv6-7b"):
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        params = M.init_params(cfg, RNG)
        rs = np.random.RandomState(7)
        toks = rs.randint(0, cfg.vocab_size, size=(2, 13)).astype(np.int32)

        plain = M.init_cache(cfg, 2, 64)
        _, plain = M.prefill_chunk(cfg, params, jnp.asarray(toks), plain)

        padded = M.init_cache(cfg, 2, 64)
        grid = np.zeros((2, 16), np.int32)
        grid[:, :13] = toks
        _, padded = M.prefill_chunk(cfg, params, jnp.asarray(grid), padded,
                                    length=jnp.asarray([13, 13], jnp.int32))
        for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(padded)):
            a, b = np.asarray(a), np.asarray(b)
            if cfg.family == "dense":
                np.testing.assert_array_equal(a, b)
            else:
                np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

        # inert rows: row 1 gets length 0 and must not move at all
        before = jax.tree.map(lambda a: np.asarray(a).copy(), padded)
        _, after = M.prefill_chunk(cfg, params, jnp.asarray(grid), padded,
                                   length=jnp.asarray([3, 0], jnp.int32))
        for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            a, b = np.asarray(a), np.asarray(b)
            sl = (slice(None),) * (0 if a.ndim == 1 else 1) + (1,)
            np.testing.assert_array_equal(a[sl], b[sl])


# --------------------------------------------------------------------- #
# async swap-out
# --------------------------------------------------------------------- #

def test_async_swap_parity_and_drain_accounting():
    """Async double-buffered swap-outs: tokens identical to the sync
    path, every pending transfer drained, store leak-free."""
    outs = {}
    for tag, kw in (("sync", dict(async_swap=False)),
                    ("async", dict(async_swap=True))):
        cfg, params, eng = build(preempt_mode="swap", **kw)
        reqs = requests_for(cfg)
        res = eng.run(reqs)
        assert res.metrics.num_swaps > 0
        assert res.swap_stats["swap_ins"] == res.swap_stats["swap_outs"] > 0
        assert not eng._pending_swaps      # all transfers finalized
        assert len(eng.swap_store) == 0
        outs[tag] = res.outputs
    assert outs["sync"] == outs["async"]


def test_async_swap_readmit_within_drain_window():
    """A victim swapped out in step N and re-admitted in step N+1 is
    still mid-flight (entries drain at the END of step N+1): the
    swap-in must finalize the transfer on demand and restore exactly."""
    cfg, params, eng = build(preempt_mode="swap", async_swap=True,
                             nslots=2, M_kv=40)
    reqs = requests_for(cfg, n=4, seed=0)
    res = eng.run(reqs)
    assert res.metrics.num_swaps > 0
    # at least one same-window re-admission actually happened
    assert res.swap_stats["drains_on_swapin"] > 0, res.swap_stats
    # and the restored schedule still matches the reference oracle
    for r in reqs:
        ref = generate_reference(cfg, params, r.prompt, r.output_len,
                                 cache_len=64)
        assert res.outputs[r.rid] == ref, f"rid={r.rid}"


def test_async_swap_store_full_mid_flight_falls_back():
    """The store filling while transfers are in flight must fall back to
    discard-and-recompute, decrement num_swaps, and change no tokens."""
    wl = dict(n=8, seed=1, max_i=40)
    cfg, params, eng = build(preempt_mode="swap", async_swap=True, M_kv=50)
    ref_res = eng.run(requests_for(cfg, **wl))
    assert ref_res.swap_stats["swap_fallbacks"] == 0
    assert ref_res.metrics.num_swaps > 0

    # capacity for roughly one in-flight snapshot: later victims overflow
    one_slot = sum(
        leaf.nbytes for leaf in jax.tree.leaves(
            eng._slot_slice(eng.cache, jnp.int32(0))))
    cfg, params, eng = build(preempt_mode="swap", async_swap=True, M_kv=50,
                             swap_bytes=int(one_slot * 1.5))
    reqs = requests_for(cfg, **wl)
    res = eng.run(reqs)
    assert res.swap_stats["swap_outs"] > 0       # some swaps still fit
    assert res.swap_stats["swap_fallbacks"] > 0  # and some overflowed
    # every fallback un-counted its swap: per-request counters agree
    assert sum(r.swaps for r in reqs) == res.swap_stats["swap_outs"] \
        == res.metrics.num_swaps
    assert res.outputs == ref_res.outputs

    # fits-nothing store: every suspend falls back, num_swaps ends at 0
    cfg, params, eng = build(preempt_mode="swap", async_swap=True, M_kv=50,
                             swap_bytes=1)
    reqs = requests_for(cfg, **wl)
    res = eng.run(reqs)
    assert res.swap_stats["swap_fallbacks"] > 0
    assert res.metrics.num_swaps == 0 and sum(r.swaps for r in reqs) == 0
    assert res.outputs == ref_res.outputs


# --------------------------------------------------------------------- #
# instrumentation
# --------------------------------------------------------------------- #

def test_batch_logs_carry_wall_time():
    cfg, params, eng = build(M_kv=300)
    res = eng.run(requests_for(cfg, n=3))
    assert res.metrics.batches
    assert all(b.wall_s > 0 for b in res.metrics.batches)
    assert sum(b.wall_s for b in res.metrics.batches) <= res.wall_time + 1e-6
