"""Shared fixtures.  NOTE: XLA_FLAGS / device-count hacks are deliberately
NOT set here — unit tests and benches must see the real single CPU device;
multi-device tests spawn subprocesses with their own XLA_FLAGS."""
import dataclasses
import sys

import jax
import pytest

sys.path.insert(0, "src")

from repro.configs import ASSIGNED_ARCHS, get_config  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heaviest cross-plane parity tests — the tier-1 suite "
        "(plain pytest) always runs them; scripts/check.sh skips them "
        "by default (CHECK_FULL=1 opts back in)")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced(name: str, dtype: str = "float32"):
    return dataclasses.replace(get_config(name).reduced(), dtype=dtype)


@pytest.fixture(scope="session", params=ASSIGNED_ARCHS)
def arch_name(request):
    return request.param
