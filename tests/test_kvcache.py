"""Paged allocator invariants — unit + stateful property tests."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.kvcache import OutOfPagesError, PagedAllocator, PrefixCache


def test_basic_alloc_free():
    a = PagedAllocator(num_pages=8, page_size=4)
    assert a.tokens_capacity() == 32
    pages = a.allocate(0, 5)               # needs 2 pages
    assert len(pages) == 2 and a.used_pages == 2
    a.allocate(0, 3)                       # fits in slack (5+3=8=2 pages)
    assert a.used_pages == 2
    a.allocate(0, 1)                       # 9 tokens -> 3rd page
    assert a.used_pages == 3
    assert a.free(0) == 3
    assert a.used_pages == 0


def test_out_of_pages():
    a = PagedAllocator(num_pages=2, page_size=4)
    a.allocate(0, 8)
    with pytest.raises(OutOfPagesError):
        a.allocate(1, 1)
    a.free(0)
    a.allocate(1, 1)                       # fine after release


def test_pages_never_shared():
    a = PagedAllocator(num_pages=16, page_size=2)
    p0 = a.allocate(0, 6)
    p1 = a.allocate(1, 6)
    assert not set(p0) & set(p1)
    a.check_invariants()


def test_zero_grant_is_noop():
    """allocate(rid, 0) must NOT create a phantom empty BlockTable (the
    old setdefault did), and empty tables violate the invariants."""
    a = PagedAllocator(num_pages=4, page_size=4)
    assert a.allocate(0, 0) == []
    assert not a.has(0)
    a.check_invariants()
    # negative/zero repeatedly, interleaved with real work
    a.allocate(1, 3)
    assert a.allocate(1, 0) == []
    assert a.table(1).num_tokens == 3
    a.check_invariants()
    # an empty table smuggled in is rejected
    from repro.core.kvcache import BlockTable
    a._tables[9] = BlockTable()
    with pytest.raises(AssertionError):
        a.check_invariants()
    del a._tables[9]


def test_free_tail_partial():
    a = PagedAllocator(num_pages=8, page_size=4)
    a.allocate(0, 10)                      # 3 pages, last holds 2 tokens
    assert a.free_tail(0, 1) == 2          # partial page: 2 tokens back
    assert a.table(0).num_tokens == 8 and len(a.table(0).pages) == 2
    assert a.free_tail(0, 1) == 4
    assert a.table(0).num_tokens == 4
    a.check_invariants()
    assert a.free_tail(0, 1) == 4          # table empties and disappears
    assert not a.has(0)
    assert a.free_pages == 8
    a.check_invariants()


def test_share_refcounts_and_cow():
    a = PagedAllocator(num_pages=8, page_size=4)
    pages = a.allocate(0, 8)               # 2 full pages
    a.share(1, pages, 8)                   # rid 1 maps the same pages
    assert a.table(1).pages == pages
    assert a.used_pages == 2               # physically shared
    a.check_invariants()
    # CoW: writing into a shared page must remap to a private copy
    moved = a.ensure_private(1, 0)
    assert moved is not None and moved[0] == pages[0]
    assert a.table(1).pages[0] != pages[0]
    assert a.table(0).pages == pages       # owner untouched
    a.check_invariants()
    # private page: no copy needed
    assert a.ensure_private(1, 0) is None
    # freeing one sharer keeps the pages for the other
    a.free(0)
    assert a.used_pages == 2               # 1 shared page + 1 private copy
    a.free(1)
    assert a.free_pages == 8
    a.check_invariants()


def test_prefix_registry_hit_and_lru_reclaim():
    a = PagedAllocator(num_pages=4, page_size=2)
    keys = PrefixCache.chain_keys([1, 2, 3, 4], 2)
    assert len(keys) == 2
    a.allocate(0, 4)
    assert a.register_prefix(0, keys) == 2
    a.free(0)                              # pages survive as cached prefix
    assert a.used_pages == 2 and a.free_pages == 2
    # a chain hit maps the longest consecutive run
    assert a.lookup_prefix(keys) == [a.prefix_cache.get(keys[0]),
                                     a.prefix_cache.get(keys[1])]
    bogus = PrefixCache.chain_keys([9, 9, 9, 9], 2)
    assert a.lookup_prefix(bogus) == []
    assert a.lookup_prefix([keys[0], bogus[1]]) == \
        [a.prefix_cache.get(keys[0])]      # miss breaks the chain
    # pinned-only pages are reclaimed LRU when the pool runs short:
    # cached prefixes never block an admitted request
    a.allocate(1, 8)                       # needs all 4 pages
    assert a.stats["reclaimed"] == 2 and len(a.prefix_cache) == 0
    a.check_invariants()
    a.free(1)
    assert a.free_pages == 4


def test_prefix_hit_verifies_tokens_against_hash_collision():
    """A key hit whose stored page tokens differ (64-bit hash collision)
    must be treated as a MISS — serving another prompt's KV pages would
    silently break the token-identical contract."""
    a = PagedAllocator(num_pages=4, page_size=2)
    keys = PrefixCache.chain_keys([1, 2], 2)
    a.allocate(0, 2)
    a.register_prefix(0, keys, [(1, 2)])
    a.free(0)
    assert a.lookup_prefix(keys, [(1, 2)]) != []        # verified hit
    assert a.lookup_prefix(keys, [(7, 8)]) == []        # collision: miss
    # unverified lookups (no tokens supplied) keep working
    assert a.lookup_prefix(keys) != []
    a.check_invariants()


def test_shared_prefix_attach_then_reclaim_keeps_sharer_data():
    """A reclaim candidate whose page a live table still maps is
    SKIPPED: evicting it frees no memory, so destroying the registry
    entry would only burn the cache (the pre-fix behaviour).  Only
    pinned-ONLY pages return capacity — and their entries are the only
    ones evicted (under the trie, tail pages first)."""
    a = PagedAllocator(num_pages=4, page_size=2)
    keys = PrefixCache.chain_keys([5, 6, 7, 8], 2)
    a.allocate(0, 4)
    a.register_prefix(0, keys)
    a.free(0)                              # both pages cached
    pages = a.lookup_prefix(keys)
    a.share(1, pages[:1], 2)               # rid 1 maps only the first
    a.allocate(2, 6)                       # 3 pages: reclaim pressure
    # the still-mapped entry SURVIVES; only the pinned-only tail page
    # was evicted, and only that one counted as reclaimed
    assert len(a.prefix_cache) == 1
    assert a.prefix_cache.get(keys[0]) == pages[0]
    assert a.stats["reclaimed"] == 1
    assert a.stats["reclaim_skipped"] == 0  # tail-first never reached it
    assert a.table(1).pages == pages[:1]   # sharer keeps its page
    a.check_invariants()
    # further pressure lands ON the mapped page: it is skipped, counted,
    # and the request correctly bounces — the sharer's data survives
    with pytest.raises(OutOfPagesError):
        a.allocate(9, 2)
    assert a.stats["reclaim_skipped"] >= 1
    assert a.prefix_cache.get(keys[0]) == pages[0]
    a.check_invariants()
    # and the shared page only frees once the sharer lets go — then it
    # still serves registry hits until genuinely reclaimed
    a.free(2)
    a.free(1)
    assert a.free_pages == 3 and a.used_pages == 1   # cached prefix
    a.allocate(3, 8)                       # now reclaimable: pinned-only
    assert len(a.prefix_cache) == 0 and a.stats["reclaimed"] == 2
    a.free(3)
    assert a.free_pages == 4
    a.check_invariants()


@settings(max_examples=100, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 5), st.integers(1, 9),
                              st.integers(0, 3)), max_size=60))
def test_property_no_leaks_no_double_alloc(ops):
    """Random allocate/free/free_tail/register interleavings keep the
    page set partitioned and refcounts exact."""
    a = PagedAllocator(num_pages=10, page_size=4)
    for rid, tokens, op in ops:
        if op == 0:
            a.free(rid)
        elif op == 1 and a.has(rid):
            a.free_tail(rid, 1)
        elif op == 2 and a.has(rid):
            # registry pins under synthetic keys (content irrelevant here)
            a.register_prefix(rid, [hash((rid, i, len(a.table(rid).pages)))
                                    for i in range(len(a.table(rid).pages))])
        else:
            try:
                a.allocate(rid, tokens)
            except OutOfPagesError:
                pass
        a.check_invariants()
    for rid in range(6):
        a.free(rid)
    a.check_invariants()
    # drain surviving registry pins through the proper reclaim path:
    # one full-pool allocation evicts every cached prefix
    a.allocate(99, 40)
    assert len(a.prefix_cache) == 0
    a.check_invariants()
    a.free(99)
    assert a.free_pages == 10
