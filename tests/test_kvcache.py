"""Paged allocator invariants — unit + stateful property tests."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.kvcache import OutOfPagesError, PagedAllocator


def test_basic_alloc_free():
    a = PagedAllocator(num_pages=8, page_size=4)
    assert a.tokens_capacity() == 32
    pages = a.allocate(0, 5)               # needs 2 pages
    assert len(pages) == 2 and a.used_pages == 2
    a.allocate(0, 3)                       # fits in slack (5+3=8=2 pages)
    assert a.used_pages == 2
    a.allocate(0, 1)                       # 9 tokens -> 3rd page
    assert a.used_pages == 3
    assert a.free(0) == 3
    assert a.used_pages == 0


def test_out_of_pages():
    a = PagedAllocator(num_pages=2, page_size=4)
    a.allocate(0, 8)
    with pytest.raises(OutOfPagesError):
        a.allocate(1, 1)
    a.free(0)
    a.allocate(1, 1)                       # fine after release


def test_pages_never_shared():
    a = PagedAllocator(num_pages=16, page_size=2)
    p0 = a.allocate(0, 6)
    p1 = a.allocate(1, 6)
    assert not set(p0) & set(p1)
    a.check_invariants()


@settings(max_examples=100, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 5), st.integers(1, 9),
                              st.booleans()), max_size=60))
def test_property_no_leaks_no_double_alloc(ops):
    """Random allocate/free interleavings keep the page set partitioned."""
    a = PagedAllocator(num_pages=10, page_size=4)
    for rid, tokens, do_free in ops:
        if do_free:
            a.free(rid)
        else:
            try:
                a.allocate(rid, tokens)
            except OutOfPagesError:
                pass
        a.check_invariants()
    for rid in range(6):
        a.free(rid)
    assert a.free_pages == 10
